"""Architecture registry: one module per assigned architecture.

Each module exposes ``CONFIG`` (the full published configuration) and
``REDUCED`` (a tiny same-family config for CPU smoke tests).
Access via ``get_config(name)`` / ``get_reduced(name)`` / ``ARCHS``.
"""
from importlib import import_module

ARCHS = [
    "h2o_danube3_4b",
    "granite_8b",
    "granite_34b",
    "command_r_plus_104b",
    "hubert_xlarge",
    "pixtral_12b",
    "mixtral_8x7b",
    "deepseek_moe_16b",
    "recurrentgemma_2b",
    "xlstm_1_3b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _mod(name: str):
    name = _ALIASES.get(name, name)
    return import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_reduced(name: str):
    return _mod(name).REDUCED


def all_configs():
    return {a: get_config(a) for a in ARCHS}
