"""h2o-danube3-4b [dense]: llama+mistral mix with SWA.
[arXiv:2401.16818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, head_dim=120,
    window=4096, act="swiglu", rope_theta=10_000.0,
    notes="SWA window 4096; head_dim 120 (3840/32) is not 128-aligned -- "
          "MXU pads to 128 (documented in roofline).",
)

REDUCED = ModelConfig(
    name="h2o-danube3-4b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=256, head_dim=16, window=32, act="swiglu",
)
