"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks, 7:1. [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=512, act="gelu",
    cycle=("mlstm", "mlstm", "mlstm", "mlstm",
           "mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    notes="d_ff=0: mLSTM/sLSTM blocks carry their own projections.",
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=256, head_dim=16, act="gelu",
    cycle=("mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_proj_factor=2.0,
)
