"""mixtral-8x7b [moe]: 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, act="swiglu",
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16, act="swiglu", window=32,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
)
