"""command-r-plus-104b [dense]: GQA, no-bias, parallel residual.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000, head_dim=128, act="swiglu",
    parallel_residual=True, norm="layernorm", rope_theta=75_000_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="command-r-plus-104b-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=176, vocab=512, head_dim=16, act="swiglu",
    parallel_residual=True, norm="layernorm", tie_embeddings=True,
)
