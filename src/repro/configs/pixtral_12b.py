"""pixtral-12b [vlm]: mistral-nemo decoder backbone; pixtral-ViT frontend
is a stub (input_specs provides patch embeddings).
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128, act="swiglu",
    rope_theta=1_000_000.0, frontend="vision", n_patches=256,
)

REDUCED = ModelConfig(
    name="pixtral-12b-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=224, vocab=512, head_dim=16, act="swiglu",
    frontend="vision", n_patches=8,
)
