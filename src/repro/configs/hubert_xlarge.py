"""hubert-xlarge [audio]: encoder-only transformer backbone; the conv
waveform frontend is a stub (input_specs provides frame embeddings).
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80, act="gelu", norm="layernorm",
    causal=False, frontend="audio",
    notes="encoder-only: decode shapes skipped (no autoregressive step).",
)

REDUCED = ModelConfig(
    name="hubert-xlarge-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=64, head_dim=16, act="gelu", norm="layernorm",
    causal=False, frontend="audio",
)
