"""recurrentgemma-2b [hybrid]: RG-LRU + local attention in a 1:2
attention:recurrent pattern. [arXiv:2402.19427; hf]

The published model has 26 blocks: 8 x (recurrent, recurrent, local-attn)
followed by 2 recurrent blocks.  We express that exactly as one 26-block
cycle so the whole depth is still a single scanned unit.
"""
from repro.models.config import ModelConfig

# published order: r r a r r a ... r r  (26 blocks).  Expressed as a
# 2-block prefix (r, r) + 8 scanned cycles of (a, r, r), which preserves
# the exact block sequence while keeping the scanned body small.
CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256, act="geglu",
    window=2048,
    prefix=("rglru", "rglru"),
    cycle=("local_attn", "rglru", "rglru"),
    rnn_width=2560, conv_width=4, tie_embeddings=True,
    notes="prefix (r,r) + 8x cycle (a,r,r) == published r r (a r r)x8; "
          "MQA local attention, window 2048.",
)

REDUCED = ModelConfig(
    name="recurrentgemma-2b-reduced", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=192, vocab=256, head_dim=16, act="geglu", window=32,
    cycle=("rglru", "rglru", "local_attn"),
    rnn_width=64, conv_width=4, tie_embeddings=True,
)
