"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6, fine-grained,
first layer dense. [arXiv:2401.06066; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128, act="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408,
                  n_shared=2, d_shared=2816,
                  first_dense=1, d_first_dense=10944),
)

REDUCED = ModelConfig(
    name="deepseek-moe-16b-reduced", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=256, head_dim=16, act="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64,
                  n_shared=1, d_shared=128,
                  first_dense=1, d_first_dense=256),
)
