"""Pallas TPU kernel: fused RMSNorm.

Fuses the square-mean reduction, rsqrt and the weight multiply into one
VMEM pass over the (rows, d_model) activations -- the unfused XLA version
reads the activation twice (once for the variance, once for the scale).
Rows are tiled in blocks; d_model stays resident in VMEM per row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (..., d); w: (d,).  Normalizes the last axis."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, max(n, 1))
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = xf.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w.reshape(1, d))
    return out[:n].reshape(orig_shape)
