"""Pallas TPU kernel: blockwise (flash) attention forward.

TPU-native adaptation: instead of a CUDA warp-level softmax, the kernel
streams K/V blocks through VMEM with the online-softmax recurrence kept in
VMEM scratch that persists across the innermost ("arbitrary") grid
dimension; the (block_q x block_k) logits tile is produced by the MXU and
never leaves VMEM.  Block sizes default to MXU-aligned 128/512.

Supports causal masking, sliding-window (SWA) masking, decode offsets
(Sq < Skv with queries at the sequence tail), and GQA via a q-heads-per-kv-
head grouping handled in the BlockSpec index maps (kv blocks are fetched
once per q-head group, not repeated in HBM).

The pure-XLA oracle lives in :mod:`repro.kernels.ref`; the jitted wrapper
with the xla/pallas switch in :mod:`repro.kernels.ops`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_kv_blocks: int,
                  q_offset: int, kv_len: int):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0].astype(jnp.float32)                    # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = jk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len          # exclude zero-padded keys
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...][:, :1]                           # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)           # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)                               # masked -> exp(-inf)=0
    p = jnp.where(mask, p, 0.0)
    l_cur = jnp.sum(p, axis=-1, keepdims=True)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_new = alpha * l_ref[...][:, :1] + l_cur
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == n_kv_blocks - 1)
    def _finish():
        denom = l_ref[...][:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "block_q",
                              "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); returns (B, Hq, Sq, D).

    Queries occupy the *tail* of the key sequence (prefill: Sq == Skv;
    decode: Sq == 1 with a long cache).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    # pad sequences to block multiples
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    q_offset = Skv - Sq  # absolute position of query row 0
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Skv + pk

    qf = q.reshape(B * Hq, Sqp, D)
    kf = k.reshape(B * Hkv, Skp, D)
    vf = v.reshape(B * Hkv, Skp, D)
    n_q_blocks = Sqp // block_q
    n_kv_blocks = Skp // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_kv_blocks,
        q_offset=q_offset, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q_blocks, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, jk, g=group: (bh // g, jk, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, jk, g=group: (bh // g, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, D), q.dtype),
        scratch_shapes=[
            # fp32 online-softmax state, persists across the kv grid dim
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, Hq, Sqp, D)
    return out[:, :, :Sq, :]
