"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fused_combine_ref(a: jnp.ndarray, b: jnp.ndarray,
                      accum_dtype=jnp.float32) -> jnp.ndarray:
    """Elementwise combine (the allreduce reduction op) with fp32 accum."""
    return (a.astype(accum_dtype) + b.astype(accum_dtype)).astype(a.dtype)


def combine_n_ref(stack: jnp.ndarray, accum_dtype=jnp.float32) -> jnp.ndarray:
    """Sum K rows: stack (K, n) -> (n,). fp32 accumulation."""
    return jnp.sum(stack.astype(accum_dtype), axis=0).astype(stack.dtype)


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def chunked_attention_ref(q, k, v, *, causal=True, window=None, scale=None,
                          kv_valid=None, q_positions=None,
                          q_chunk: int = 256):
    """Memory-bounded XLA attention: lax.map over query chunks, so only a
    (B, H, q_chunk, Skv) logits tile is ever live.  Same math/masking as
    :func:`flash_attention_ref`; used for long sequences where the full
    (Sq, Skv) logits tensor would not fit (the dry-run path -- the Pallas
    flash kernel is the on-hardware equivalent)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = D ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32) + (Skv - Sq)
    q_chunk = min(q_chunk, Sq)
    pad = (-Sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad))
    n = q.shape[2] // q_chunk
    qs = q.reshape(B, Hq, n, q_chunk, D).transpose(2, 0, 1, 3, 4)
    ps = q_positions.reshape(n, q_chunk)
    kpos = jnp.arange(Skv, dtype=jnp.int32)[None, :]

    def one(args):
        qc, pc_ = args
        logits = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        qpos = pc_[:, None]
        mask = jnp.ones((q_chunk, Skv), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if kv_valid is not None:
            mask &= kpos < kv_valid
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        m = jnp.max(logits, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(logits - m)
        p = jnp.where(mask[None, None], p, 0.0)
        den = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        return (jnp.einsum("bhqk,bhkd->bhqd", p / den,
                           v.astype(jnp.float32))).astype(q.dtype)

    # flash-style backward: recompute each chunk's logits/probabilities
    # instead of saving the (B, H, q_chunk, Skv) tiles across all chunks
    one = jax.checkpoint(one, prevent_cse=False)
    out = jax.lax.map(one, (qs, ps))
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, n * q_chunk, D)
    return out[:, :, :Sq]


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None,
                        kv_valid=None,
                        q_positions=None,
                        return_lse: bool = False):
    """Reference attention.  q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D).

    GQA: Hq must be a multiple of Hkv; kv heads are repeated.
    ``window``: sliding-window attention -- query i attends to keys in
    (i_abs - window, i_abs] where i_abs = i + (Skv - Sq) (decode offset).
    ``kv_valid``: traced scalar or per-row ``(B,)`` vector -- keys at
    index >= kv_valid are masked (KV-cache decode over a fixed-size
    buffer; the vector form serves continuous batching, where every
    batch row sits at its own sequence length).
    ``q_positions``: (Sq,) or per-row (B, Sq) absolute query positions
    overriding the tail-alignment default (cache decode / prefill into
    a larger buffer).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    # mask shape: (Sq, Skv) shared, or (B, Sq, Skv) when any constraint
    # is per-row (vector kv_valid / 2-D q_positions)
    if q_positions is None:
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    else:
        qpos = q_positions.astype(jnp.int32)[..., :, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    if kv_valid is not None:
        kv_valid = jnp.asarray(kv_valid)
        if kv_valid.ndim == 1:
            mask = mask & (kpos[None] < kv_valid[:, None, None])
        else:
            mask = mask & (kpos < kv_valid)
    if mask.ndim == 2:
        mask = mask[None, None]
    else:
        mask = mask[:, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    if return_lse:
        m = jnp.max(logits, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        den = jnp.sum(p, axis=-1)
        lse = jnp.where(den > 0, m_safe + jnp.log(jnp.maximum(den, 1e-30)),
                        -jnp.inf)
        o = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(den, 1e-30)[..., None],
                       v.astype(jnp.float32)).astype(q.dtype)
        return o, lse
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully masked rows (can't happen causally)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
