"""Pallas TPU kernel: fused combine for the allreduce reduction step.

The gamma term of the paper's cost model is the per-byte combine speed.  On
TPU the combine (y = a + b over a large contiguous buffer, with fp32
accumulation for bf16 gradients) is HBM-bandwidth bound: 3 bytes moved per
combined byte.  The kernel tiles the flat buffer through VMEM in blocks
sized for double-buffered HBM->VMEM DMA, and fuses the dtype widening /
narrowing into the same pass so no extra fp32 copy of the buffer ever
exists in HBM -- that widening is exactly what a naive
``(a.astype(f32) + b.astype(f32)).astype(bf16)`` materializes.

``combine_n`` fuses K-way combines (latency-optimal schedule steps
combine several arrivals per output row) into one pass over HBM:
(K+1)/3x less traffic than K-1 chained pairwise ops.

The combine is any of the elementwise monoid kinds the schedule family
supports (``op`` = "add" | "max" | "min"): max/min cost the same one
VPU instruction per element as the add and reuse the identical VMEM
tiling -- the kernel is memory-bound either way, which is exactly why
the cost model prices all three with the same gamma.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 MiB fp32 working set per block-pair fits comfortably in 16 MiB VMEM
# with double buffering; lane dim must be a multiple of 128.
_BLOCK = 128 * 1024  # elements per tile (flat layout, reshaped to (rows,128))
_LANES = 128

_OPS = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


def _combine_kernel(a_ref, b_ref, o_ref, *, accum_dtype, op):
    a = a_ref[...].astype(accum_dtype)
    b = b_ref[...].astype(accum_dtype)
    o_ref[...] = _OPS[op](a, b).astype(o_ref.dtype)


def _combine_n_kernel(s_ref, o_ref, *, accum_dtype, k, op):
    acc = s_ref[0].astype(accum_dtype)
    for i in range(1, k):
        acc = _OPS[op](acc, s_ref[i].astype(accum_dtype))
    o_ref[...] = acc.astype(o_ref.dtype)


def _pad_flat(x, block):
    n = x.shape[-1]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, n


@functools.partial(jax.jit, static_argnames=("accum_dtype", "interpret",
                                             "block", "op"))
def fused_combine(a: jnp.ndarray, b: jnp.ndarray, *,
                  accum_dtype=jnp.float32, interpret: bool = False,
                  block: int = _BLOCK, op: str = "add") -> jnp.ndarray:
    """y = a (op) b elementwise over flat buffers, fp32 accumulation."""
    assert a.shape == b.shape and a.ndim == 1, (a.shape, b.shape)
    af, n = _pad_flat(a, block)
    bf, _ = _pad_flat(b, block)
    rows = block // _LANES
    grid = af.shape[0] // block
    a2 = af.reshape(grid * rows, _LANES)
    b2 = bf.reshape(grid * rows, _LANES)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, accum_dtype=accum_dtype, op=op),
        grid=(grid,),
        in_specs=[pl.BlockSpec((rows, _LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a.dtype),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("accum_dtype", "interpret",
                                             "block", "op"))
def combine_n(stack: jnp.ndarray, *, accum_dtype=jnp.float32,
              interpret: bool = False, block: int = _BLOCK,
              op: str = "add") -> jnp.ndarray:
    """Reduce K rows (K, n) -> (n,) by ``op`` in a single HBM pass."""
    assert stack.ndim == 2
    k = stack.shape[0]
    sf, n = _pad_flat(stack, block)
    rows = block // _LANES
    grid = sf.shape[-1] // block
    s2 = sf.reshape(k, grid * rows, _LANES)
    out = pl.pallas_call(
        functools.partial(_combine_n_kernel, accum_dtype=accum_dtype, k=k,
                          op=op),
        grid=(grid,),
        in_specs=[pl.BlockSpec((k, rows, _LANES), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(s2.shape[1:], stack.dtype),
        interpret=interpret,
    )(s2)
    return out.reshape(-1)[:n]
