"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode, which
executes the kernel body in Python -- bit-accurate for validation against
the :mod:`repro.kernels.ref` oracles.  On TPU they compile to Mosaic.

``attention`` / ``norm`` expose an ``impl`` switch ("pallas" | "xla") so the
model stack can pick the XLA path where cost_analysis visibility matters
(the multi-pod dry-run) and the kernel path on real hardware.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .fused_combine import combine_n, fused_combine
from .rmsnorm import rmsnorm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def combine(a: jnp.ndarray, b: jnp.ndarray, *, impl: str = "pallas"):
    if impl == "xla" or a.ndim != 1:
        return ref.fused_combine_ref(a, b)
    return fused_combine(a, b, interpret=_interpret())


def combine_many(stack: jnp.ndarray, *, impl: str = "pallas"):
    if impl == "xla":
        return ref.combine_n_ref(stack)
    return combine_n(stack, interpret=_interpret())


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None, impl: str = "xla",
              kv_valid=None, q_positions=None, return_lse: bool = False,
              block_q: int = 128, block_k: int = 512):
    if return_lse:
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window, scale=scale,
                                       kv_valid=kv_valid,
                                       q_positions=q_positions,
                                       return_lse=True)
    if impl == "chunked" and q.shape[2] > 1:
        return ref.chunked_attention_ref(q, k, v, causal=causal,
                                         window=window, scale=scale,
                                         kv_valid=kv_valid,
                                         q_positions=q_positions)
    if impl in ("xla", "chunked") or kv_valid is not None \
            or q_positions is not None:
        # traced cache lengths / explicit positions run on the XLA path;
        # a production TPU deployment would use a flash-decode kernel with
        # scalar prefetch here.
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale, kv_valid=kv_valid,
                                       q_positions=q_positions)
    return flash_attention(q, k, v, causal=causal, window=window, scale=scale,
                           block_q=block_q, block_k=block_k,
                           interpret=_interpret())


def norm(x, w, *, eps: float = 1e-6, impl: str = "xla"):
    if impl == "xla":
        return ref.rmsnorm_ref(x, w, eps=eps)
    return rmsnorm(x, w, eps=eps, interpret=_interpret())
