"""Sharded, atomic, elastic-restorable checkpointing.

Layout:
    <dir>/step_<k>/
        manifest.json        -- step, leaf paths, shapes, dtypes, meta
        <leaf-path>.npy      -- one file per pytree leaf (global arrays)
        _COMMITTED           -- written last; restore ignores dirs without it

Atomicity: write into ``step_<k>.tmp`` then rename -- a crash mid-write
never corrupts the latest checkpoint (restart resumes from the previous
committed step).  ``async_save`` runs the serialization on a background
thread so the train loop overlaps I/O with compute.

Integrity: the manifest records a sha256 per leaf file and the
``_COMMITTED`` marker records the manifest's own sha256, so damage
*after* commit (torn disk write, truncation, bit rot -- the failure the
rename cannot defend against) is detected, not silently restored.
:func:`validate_checkpoint` checks one step directory;
:func:`restore` validates before loading and falls back to the newest
earlier step that verifies, moving damaged directories aside to
``step_<k>.corrupt`` (the quarantine discipline of
:mod:`repro.tuning.cache`).  Checkpoints written before checksums
existed validate by file presence alone.

Elasticity: leaves are stored as GLOBAL arrays, so a restart with a
different mesh / dp size (or a different param_mode) just reshards on
load.  The zero1 flat optimizer buffers depend on (dp, tp); on an elastic
resize they are re-initialized (Adam moments warm up in ~b2 horizon) --
recorded in the manifest so the trainer can log it.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.compat import tree_flatten_with_path
import numpy as np


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, trees: Dict[str, Any],
         meta: Optional[Dict] = None) -> str:
    """Synchronous checkpoint of named pytrees (e.g. params, opt_state)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "trees": {}, "meta": meta or {}}
    for name, tree in trees.items():
        leaves = _leaf_paths(tree)
        manifest["trees"][name] = {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fn = f"{name}__{key.replace('/', '__')}.npy"
            fpath = os.path.join(tmp, fn)
            np.save(fpath, arr)
            manifest["trees"][name][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha256(fpath)}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    # the commit marker carries the manifest's digest: a torn or tampered
    # manifest is then as detectable as a torn leaf file
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write(_sha256(mpath))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread checkpointing; at most one save in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, trees: Dict[str, Any],
             meta: Optional[Dict] = None):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO async
        host_trees = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  trees)

        def _run():
            save(self.ckpt_dir, step, host_trees, meta)
            _gc(self.ckpt_dir, self.keep)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str, validate: bool = False) -> List[int]:
    """Committed checkpoint steps, ascending.

    ``validate=True`` additionally verifies each step's content
    checksums (:func:`validate_checkpoint`) and drops -- without
    quarantining -- the ones that fail; the default keeps listing cheap
    (one marker stat per step).
    """
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                not d.endswith(".corrupt") and \
                os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")):
            step = int(d.split("_")[1].split(".")[0])
            if validate and not validate_checkpoint(os.path.join(ckpt_dir, d)):
                continue
            out.append(step)
    return sorted(out)


def validate_checkpoint(step_dir: str) -> bool:
    """True iff a committed checkpoint directory verifies end to end.

    Checks, in order: the ``_COMMITTED`` marker exists; the manifest
    parses and (when the marker carries a digest -- legacy markers hold
    ``ok``) hashes to what the marker recorded at commit time; every
    leaf file exists and (when the manifest recorded one) matches its
    sha256.  Any failure -- torn write, truncation, bit flip, missing
    file -- returns False; nothing is modified.
    """
    marker = os.path.join(step_dir, "_COMMITTED")
    mpath = os.path.join(step_dir, "manifest.json")
    try:
        with open(marker) as f:
            committed = f.read().strip()
        if len(committed) == 64:  # digest marker (legacy markers hold "ok")
            if _sha256(mpath) != committed:
                return False
        with open(mpath) as f:
            manifest = json.load(f)
        for name, leaves in manifest["trees"].items():
            for key, ent in leaves.items():
                fpath = os.path.join(step_dir, ent["file"])
                if not os.path.exists(fpath):
                    return False
                want = ent.get("sha256")
                if want is not None and _sha256(fpath) != want:
                    return False
    except (OSError, ValueError, KeyError, AttributeError):
        return False
    return True


def _quarantine(step_dir: str) -> None:
    """Move a damaged checkpoint aside to ``<dir>.corrupt`` so it stops
    shadowing older restorable steps (mirrors the tuning cache's
    corrupt-file discipline).  Best effort: a failure to move never
    masks the original corruption."""
    dst = step_dir + ".corrupt"
    try:
        if os.path.exists(dst):
            shutil.rmtree(dst, ignore_errors=True)
        os.replace(step_dir, dst)
    except OSError:
        pass


def restore(ckpt_dir: str, like: Dict[str, Any],
            step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
    """Load named pytrees, reshaping into the structure of ``like``.

    A tree whose leaf set does not match what was stored (elastic resize
    of zero1 buffers) is returned as its ``like`` value unchanged, with a
    note in the returned meta.

    Every candidate step is checksum-validated first.  With ``step``
    given, a damaged checkpoint raises ``ValueError`` (the caller asked
    for that step specifically); without it, damaged steps are
    quarantined to ``step_<k>.corrupt`` and restore falls back to the
    newest earlier step that verifies.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {ckpt_dir}")
    if step is not None:
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        if not validate_checkpoint(d):
            raise ValueError(
                f"checkpoint step {step} in {ckpt_dir} failed validation")
    else:
        d = None
        for s in reversed(steps):
            cand = os.path.join(ckpt_dir, f"step_{s:08d}")
            if validate_checkpoint(cand):
                step, d = s, cand
                break
            _quarantine(cand)
        if d is None:
            raise FileNotFoundError(
                f"no checkpoint in {ckpt_dir} passed validation "
                f"(all {len(steps)} quarantined)")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for name, tree in like.items():
        want = _leaf_paths(tree)
        have = manifest["trees"].get(name, {})
        if set(want) != set(have) or any(
                list(np.shape(want[k])) != have[k]["shape"] for k in want):
            out[name] = tree            # incompatible layout: keep fresh
            continue
        loaded = {k: np.load(os.path.join(d, have[k]["file"]))
                  for k in want}
        flat, treedef = tree_flatten_with_path(tree)
        leaves = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            leaves.append(loaded[key].astype(have[key]["dtype"]))
        out[name] = jax.tree.unflatten(jax.tree.structure(tree), leaves)
    return step, out
