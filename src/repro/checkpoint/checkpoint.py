"""Sharded, atomic, elastic-restorable checkpointing.

Layout:
    <dir>/step_<k>/
        manifest.json        -- step, leaf paths, shapes, dtypes, meta
        <leaf-path>.npy      -- one file per pytree leaf (global arrays)
        _COMMITTED           -- written last; restore ignores dirs without it

Atomicity: write into ``step_<k>.tmp`` then rename -- a crash mid-write
never corrupts the latest checkpoint (restart resumes from the previous
committed step).  ``async_save`` runs the serialization on a background
thread so the train loop overlaps I/O with compute.

Elasticity: leaves are stored as GLOBAL arrays, so a restart with a
different mesh / dp size (or a different param_mode) just reshards on
load.  The zero1 flat optimizer buffers depend on (dp, tp); on an elastic
resize they are re-initialized (Adam moments warm up in ~b2 horizon) --
recorded in the manifest so the trainer can log it.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax

from repro.compat import tree_flatten_with_path
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, trees: Dict[str, Any],
         meta: Optional[Dict] = None) -> str:
    """Synchronous checkpoint of named pytrees (e.g. params, opt_state)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "trees": {}, "meta": meta or {}}
    for name, tree in trees.items():
        leaves = _leaf_paths(tree)
        manifest["trees"][name] = {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fn = f"{name}__{key.replace('/', '__')}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["trees"][name][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread checkpointing; at most one save in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, trees: Dict[str, Any],
             meta: Optional[Dict] = None):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO async
        host_trees = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  trees)

        def _run():
            save(self.ckpt_dir, step, host_trees, meta)
            _gc(self.ckpt_dir, self.keep)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def restore(ckpt_dir: str, like: Dict[str, Any],
            step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
    """Load named pytrees, reshaping into the structure of ``like``.

    A tree whose leaf set does not match what was stored (elastic resize
    of zero1 buffers) is returned as its ``like`` value unchanged, with a
    note in the returned meta.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {ckpt_dir}")
    step = step if step is not None else steps[-1]
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for name, tree in like.items():
        want = _leaf_paths(tree)
        have = manifest["trees"].get(name, {})
        if set(want) != set(have) or any(
                list(np.shape(want[k])) != have[k]["shape"] for k in want):
            out[name] = tree            # incompatible layout: keep fresh
            continue
        loaded = {k: np.load(os.path.join(d, have[k]["file"]))
                  for k in want}
        flat, treedef = tree_flatten_with_path(tree)
        leaves = []
        for path, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            leaves.append(loaded[key].astype(have[key]["dtype"]))
        out[name] = jax.tree.unflatten(jax.tree.structure(tree), leaves)
    return step, out
