"""Parallelism configuration and manual-SPMD collective helpers.

The whole model runs inside one ``jax.shard_map`` over the full mesh
(manual mode on every axis).  Axis roles:

* ``dp_axes``  -- data parallelism (possibly hierarchical: ("pod","data")).
  Gradient synchronization over these axes uses the paper's generalized
  allreduce / reduce-scatter / all-gather schedules.
* ``tp_axis``  -- Megatron-style tensor parallelism with sequence-parallel
  residuals: the residual stream is sharded over the sequence dim on
  ``tp_axis``; each block boundary does all-gather(seq) going in and
  reduce-scatter(seq) coming out.  With tp=1 both collectives are no-ops.

``collective_impl`` selects XLA-native all-gather/reduce-scatter or the
paper's schedule-based ppermute programs for the TP boundary collectives
(a §Perf experiment); DP gradient sync always goes through the paper's
machinery (that *is* the reproduction).

When ``dp_axes`` spans multiple fabric levels (e.g. ("pod", "data") with
DCN between pods and ICI inside), attach a
:class:`repro.topology.Topology` via the ``topology`` field: gradient
sync then routes through :func:`dp_grad_allreduce`, which picks
flat-vs-hierarchical (and the outer step count r) per message size from
the per-level fabric parameters instead of flattening everything into
one cyclic group.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.allreduce import (all_gather_flat, allreduce_flat,
                                  allreduce_tree, hierarchical_allreduce,
                                  reduce_scatter_flat)
from repro.core.cost_model import Fabric, TPU_V5E_ICI
from repro.core.monoid import CombineLike, resolve_combine
from repro.core.schedule import ShapeError, max_r
from repro.obs import trace as obs_trace
from repro.topology.fabric import Topology

AxisName = Union[str, Tuple[str, ...]]


@dataclass(frozen=True)
class ParallelConfig:
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    dp: int = 1                    # static product of dp axis sizes
    tp: int = 1
    param_mode: str = "dp"         # dp | zero1 | fsdp
    grad_r: Optional[int] = None   # gen-allreduce step override (None = autotune)
    grad_n_buckets: Optional[int] = None  # pipelined buckets (None = autotune)
    grad_combine: str = "auto"     # auto | add | pallas (ExecPlan combines)
    grad_group: str = "cyclic"     # cyclic | hypercube
    collective_impl: str = "xla"   # xla | group  (TP boundary collectives)
    moe_dispatch: str = "tp"       # tp | gshard | schedule  (MoE expert
    # dispatch: "tp" = TP-sharded experts, no dispatch collective;
    # "gshard" = expert-parallel all-to-all via lax.all_to_all (the
    # oracle); "schedule" = the same dispatch through the
    # permutation-group all_to_all_flat step tables)
    topology: Optional[Topology] = None  # multi-level fabric of dp_axes
    tuning: bool = False           # consult the measured tuning table
    # (repro.tuning) for gradient-sync schedule choice; False = analytic
    # cost model only
    trace: bool = False            # emit gradient-sync spans into the
    # global tracer (repro.obs.trace) when it is enabled; spans are
    # trace-time only (staging inside jit), runtime timelines come from
    # the blocking replay in repro.obs.instrument
    decode_collectives: str = "xla"  # xla | plan  (serving decode-path TP
    # psum / vocab all-gather: "plan" runs them on ExecPlan schedules
    # picked by autotune.choose() at the decode message size -- the
    # r = max_r / traff_rounds latency regime the paper targets)
    remat: bool = True
    scan_layers: bool = True
    overlap_bucket_bytes: Optional[int] = None  # reverse-layer gradient
    # bucket size for the backward-overlapped sync (None = no bucketing:
    # one post-backward flat allreduce, the historical behavior)
    overlap_dispatch: str = "backward"  # backward | post | skip -- when
    # bucketing is on: "backward" dispatches each bucket's allreduce
    # from inside the backward pass via custom_vjp markers
    # (attach_overlap_sync), "post" syncs the same buckets after the
    # backward completes (the A/B control: identical collectives,
    # dispatch timing is the only difference), "skip" elides DP sync
    # entirely (benchmark compute-baseline ONLY -- grads stay unsynced)
    overlap_compute_us: Optional[float] = None  # per-bucket backward
    # compute estimate (us) forwarded to the autotuner as its
    # compute_overlap_us hint; None prices buckets by raw cost
    accum_dtype = jnp.float32

    @property
    def dp_axis_name(self) -> AxisName:
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def hierarchical_dp(self) -> bool:
        """Whether DP gradient sync should compose per-level schedules."""
        return (self.topology is not None
                and self.topology.n_levels > 1
                and len(self.dp_axes) == self.topology.n_levels)


def dp_grad_allreduce(tree, pc: ParallelConfig, *, mean: bool = True,
                      fabric: Fabric = TPU_V5E_ICI,
                      op: CombineLike = "sum",
                      compute_overlap_us: Optional[float] = None,
                      tag: Optional[str] = None):
    """Gradient allreduce over the DP axes.

    With a multi-level ``pc.topology`` this routes through the
    topology-aware path (reduce-scatter on the fast inner level, the
    generalized allreduce with tunable r on the slow outer level,
    all-gather back); otherwise the flat generalized allreduce over the
    (possibly flattened) DP axis tuple.

    Gradient buckets of **any** size ride the DP split: the fused flat
    buffer is rarely divisible by ``dp``, and the collectives now run
    the balanced exact (ragged) split natively -- the autotuner prices
    such buckets by true moved bytes (no padding bytes), and the zero1
    path shards them exactly (see
    :func:`repro.core.allreduce.tree_reduce_scatter`).

    ``fabric`` tunes the *flat* path only; the hierarchical path reads
    per-level alpha/beta/gamma from ``pc.topology`` (override it via
    ``parallel_config_for(..., topology=...)`` for non-v5e machines).
    ``pc.grad_n_buckets`` pins the ExecPlan executor's pipelined bucket
    count (None = autotuned from the same fabric) and ``pc.grad_combine``
    its combine kernel routing ("auto" = Pallas combine_n on TPU).
    ``pc.tuning`` opts the schedule choice into the measured tuning
    table (:mod:`repro.tuning`): when a measurement taken on this
    backend covers the gradient's size, it overrides the model's pick.

    NOTE on ``pc.grad_r``: on a flat mesh it tunes the schedule over the
    full DP size (range [0, max_r(dp)]); on a hierarchical mesh it pins
    the hierarchical family and tunes the *outer level's* allreduce, so
    its valid range shrinks to [0, max_r(outer_size)].  Out-of-range
    values fail fast here with the hierarchical meaning spelled out
    rather than deep inside the schedule compiler.

    ``op`` generalizes the reduction over the same schedules: any
    monoid ("sum" / "max" / "min" / "mean" / a
    :class:`~repro.core.monoid.Monoid` / a callable).  Non-sum
    operators compose with ``mean=False`` only; ``pc.grad_combine``
    keeps selecting the *implementation* (Pallas vs plain elementwise)
    and composes with ``op`` as ``"<op>:pallas"``.

    ``compute_overlap_us`` is the backward-overlap hint forwarded to the
    autotuner on the flat path (the hierarchical path prices per level
    and takes no hint today); ``tag`` labels this dispatch's executor
    trace span (the overlapped sync passes ``"grad_bucket<k>"``).
    """
    if pc.dp == 1:
        return tree
    monoid, impl = resolve_combine(op)
    if monoid.name == "sum":
        combine = pc.grad_combine     # historical spellings, incl. "add"
    elif pc.grad_combine == "pallas" and monoid.fuses_pallas:
        combine = f"{monoid.name}:pallas"
    else:
        combine = monoid
    if mean and monoid.name not in ("sum", "mean"):
        raise ValueError(f"dp_grad_allreduce(op={monoid.name!r}) needs "
                         f"mean=False (mean only composes with sum)")
    if pc.trace:
        n_elems = sum(int(x.size) for x in jax.tree.leaves(tree))
        attrs = {} if tag is None else {"tag": tag}
        sp = obs_trace.span("dp_grad_allreduce", cat="trace",
                            dp=pc.dp, n_elems=n_elems, op=monoid.name,
                            hierarchical=pc.hierarchical_dp,
                            tuning=pc.tuning, **attrs)
    else:
        sp = obs_trace._NULL_SPAN
    with sp:
        if pc.hierarchical_dp:
            outer = pc.topology.outer
            if pc.grad_r is not None and \
                    not 0 <= pc.grad_r <= max_r(outer.size):
                raise ValueError(
                    f"grad_r={pc.grad_r} invalid for hierarchical DP over "
                    f"{pc.topology.describe()}: it tunes the outer level "
                    f"{outer.name}[{outer.size}], so the valid range is "
                    f"[0, {max_r(outer.size)}] (use grad_r=None to autotune "
                    f"flat-vs-hierarchical)")
            return hierarchical_allreduce(tree, pc.dp_axes, pc.topology,
                                          r=pc.grad_r, mean=mean,
                                          combine=combine,
                                          n_buckets=pc.grad_n_buckets,
                                          tune=pc.tuning)
        return allreduce_tree(tree, pc.dp_axis_name, mean=mean, r=pc.grad_r,
                              fabric=fabric, combine=combine,
                              n_buckets=pc.grad_n_buckets, tune=pc.tuning,
                              compute_overlap_us=compute_overlap_us,
                              tag=tag)


def grads_all_finite(tree, pc: ParallelConfig, *,
                     fabric: Fabric = TPU_V5E_ICI) -> jnp.ndarray:
    """Global loss-scale overflow check: True iff every gradient element
    on every DP rank is finite.

    The classic dynamic-loss-scaling guard is a *max*-allreduce, not a
    sum: each rank reduces its leaves to one "any non-finite?" indicator
    and the DP-wide maximum of the indicators decides whether the step
    applies or the scale backs off.  The indicator rides the exact same
    generalized schedules as the gradients (``op="max"`` through
    :func:`dp_grad_allreduce`), so the check works on hierarchical
    meshes and with measured tuning without any extra machinery --
    that one-scalar max-allreduce is the latency-optimal corner
    (r = max_r) of the paper's family by construction.

    Returns a boolean scalar (replicated across DP ranks).
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.bool_(True)
    bad = [jnp.any(~jnp.isfinite(g)) for g in leaves
           if jnp.issubdtype(g.dtype, jnp.inexact)]
    if not bad:
        return jnp.bool_(True)   # integer trees cannot overflow to inf
    local = jnp.stack(bad).any().astype(jnp.float32)
    if pc.dp == 1:
        return local == 0
    synced = dp_grad_allreduce(local[None], pc, mean=False, fabric=fabric,
                               op="max")
    return synced[0] == 0


# ---------------------------------------------------------------------------
#  backward-overlapped gradient sync (reverse-layer bucketing + markers)
# ---------------------------------------------------------------------------
#
# The post-backward sync pays for *all* gradient communication after the
# last backward FLOP -- nothing is hidden.  The overlapped path groups
# the parameter leaves into reverse-layer-order buckets
# (``reverse_layer_buckets``; sized by ``pc.overlap_bucket_bytes``) and
# wraps each bucket's params in a ``jax.custom_vjp`` identity marker
# (``attach_overlap_sync``) whose backward rule runs that bucket's
# ``dp_grad_allreduce``.  Autodiff reaches a marker's backward rule the
# moment every cotangent of its bucket exists, i.e. right when that
# layer band's backward completes -- so the last layers' gradients hit
# the wire while earlier layers are still differentiating, which is
# exactly the producer the multi-bucket pipelined ExecPlan executor
# wants.  ``bucketed_grad_sync`` runs the *same* per-bucket collectives
# after the backward instead (``pc.overlap_dispatch == "post"``): the
# two modes differ only in dispatch timing, so their results are
# bit-identical by construction -- the A/B pair the 8-device worker's
# bit-exactness gate and the overlap benchmark both lean on.

def reverse_layer_buckets(layers, sizes, bucket_bytes):
    """Greedy reverse-layer-order bucketing of parameter leaves.

    ``layers[i]`` is leaf i's layer index (backward completes highest
    layer first), ``sizes[i]`` its payload in bytes.  Leaves are taken
    in descending layer order (ties: ascending leaf index, so the
    partition is deterministic) and packed into buckets of at most
    ``bucket_bytes``; a leaf larger than the budget gets its own
    bucket.  Returns a list of index lists -- an exact partition of
    ``range(len(layers))``.

    >>> reverse_layer_buckets([0, 1, 1, 2], [4, 4, 4, 4], 8)
    [[3, 1], [2, 0]]
    >>> reverse_layer_buckets([0, 1], [4, 100], 8)   # oversize leaf
    [[1], [0]]
    >>> sorted(sum(reverse_layer_buckets([2, 0, 1], [9, 9, 9], 4), []))
    [0, 1, 2]
    """
    if len(layers) != len(sizes):
        raise ValueError(f"reverse_layer_buckets: {len(layers)} layers "
                         f"vs {len(sizes)} sizes")
    budget = max(int(bucket_bytes), 1)
    order = sorted(range(len(layers)), key=lambda i: (-layers[i], i))
    buckets, cur, cur_bytes = [], [], 0
    for i in order:
        if cur and cur_bytes + sizes[i] > budget:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += int(sizes[i])
    if cur:
        buckets.append(cur)
    return buckets


def _overlap_marker(pc: ParallelConfig, fabric: Fabric, tag: str):
    """Identity on a bucket's params whose VJP syncs the bucket's grads.

    Forward is the identity (zero cost, fused away); the backward rule
    runs this bucket's ``dp_grad_allreduce(mean=True)`` on the
    cotangents, so gradients emerge from ``jax.grad`` already
    DP-synced -- dispatched at the execution point where this bucket's
    backward completed, not after the whole pass.
    """
    @jax.custom_vjp
    def marker(*leaves):
        return leaves

    def fwd(*leaves):
        return leaves, None

    def bwd(_, cts):
        synced = dp_grad_allreduce(
            list(cts), pc, mean=True, fabric=fabric,
            compute_overlap_us=pc.overlap_compute_us, tag=tag)
        return tuple(synced)

    marker.defvjp(fwd, bwd)
    return marker


def attach_overlap_sync(tree, buckets, pc: ParallelConfig, *,
                        fabric: Fabric = TPU_V5E_ICI):
    """Wrap each bucket of ``tree``'s leaves in its dispatch marker.

    ``buckets`` is the index partition from
    :func:`reverse_layer_buckets` over ``jax.tree.flatten(tree)``
    order.  Apply to the *params* before the loss: the returned tree
    computes identically forward, and under ``jax.grad`` each bucket's
    gradient comes back DP-mean-synced by the marker's backward rule
    (callers must then skip the post-backward ``sync_grads_dp``).
    """
    leaves, treedef = jax.tree.flatten(tree)
    out = list(leaves)
    for k, bucket in enumerate(buckets):
        marker = _overlap_marker(pc, fabric, f"grad_bucket{k}")
        synced = marker(*[out[i] for i in bucket])
        for i, v in zip(bucket, synced):
            out[i] = v
    return jax.tree.unflatten(treedef, out)


def bucketed_grad_sync(grads, buckets, pc: ParallelConfig, *,
                       fabric: Fabric = TPU_V5E_ICI):
    """Post-backward sync of the *same* per-bucket collectives.

    The ``overlap_dispatch == "post"`` control arm: per bucket, the
    identical leaf list in the identical order through the identical
    ``dp_grad_allreduce`` call as :func:`attach_overlap_sync`'s
    backward rule -- only the dispatch point differs, which is what
    makes backward-vs-post bit-exact comparisons meaningful.
    """
    leaves, treedef = jax.tree.flatten(grads)
    out = list(leaves)
    for k, bucket in enumerate(buckets):
        synced = dp_grad_allreduce(
            [out[i] for i in bucket], pc, mean=True, fabric=fabric,
            compute_overlap_us=pc.overlap_compute_us,
            tag=f"grad_bucket{k}")
        for i, v in zip(bucket, synced):
            out[i] = v
    return jax.tree.unflatten(treedef, out)


def tp_rank(pc: ParallelConfig):
    return lax.axis_index(pc.tp_axis) if pc.tp > 1 else jnp.int32(0)


# ---------------------------------------------------------------------------
#  sequence-parallel boundary collectives
# ---------------------------------------------------------------------------

def seq_all_gather(x: jnp.ndarray, pc: ParallelConfig, axis: int = 1):
    """(B, S/tp, d) -> (B, S, d) over the TP axis."""
    if pc.tp == 1:
        return x
    if pc.collective_impl == "group":
        shape = x.shape
        flat = jnp.moveaxis(x, axis, 0).reshape(x.shape[axis], -1)
        g = all_gather_flat(flat.reshape(-1), pc.tp_axis)
        g = g.reshape(pc.tp * shape[axis], -1)
        g = g.reshape((pc.tp * shape[axis],) + shape[:axis] + shape[axis + 1:])
        return jnp.moveaxis(g, 0, axis)
    return lax.all_gather(x, pc.tp_axis, axis=axis, tiled=True)


def seq_reduce_scatter(x: jnp.ndarray, pc: ParallelConfig, axis: int = 1):
    """(B, S, d) partial-sums -> (B, S/tp, d) reduced shards over TP.

    The sequence dim must divide ``tp`` (both the XLA ``psum_scatter``
    and the shard reshape below need uniform per-rank shards; the ragged
    flat collectives cover uneven *flat* buffers, not uneven tensor
    dims) -- a violation raises :class:`~repro.core.schedule.ShapeError`
    instead of silently mis-reshaping.
    """
    if pc.tp == 1:
        return x
    if x.shape[axis] % pc.tp:
        raise ShapeError(
            f"seq_reduce_scatter: dim {axis} not divisible by tp={pc.tp}",
            expected=f"multiple of {pc.tp}", actual=x.shape[axis])
    if pc.collective_impl == "group":
        moved = jnp.moveaxis(x, axis, 0)
        flat = moved.reshape(-1)
        shard = reduce_scatter_flat(flat, pc.tp_axis,
                                    accum_dtype=None)
        out_shape = (moved.shape[0] // pc.tp,) + moved.shape[1:]
        return jnp.moveaxis(shard.reshape(out_shape), 0, axis)
    return lax.psum_scatter(x, pc.tp_axis, scatter_dimension=axis, tiled=True)


def tp_psum(x, pc: ParallelConfig):
    if pc.tp == 1:
        return x
    return lax.psum(x, pc.tp_axis)


# ---------------------------------------------------------------------------
#  decode-time TP collectives (serving)
# ---------------------------------------------------------------------------
#
# Tensor-parallel decode moves tiny messages -- a few KB of activations
# per token step -- which is the latency-dominated corner where the
# paper's large-r / traff_rounds schedules beat bandwidth-optimal
# pipelines.  With ``pc.decode_collectives == "plan"`` the serve step's
# TP psum and vocab all-gather run on ExecPlan ppermute programs whose
# schedule is picked by :func:`repro.core.autotune.choose` at trace time
# from the actual decode message size (consulting the measured tuning
# table when ``pc.tuning``).  Each pick is appended to a module-level
# log so tests and benches can assert what was chosen, including
# ``Choice.source == "measured"``.

_DECODE_CHOICE_LOG: list = []


def decode_choice_log():
    """Trace-time decode collective picks: [(op, nbytes, Choice), ...]."""
    return list(_DECODE_CHOICE_LOG)


def reset_decode_choice_log():
    _DECODE_CHOICE_LOG.clear()


def _decode_choice(pc: ParallelConfig, nbytes: int, itemsize: int, op: str):
    from repro.core.autotune import choose, schedule_for
    choice = choose(pc.tp, int(nbytes), TPU_V5E_ICI,
                    tune=pc.tuning, itemsize=itemsize)
    _DECODE_CHOICE_LOG.append((op, int(nbytes), choice))
    return choice, schedule_for(choice, pc.tp)


def tp_decode_psum(x, pc: ParallelConfig):
    """TP psum for the decode path (see module note above)."""
    if pc.tp == 1:
        return x
    if pc.decode_collectives != "plan":
        return lax.psum(x, pc.tp_axis)
    itemsize = jnp.dtype(x.dtype).itemsize
    choice, sched = _decode_choice(pc, x.size * itemsize, itemsize, "psum")
    out = allreduce_flat(x.reshape(-1), pc.tp_axis, sched,
                         accum_dtype=pc.accum_dtype,
                         n_buckets=choice.n_buckets)
    return out.reshape(x.shape).astype(x.dtype)


def tp_decode_all_gather(x, pc: ParallelConfig, axis: int = -1):
    """TP all-gather for the decode path (vocab-parallel logits).

    A pure gather has exactly one schedule family here -- the paper's
    distribution phase (``build_all_gather``, ceil(lg P) steps) -- so
    unlike the psum there is no family to pick.  ``choose()`` still runs
    at the gathered message size for its pipelining decision
    (``n_buckets``) and so the pick lands in the decode choice log with
    its ``source`` tag.
    """
    if pc.tp == 1:
        return x
    if pc.decode_collectives != "plan":
        return lax.all_gather(x, pc.tp_axis, axis=axis, tiled=True)
    from repro.core.schedule import build_all_gather
    axis = axis % x.ndim
    itemsize = jnp.dtype(x.dtype).itemsize
    nbytes = int(x.size) * itemsize * pc.tp     # total gathered bytes
    choice, _ = _decode_choice(pc, nbytes, itemsize, "all_gather")
    moved = jnp.moveaxis(x, axis, 0)
    g = all_gather_flat(moved.reshape(-1), pc.tp_axis,
                        build_all_gather(pc.tp),
                        n_buckets=choice.n_buckets)
    g = g.reshape((pc.tp * moved.shape[0],) + moved.shape[1:])
    return jnp.moveaxis(g, 0, axis)


# ---------------------------------------------------------------------------
#  parameter partitioning metadata
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    """How one parameter is laid out across the mesh.

    tp_dim:   dimension sharded over the TP axis (None = replicated in TP;
              such params need a psum over TP of their grads).
    fsdp_dim: dimension sharded over the DP axes in "fsdp" mode
              (None = replicated; grads then sync via the paper's
              allreduce).  Chosen automatically as the largest dim
              divisible by dp.
    """

    tp_dim: Optional[int] = None
    fsdp_dim: Optional[int] = None
    stacked: int = 0               # leading stacking dims (consumed by scans)

    @property
    def tp_replicated(self) -> bool:
        return self.tp_dim is None


def choose_fsdp_dim(shape: Tuple[int, ...], dp: int,
                    avoid: Optional[int] = None) -> Optional[int]:
    """Largest dim divisible by dp (excluding ``avoid``, the tp dim).

    Divisibility here is a hard ``shard_map`` constraint (per-device
    param shards enter the step function as static equal shapes), not a
    collectives limitation: leaves left unsharded (``None``) still sync
    their gradients through the ragged flat allreduce, which charges
    and moves only true bytes for awkward sizes.
    """
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if i == avoid:
            continue
        if s % dp == 0 and s > best_size:
            best, best_size = i, s
    return best


def shard_leaf(x: jnp.ndarray, spec: ParamSpec, pc: ParallelConfig,
               tp_index: int, dp_index: int) -> jnp.ndarray:
    """Slice a *full* parameter down to this device's shard (init path)."""
    if spec.tp_dim is not None and pc.tp > 1:
        n = x.shape[spec.tp_dim] // pc.tp
        x = lax.dynamic_slice_in_dim(x, tp_index * n, n, spec.tp_dim)
    if pc.param_mode == "fsdp" and spec.fsdp_dim is not None and pc.dp > 1:
        n = x.shape[spec.fsdp_dim] // pc.dp
        x = lax.dynamic_slice_in_dim(x, dp_index * n, n, spec.fsdp_dim)
    return x


def fsdp_gather(x: jnp.ndarray, spec: ParamSpec, pc: ParallelConfig,
                *, sliced: bool = False):
    """All-gather an fsdp-sharded param for use; VJP is reduce-scatter,
    which is exactly ZeRO-3 gradient flow.

    ``sliced``: the leading stacking dims have already been consumed by
    the (cycle, group) scans, so the fsdp dim shifts down by ``stacked``.
    """
    if pc.param_mode != "fsdp" or spec.fsdp_dim is None or pc.dp == 1:
        return x
    axis = spec.fsdp_dim - (int(spec.stacked) if sliced else 0)
    return lax.all_gather(x, pc.dp_axis_name, axis=axis, tiled=True)


def fsdp_gather_tree(params, specs, pc: ParallelConfig, *,
                     sliced: bool = False):
    # ParamSpec is an unregistered dataclass, i.e. a pytree leaf, so the
    # specs tree aligns leaf-for-leaf with the params tree.
    return jax.tree.map(
        lambda x, s: fsdp_gather(x, s, pc, sliced=sliced), params, specs)
