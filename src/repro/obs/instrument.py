"""Blocking per-tick traced replay of a compiled collective schedule.

:func:`repro.core.execplan.execute` stages the whole replay inside one
``shard_map``/jit trace, so host-side wall clocks can only see the fused
program's total time -- never the per-tick send/combine breakdown the
predicted-vs-measured validation (:mod:`repro.obs.validate`) needs.
This module is the opt-in measurement mode: it replays the *same*
:class:`~repro.core.execplan.ExecPlan` tables over the *same*
:func:`~repro.core.execplan.tick_structure` timeline, but drives the
tick loop from the host, with each tick split into two separately
jitted ``shard_map`` phases

* **send**   -- gather every active bucket's ``tx_slots`` rows and issue
  its ``ppermute``;
* **combine** -- apply the tick's pairwise combines and land received
  rows in their freed slots;

and a ``jax.block_until_ready`` fence after each phase.  The fences are
the point: they trade the fused program's overlap away for an exact
per-phase timeline, which is why this is a *measurement* mode and never
the production path (the production path keeps its <2% disabled-tracing
overhead; see ``trace_off_overhead`` in the executor benchmark).

Each rep replays all ticks from the same initial buffer; the rep with
the smallest total is kept (host noise only ever adds time).  The
replay verifies its result against a numpy reduction of the inputs, so
a timeline is never reported for a wrong answer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from . import trace as obs_trace


@dataclass(frozen=True)
class TickRecord:
    """Measured timing of one executor tick of the blocking replay."""

    tick: int
    steps: Tuple[Tuple[int, int], ...]   # active (bucket, step) pairs
    comm_us: float                       # send phase (gather + ppermute)
    combine_us: float                    # combine phase (adds + recv lands)

    @property
    def total_us(self) -> float:
        return self.comm_us + self.combine_us

    def to_dict(self) -> dict:
        return {"tick": self.tick,
                "steps": [list(p) for p in self.steps],
                "comm_us": self.comm_us, "combine_us": self.combine_us,
                "total_us": self.total_us}


@dataclass(frozen=True)
class ReplayReport:
    """One traced replay: identity, per-tick timeline, correctness."""

    kind: str
    r: int
    P: int
    m: int                               # message elements
    itemsize: int
    n_buckets: int
    ticks: Tuple[TickRecord, ...]
    reps: int
    verified: bool
    max_abs_err: float
    result: Optional[np.ndarray] = field(default=None, repr=False,
                                         compare=False)

    @property
    def nbytes(self) -> int:
        return self.m * self.itemsize

    @property
    def total_us(self) -> float:
        return sum(t.total_us for t in self.ticks)

    def measured_tick_us(self) -> List[float]:
        return [t.total_us for t in self.ticks]

    def to_dict(self) -> dict:
        return {"kind": self.kind, "r": self.r, "P": self.P, "m": self.m,
                "itemsize": self.itemsize, "nbytes": self.nbytes,
                "n_buckets": self.n_buckets, "reps": self.reps,
                "verified": self.verified,
                "max_abs_err": self.max_abs_err,
                "total_us": self.total_us,
                "ticks": [t.to_dict() for t in self.ticks]}


# ---------------------------------------------------------------------------
#  state preparation (numpy mirror of the executor's bucket split)
# ---------------------------------------------------------------------------

def _initial_state(plan, vectors, n_buckets):
    """(P, B, n_slots, ub) initial buffer + (u, ub, chunk_sizes, m).

    Mirrors :func:`repro.core.execplan.simulate_plan`'s init: device d's
    input is split into the balanced ragged chunk buffer and placed by
    ``plan.init_rows[:, d]``; each slot row is then cut into
    ``n_buckets`` equal column slices (zero-padded to ``ub * B``)."""
    from repro.core.execplan import _np_chunks
    from repro.core.schedule import ragged_sizes

    P = plan.P
    m = int(vectors[0].shape[0])
    chunk_sizes = ragged_sizes(m, P)
    u = max(-(-m // P), 1)
    B = max(1, min(int(n_buckets), u))
    ub = -(-u // B)
    state = np.zeros((P, B, plan.n_slots, ub), vectors[0].dtype)
    for d in range(P):
        ch = _np_chunks(np.asarray(vectors[d]), P)
        init = ch[plan.init_rows[:, d]]                  # (R0, u)
        padded = np.zeros((plan.n_rows0, ub * B), init.dtype)
        padded[:, :u] = init
        state[d, :, :plan.n_rows0, :] = \
            padded.reshape(plan.n_rows0, B, ub).transpose(1, 0, 2)
    return state, (u, ub, chunk_sizes, m)


def _extract_results(plan, state, geom):
    """Per-device exact reduced vectors from the final (P,B,S,ub) state."""
    u, ub, chunk_sizes, m = geom
    P = plan.P
    out = []
    for d in range(P):
        full = np.concatenate(list(state[d]), axis=1)[:, :u]  # (n_slots, u)
        cols = plan.final_rows[:, d]
        out.append(np.concatenate(
            [full[cols[c]][:chunk_sizes[c]] for c in range(P)]))
    return out


# ---------------------------------------------------------------------------
#  per-tick jitted phase functions
# ---------------------------------------------------------------------------

def _tick_phase_fns(plan, active, axis_name, mesh):
    """(send_fn, combine_fn) for one tick's active (bucket, step) pairs.

    ``send_fn(buf) -> rx_tuple`` stages every active bucket's gather +
    ``ppermute`` (every live step transmits, so each active pair yields
    one rx array); ``combine_fn(buf, rx_tuple) -> buf`` applies the
    tick's combines and lands received rows.  Both are ``shard_map``
    over the leading device axis of the (P, B, n_slots, ub) buffer.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro import compat

    spec = P(axis_name, None, None, None)
    rx_spec = tuple(P(axis_name, None, None) for _ in active)

    def send(buf):
        b = buf[0]
        outs = []
        for j, s in active:
            sp = plan.steps[s]
            tx = b[j][jnp.asarray(sp.tx_slots)]
            outs.append(lax.ppermute(tx, axis_name, perm=sp.perm)[None])
        return tuple(outs)

    def combine(buf, rxs):
        b = buf[0]
        for (j, s), rx3 in zip(active, rxs):
            sp = plan.steps[s]
            rx = rx3[0]
            if sp.n_adds:
                sums = b[j, jnp.asarray(sp.add_src)] + \
                    rx[jnp.asarray(sp.add_arr)]
                b = b.at[j, jnp.asarray(sp.add_dst)].set(sums)
            if len(sp.recv_slots):
                b = b.at[j, jnp.asarray(sp.recv_slots)].set(
                    rx[jnp.asarray(sp.recv_arr)])
        return b[None]

    send_fn = jax.jit(compat.shard_map(
        send, mesh=mesh, in_specs=spec, out_specs=rx_spec))
    combine_fn = jax.jit(compat.shard_map(
        combine, mesh=mesh, in_specs=(spec, rx_spec), out_specs=spec))
    return send_fn, combine_fn


# ---------------------------------------------------------------------------
#  the traced replay
# ---------------------------------------------------------------------------

def traced_allreduce(sched, vectors, *, n_buckets: int = 1,
                     mesh=None, axis_name: str = "data",
                     reps: int = 3, tracer=None) -> ReplayReport:
    """Replay an allreduce schedule tick-by-tick with per-phase fences.

    ``vectors`` is one flat numpy array per device (the per-device
    inputs of the sum-allreduce).  Returns a :class:`ReplayReport` whose
    tick timeline is the best (minimum-total) of ``reps`` replays, with
    the result verified against ``np.add.reduce(vectors)``.

    When the given (or global) tracer is enabled, every rep emits
    nested ``replay > tick > send/combine`` spans plus per-tick
    ``tx_bytes`` / ``add_bytes`` counters, so the exported Chrome trace
    shows the same timeline the report tabulates.
    """
    import jax

    from repro.core.cost_model import HOST_CPU, ragged_tick_costs
    from repro.core.execplan import compile_plan, tick_structure

    if tracer is None:
        tracer = obs_trace.get_tracer()
    plan = compile_plan(sched)
    P = plan.P
    if mesh is None:
        mesh = jax.make_mesh((P,), (axis_name,))
    vectors = [np.asarray(v) for v in vectors]
    itemsize = int(vectors[0].dtype.itemsize)
    state0, geom = _initial_state(plan, vectors, n_buckets)
    u, ub, chunk_sizes, m = geom
    B = state0.shape[1]
    ticks = tick_structure(plan, B)
    # bytes moved/reduced per tick (fabric-independent fields only)
    tick_bytes = ragged_tick_costs(sched, m * itemsize, HOST_CPU, B,
                                   itemsize=itemsize)

    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as Pspec
    sharding = NamedSharding(mesh, Pspec(axis_name, None, None, None))
    buf0 = jax.device_put(state0, sharding)

    fns = [_tick_phase_fns(plan, active, axis_name, mesh)
           for active in ticks]

    def replay(record):
        buf = buf0
        timings = []
        import time
        for t, (active, (send_fn, combine_fn)) in enumerate(zip(ticks, fns)):
            with tracer.span("tick", cat="replay", tick=t,
                             steps=[list(p) for p in active]) if record \
                    else obs_trace._NULL_SPAN:
                t0 = time.perf_counter_ns()
                with tracer.span("send", cat="replay") if record \
                        else obs_trace._NULL_SPAN:
                    rx = jax.block_until_ready(send_fn(buf))
                t1 = time.perf_counter_ns()
                with tracer.span("combine", cat="replay") if record \
                        else obs_trace._NULL_SPAN:
                    buf = jax.block_until_ready(combine_fn(buf, rx))
                t2 = time.perf_counter_ns()
            if record:
                tracer.counter("tx_bytes", tick_bytes[t]["tx_bytes"])
                tracer.counter("add_bytes", tick_bytes[t]["add_bytes"])
            timings.append(((t1 - t0) / 1e3, (t2 - t1) / 1e3))
        return buf, timings

    with tracer.span("replay", cat="replay", kind=plan.kind, r=sched.r,
                     P=P, m=m, n_buckets=B, n_ticks=len(ticks),
                     reps=reps):
        final_buf, _ = replay(record=False)           # warmup / compile
        best = None
        for _ in range(max(int(reps), 1)):
            final_buf, timings = replay(record=True)
            total = sum(a + b for a, b in timings)
            if best is None or total < best[0]:
                best = (total, timings)

    results = _extract_results(plan, np.asarray(final_buf), geom)
    ref = np.add.reduce(np.stack(vectors), axis=0)
    err = max(float(np.max(np.abs(res - ref))) if m else 0.0
              for res in results)
    tol = 1e-4 * max(1.0, float(np.max(np.abs(ref))) if m else 1.0)
    records = tuple(
        TickRecord(tick=t, steps=tuple(tuple(p) for p in active),
                   comm_us=round(comm, 3), combine_us=round(comb, 3))
        for t, (active, (comm, comb)) in enumerate(zip(ticks, best[1])))
    return ReplayReport(kind=plan.kind, r=sched.r, P=P, m=m,
                        itemsize=itemsize, n_buckets=B, ticks=records,
                        reps=reps, verified=bool(err <= tol),
                        max_abs_err=err, result=results[0])
