"""Span/counter recorder with Chrome-trace (Perfetto-loadable) export.

One process-global :class:`Tracer` collects *complete* span events
(``ph: "X"``: name, timestamp, duration, process, thread) and counter
samples (``ph: "C"``), and serializes them to the Chrome trace-event
JSON format that ``ui.perfetto.dev`` / ``chrome://tracing`` load
directly.  Design constraints, in order:

1. **Disabled means free.**  The default tracer is disabled; the
   module-level :func:`span` returns a shared no-op context manager
   without allocating, so instrumentation sites sprinkled through hot
   dispatch paths cost one attribute check (<2% on the executor bench,
   gated by the benchmark's ``trace_off_overhead`` figure).
2. **Thread-safe nesting.**  Spans nest per thread (each thread has its
   own open-span stack); the event list append is lock-protected, so
   worker threads (async checkpointer, data prefetch) can trace freely.
3. **Self-describing export.**  ``export()`` emits process/thread
   metadata records and keeps every span's ``args`` (schedule kind, r,
   n_buckets, bytes, ...), so a trace is readable without the code.

>>> t = Tracer(enabled=True)
>>> with t.span("tick", cat="exec", step=3):
...     with t.span("combine", cat="exec"):
...         pass
>>> t.counter("bytes_tx", 4096)
>>> ev = t.export()["traceEvents"]
>>> [e["ph"] for e in ev if e["ph"] != "M"]
['X', 'X', 'C']
>>> sorted(e["name"] for e in ev if e["ph"] == "X")
['combine', 'tick']
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One open span; appended to the tracer's event list on exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def set(self, **args) -> "_Span":
        """Attach result metadata discovered while the span is open."""
        self.args.update(args)
        return self

    def __enter__(self):
        self._t0 = self._tracer._now_us()
        self._tracer._push(self)
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._now_us()
        self._tracer._pop(self, self._t0, t1 - self._t0)
        return False


class Tracer:
    """Span/counter recorder; see module docstring.

    ``enabled`` may be flipped at runtime; events recorded while
    disabled are simply not recorded (open spans straddling the flip
    close without emitting).
    """

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: Dict[int, int] = {}
        self._t0_ns = time.perf_counter_ns()
        self._pid = os.getpid()

    # ------------------------------------------------------------ clock
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # ------------------------------------------------------------ spans
    def span(self, name: str, cat: str = "", **args):
        """Context manager recording one complete ("X") event."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def _push(self, sp: _Span) -> None:
        self._stack().append(sp)

    def _pop(self, sp: _Span, ts: float, dur: float) -> None:
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        if not self.enabled:
            return
        ev = {"name": sp.name, "cat": sp.cat or "span", "ph": "X",
              "ts": round(ts, 3), "dur": round(max(dur, 0.0), 3),
              "pid": self._pid, "tid": self._tid()}
        if sp.args:
            ev["args"] = _jsonable(sp.args)
        with self._lock:
            self._events.append(ev)

    @property
    def depth(self) -> int:
        """Open-span nesting depth of the calling thread."""
        return len(self._stack())

    # --------------------------------------------------------- counters
    def counter(self, name: str, value, cat: str = "counter") -> None:
        """Record one counter sample (Chrome ``"C"`` event)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "C",
              "ts": round(self._now_us(), 3), "pid": self._pid,
              "tid": self._tid(), "args": {name: value}}
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, cat: str = "mark", **args) -> None:
        """Record one instant ("i") event (a point-in-time mark)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": round(self._now_us(), 3), "pid": self._pid,
              "tid": self._tid()}
        if args:
            ev["args"] = _jsonable(args)
        with self._lock:
            self._events.append(ev)

    # ----------------------------------------------------------- export
    def export(self, process_name: str = "repro") -> dict:
        """Chrome trace-event JSON payload (Perfetto-loadable)."""
        with self._lock:
            events = list(self._events)
            tids = dict(self._tids)
        meta = [{"name": "process_name", "ph": "M", "pid": self._pid,
                 "tid": 0, "args": {"name": process_name}}]
        for ident, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": self._pid, "tid": tid,
                         "args": {"name": f"thread-{tid}"}})
        events.sort(key=lambda e: e.get("ts", 0.0))
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def save(self, path: str, process_name: str = "repro") -> str:
        """Write the exported trace JSON to ``path`` (dirs created)."""
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.export(process_name), f)
        return path

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)


def _jsonable(args: dict) -> dict:
    out = {}
    for k, v in args.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool))
                      else str(x) for x in v]
        else:
            out[k] = str(v)
    return out


# ---------------------------------------------------------------------------
#  process-global tracer
# ---------------------------------------------------------------------------

_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the global tracer (tests); returns the previous one."""
    global _tracer
    prev, _tracer = _tracer, tracer
    return prev


def enable(clear: bool = False) -> Tracer:
    """Turn the global tracer on (optionally dropping recorded events)."""
    if clear:
        _tracer.clear()
    _tracer.enabled = True
    return _tracer


def disable() -> Tracer:
    _tracer.enabled = False
    return _tracer


def span(name: str, cat: str = "", **args):
    """Module-level span against the global tracer.

    The disabled fast path returns a shared no-op context manager
    without constructing anything -- safe to call in dispatch loops.
    """
    t = _tracer
    if not t.enabled:
        return _NULL_SPAN
    return t.span(name, cat, **args)


def counter(name: str, value, cat: str = "counter") -> None:
    """Module-level counter sample against the global tracer."""
    t = _tracer
    if t.enabled:
        t.counter(name, value, cat)
