"""Observability layer: tracing, metrics, structured logging, telemetry.

The collective stack can *verify* itself (symbolic simulator, numpy
oracles, conformance harness) and *time* itself end to end (tuning
grid, executor bench), but until this package it could not say where a
schedule's time goes.  ``repro.obs`` adds the missing instrumentation:

* :mod:`repro.obs.trace`    -- span/counter recorder with Chrome-trace
  (Perfetto-loadable) JSON export; a process-global tracer that is a
  near-zero-cost no-op until enabled;
* :mod:`repro.obs.metrics`  -- structured counters and histograms
  (bytes moved, combine FLOPs, request latency p50/p99) with a JSON
  snapshot format committed under ``results/``;
* :mod:`repro.obs.log`      -- a small structured logger (level via the
  ``REPRO_LOG`` env var) replacing bare prints in the benchmark
  drivers and workers;
* :mod:`repro.obs.skew`     -- per-device arrival-pattern telemetry
  (Proficz, arXiv:1804.05349): the measurement half of PAP-aware
  schedules;
* :mod:`repro.obs.instrument` -- opt-in blocking per-tick replay of an
  :class:`~repro.core.execplan.ExecPlan` that times every send and
  combine phase on real devices;
* :mod:`repro.obs.validate` -- predicted-vs-measured reports overlaying
  the alpha-beta-gamma cost model's per-tick predictions on measured
  timelines, emitting a per-(kind, r, n_buckets, size) model-error
  table.

Import discipline: everything here sits *above* ``repro.core`` (it may
import the cost model and plans) but below nothing -- core modules only
ever call the tracer through the cheap global accessors, never the
other way around, and importing ``repro.obs`` must not import jax.
"""
from . import log, metrics, trace  # noqa: F401
from .log import get_logger  # noqa: F401
from .metrics import get_metrics  # noqa: F401
from .trace import counter, get_tracer, span  # noqa: F401
