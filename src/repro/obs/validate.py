"""Predicted-vs-measured validation of the collective cost model.

The tuner's choices are only as good as the alpha-beta-gamma model
behind them (ROADMAP item: validate the model against measurement).
This module overlays the model's per-tick timeline
(:func:`repro.core.cost_model.ragged_tick_costs`) on a measured one
(:func:`repro.obs.instrument.traced_allreduce`, or any source of
per-tick microseconds) and reduces the overlay to a model-error table:
one row per (kind, r, n_buckets, nbytes) cell with the predicted and
measured totals and their ratio.  ``ratio = measured / predicted``; a
perfectly calibrated fabric gives 1.0, and ``log2(ratio)`` is the
signed miscalibration in doublings (the scale on which the tuner's
cost comparisons actually operate).

The report is pure arithmetic over plain dicts -- no jax -- so the
golden test can prove it *exact*: feeding the model's own per-tick
costs back as "measured" must produce ratio 1.0 on every row.

>>> from repro.core.cost_model import PAPER_10GE
>>> from repro.core.schedule import build_generalized
>>> s = build_generalized(4, 1)
>>> pred = predicted_ticks_us(s, 4096, PAPER_10GE)
>>> row = validate_ticks(s, 4096, PAPER_10GE, measured_ticks_us=pred)
>>> row["ratio"], row["max_tick_ratio"]
(1.0, 1.0)
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence


def _sched_for(kind: str, P: int, r: int):
    from repro.core.schedule import (build_all_gather,
                                     build_bruck_all_gather,
                                     build_dual_root, build_generalized,
                                     build_reduce_scatter, build_ring,
                                     build_traff_rounds)
    builders = {"ring": build_ring,
                "reduce_scatter": build_reduce_scatter,
                "all_gather": build_all_gather,
                "bruck_all_gather": build_bruck_all_gather,
                "traff_rounds": build_traff_rounds,
                "dual_root": build_dual_root}
    if kind in builders:
        return builders[kind](P)
    if kind == "generalized":
        return build_generalized(P, r)
    raise ValueError(f"no schedule builder for kind {kind!r}")


def predicted_ticks_us(sched, nbytes: int, fabric, n_buckets: int = 1,
                       itemsize: int = 1, monoid=None) -> List[float]:
    """Model's per-tick timeline in microseconds (see ragged_tick_costs)."""
    from repro.core.cost_model import ragged_tick_costs
    return [t["total_s"] * 1e6 for t in
            ragged_tick_costs(sched, nbytes, fabric, n_buckets,
                              itemsize=itemsize, monoid=monoid)]


def validate_ticks(sched, nbytes: int, fabric, *,
                   measured_ticks_us: Sequence[float],
                   n_buckets: int = 1, itemsize: int = 1,
                   monoid=None) -> dict:
    """Overlay one measured tick timeline on the model's prediction.

    The measured timeline must have exactly the model's tick count
    (``n_live_steps + n_buckets - 1``) -- both sides follow
    :func:`repro.core.execplan.tick_structure`, so a length mismatch
    means the caller paired the wrong (schedule, n_buckets) with the
    measurement and is reported as a ``ValueError``, not a bad ratio.
    """
    pred = predicted_ticks_us(sched, nbytes, fabric, n_buckets,
                              itemsize=itemsize, monoid=monoid)
    meas = [float(x) for x in measured_ticks_us]
    if len(meas) != len(pred):
        raise ValueError(
            f"measured timeline has {len(meas)} ticks, model predicts "
            f"{len(pred)} for kind={sched.kind!r} n_buckets={n_buckets}")
    pred_total = sum(pred)
    meas_total = sum(meas)
    tick_ratios = [m / p if p else math.inf for m, p in zip(meas, pred)]
    ratio = meas_total / pred_total if pred_total else math.inf
    return {
        "kind": sched.kind, "r": sched.r, "P": sched.P,
        "n_buckets": int(n_buckets), "nbytes": int(nbytes),
        "n_ticks": len(pred),
        "predicted_us": pred, "measured_us": meas,
        "predicted_total_us": pred_total,
        "measured_total_us": meas_total,
        "ratio": ratio,
        "log2_ratio": math.log2(ratio) if 0 < ratio < math.inf else None,
        "max_tick_ratio": max(tick_ratios) if tick_ratios else None,
    }


def validate_replay(report, fabric, monoid=None) -> dict:
    """Model-error row for one traced replay.

    ``report`` is a :class:`repro.obs.instrument.ReplayReport` or its
    ``to_dict()`` form (what the benchmark workers serialize).
    """
    if isinstance(report, dict):
        kind, r, P = report["kind"], report["r"], report["P"]
        n_buckets, itemsize = report["n_buckets"], report["itemsize"]
        nbytes = report["nbytes"]
        meas = [t["total_us"] for t in report["ticks"]]
    else:
        kind, r, P = report.kind, report.r, report.P
        n_buckets, itemsize = report.n_buckets, report.itemsize
        nbytes = report.nbytes
        meas = report.measured_tick_us()
    row = validate_ticks(_sched_for(kind, P, r), nbytes, fabric,
                         measured_ticks_us=meas, n_buckets=n_buckets,
                         itemsize=itemsize, monoid=monoid)
    return row


def model_error_table(reports, fabric, monoid=None) -> List[dict]:
    """One model-error row per traced replay, stably ordered by cell."""
    rows = [validate_replay(rep, fabric, monoid=monoid) for rep in reports]
    rows.sort(key=lambda r: (r["kind"], r["r"], r["n_buckets"],
                             r["nbytes"]))
    return rows


def validate_overlap(sched, nbytes: int, fabric, *,
                     compute_us: float,
                     measured_exposed_us: float,
                     n_buckets: int = 1, itemsize: int = 1,
                     monoid=None) -> dict:
    """Predicted-vs-measured overlay for the *exposed* communication of
    one backward-overlapped dispatch.

    The model side is :func:`repro.core.cost_model.overlap_tick_costs`:
    the collective's per-tick timeline with ``compute_us`` of
    overlappable backward compute drained across it, reduced to the
    exposed total.  The measured side is whatever the caller timed as
    the collective's un-hidden wallclock (the overlap benchmark derives
    it as ``t_overlap - t_compute``).  Same ratio/log2 convention as
    :func:`validate_ticks`, so :func:`fit_ratio` reduces a table of
    these rows to the overlap model's single-scale miscalibration.

    Golden property (the analogue of the validate_ticks doctest):
    feeding the model's own exposed total back as "measured" is exact.

    >>> from repro.core.cost_model import PAPER_10GE, overlap_exposed_cost
    >>> from repro.core.schedule import build_generalized
    >>> s = build_generalized(4, 1)
    >>> pred = overlap_exposed_cost(s, 4096, PAPER_10GE,
    ...                             compute_us=30.0) * 1e6
    >>> row = validate_overlap(s, 4096, PAPER_10GE, compute_us=30.0,
    ...                        measured_exposed_us=pred)
    >>> row["ratio"]
    1.0
    """
    from repro.core.cost_model import overlap_tick_costs
    rows = overlap_tick_costs(sched, nbytes, fabric, n_buckets,
                              compute_us=compute_us, itemsize=itemsize,
                              monoid=monoid)
    pred_exposed = sum(t["exposed_s"] for t in rows) * 1e6
    pred_hidden = sum(t["hidden_s"] for t in rows) * 1e6
    meas = max(float(measured_exposed_us), 0.0)
    ratio = meas / pred_exposed if pred_exposed else math.inf
    return {
        "kind": sched.kind, "r": sched.r, "P": sched.P,
        "n_buckets": int(n_buckets), "nbytes": int(nbytes),
        "n_ticks": len(rows),
        "compute_us": float(compute_us),
        "predicted_exposed_us": pred_exposed,
        "predicted_hidden_us": pred_hidden,
        "predicted_total_us": pred_exposed + pred_hidden,
        "measured_exposed_us": meas,
        "ratio": ratio,
        "log2_ratio": math.log2(ratio) if 0 < ratio < math.inf else None,
    }


def fit_ratio(rows: Sequence[dict]) -> Optional[float]:
    """Geometric-mean measured/predicted ratio over a table -- the single
    scale factor a fabric recalibration would apply."""
    logs = [r["log2_ratio"] for r in rows if r.get("log2_ratio") is not None]
    if not logs:
        return None
    return 2.0 ** (sum(logs) / len(logs))


def report_markdown(rows: Sequence[dict], *, title: str = "",
                    fabric_name: str = "") -> str:
    """Render a model-error table as a GitHub-markdown report."""
    out = []
    if title:
        out.append(f"## {title}")
        out.append("")
    if fabric_name:
        out.append(f"Fabric: `{fabric_name}`.  "
                   "`ratio` = measured / predicted total; "
                   "`log2` is the signed miscalibration in doublings.")
        out.append("")
    out.append("| kind | r | buckets | bytes | ticks | predicted us "
               "| measured us | ratio | log2 |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        l2 = r.get("log2_ratio")
        out.append(
            f"| {r['kind']} | {r['r']} | {r['n_buckets']} | {r['nbytes']} "
            f"| {r['n_ticks']} | {r['predicted_total_us']:.2f} "
            f"| {r['measured_total_us']:.2f} | {r['ratio']:.3f} "
            f"| {l2:+.2f} |" if l2 is not None else
            f"| {r['kind']} | {r['r']} | {r['n_buckets']} | {r['nbytes']} "
            f"| {r['n_ticks']} | {r['predicted_total_us']:.2f} "
            f"| {r['measured_total_us']:.2f} | {r['ratio']:.3f} | - |")
    gm = fit_ratio(rows)
    if gm is not None:
        out.append("")
        out.append(f"Geometric-mean ratio: **{gm:.3f}** "
                   f"(fabric scale miscalibration x{gm:.2f}).")
    return "\n".join(out) + "\n"
