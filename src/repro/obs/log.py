"""Structured leveled logging for the benchmark drivers and workers.

Two output channels with different contracts:

* :func:`data` -- protocol rows (``"executor,256KiB,pipelined,812.4"``)
  printed verbatim to **stdout**.  The benchmark drivers parse these by
  prefix (``benchmarks/run.py`` echoes worker lines starting with
  ``"<prefix>,"``), so they are not log records and are never filtered
  by level.
* :func:`debug` / :func:`info` / :func:`warn` / :func:`error` --
  diagnostics in logfmt (``ts=... level=... event=... k=v ...``) on
  **stderr**, filtered by the ``REPRO_LOG`` env var (default ``info``;
  ``REPRO_LOG=debug`` shows everything, ``REPRO_LOG=error`` almost
  nothing).

Why not :mod:`logging`: the workers are subprocesses whose stdout is a
machine-parsed CSV stream; a logger that any imported library can
reconfigure (root handlers, propagation) is a liability there.  This is
a ~60-line fixed-format writer with no global handler state.

>>> log = get_logger("doctest")
>>> log.level_name in LEVELS
True
"""
from __future__ import annotations

import os
import sys
import time
from typing import TextIO

LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}


def _env_level() -> int:
    name = os.environ.get("REPRO_LOG", "info").strip().lower()
    return LEVELS.get(name, LEVELS["info"])


def _fmt_value(v) -> str:
    if isinstance(v, float):
        s = f"{v:.6g}"
    else:
        s = str(v)
    if any(c in s for c in ' "='):
        s = '"' + s.replace('"', "'").replace("\n", " ") + '"'
    return s


class Logger:
    """One named logfmt writer; level re-read from ``REPRO_LOG`` lazily
    so tests (and long-lived drivers) can flip verbosity at runtime."""

    def __init__(self, name: str, stream: TextIO = None):
        self.name = name
        self._stream = stream

    @property
    def level(self) -> int:
        return _env_level()

    @property
    def level_name(self) -> str:
        lvl = self.level
        return next((n for n, v in LEVELS.items() if v == lvl), "info")

    def _emit(self, level: str, event: str, fields: dict) -> None:
        if LEVELS[level] < self.level:
            return
        stream = self._stream if self._stream is not None else sys.stderr
        parts = [
            f"ts={time.time():.3f}",
            f"level={level}",
            f"logger={self.name}",
            f"event={_fmt_value(event)}",
        ]
        parts += [f"{k}={_fmt_value(v)}" for k, v in fields.items()]
        print(" ".join(parts), file=stream, flush=True)

    def debug(self, event: str, **fields) -> None:
        self._emit("debug", event, fields)

    def info(self, event: str, **fields) -> None:
        self._emit("info", event, fields)

    def warn(self, event: str, **fields) -> None:
        self._emit("warn", event, fields)

    def error(self, event: str, **fields) -> None:
        self._emit("error", event, fields)


_loggers: dict = {}


def get_logger(name: str) -> Logger:
    """Named logger (cached; cheap enough to call at every site)."""
    lg = _loggers.get(name)
    if lg is None:
        lg = _loggers[name] = Logger(name)
    return lg


def data(line: str) -> None:
    """Emit one machine-parsed protocol row to stdout, unfiltered."""
    print(line, flush=True)
