"""Structured counters, gauges and histograms with a JSON snapshot format.

Three primitive kinds, one registry:

* :class:`Counter`   -- monotonic accumulator (bytes moved, combine
  FLOPs, requests served).  ``inc()`` rejects negative increments, so a
  snapshot sequence of any counter is non-decreasing by construction.
* :class:`Gauge`     -- last-written value (queue depth, live slots).
* :class:`Histogram` -- value distribution with exact count/sum/min/max
  and interpolated percentiles (p50/p90/p99 in the snapshot); sample
  storage is capped, the moments stay exact past the cap.

``Metrics.snapshot()`` returns a plain-JSON dict -- the format the
benchmark workers write under ``results/`` next to their traces -- and
``save()`` writes it with a schema marker so downstream tooling can
evolve.

>>> m = Metrics()
>>> m.counter("tx_bytes").inc(1024)
>>> m.histogram("latency_us").record_many([100.0, 200.0, 300.0])
>>> snap = m.snapshot()
>>> snap["counters"]["tx_bytes"]
1024
>>> snap["histograms"]["latency_us"]["p50"]
200.0
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Iterable, List, Optional

SNAPSHOT_SCHEMA = "repro-metrics-v1"


class Counter:
    """Monotonic counter; negative increments are a programming error."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, delta=1) -> None:
        if delta < 0:
            raise ValueError(
                f"counter {self.name!r} is monotonic; inc({delta}) rejected")
        with self._lock:
            self._value += delta

    @property
    def value(self):
        return self._value


class Gauge:
    """Last-written value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = None

    def set(self, value) -> None:
        self._value = value

    @property
    def value(self):
        return self._value


class Histogram:
    """Value distribution; exact moments, capped sample storage.

    Percentiles use linear interpolation over the sorted retained
    samples.  The cap (default 65536) only ever affects percentile
    resolution of pathologically long runs -- count/sum/min/max stay
    exact because they are tracked as running moments.
    """

    __slots__ = ("name", "_samples", "_cap", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, cap: int = 65536):
        self.name = name
        self._samples: List[float] = []
        self._cap = int(cap)
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = threading.Lock()

    def record(self, value) -> None:
        v = float(value)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = v if self._min is None else min(self._min, v)
            self._max = v if self._max is None else max(self._max, v)
            if len(self._samples) < self._cap:
                self._samples.append(v)

    def record_many(self, values: Iterable) -> None:
        for v in values:
            self.record(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, p: float) -> Optional[float]:
        """Interpolated percentile of the retained samples (p in 0..100)."""
        with self._lock:
            xs = sorted(self._samples)
        if not xs:
            return None
        if len(xs) == 1:
            return xs[0]
        rank = (min(max(p, 0.0), 100.0) / 100.0) * (len(xs) - 1)
        lo = int(rank)
        frac = rank - lo
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * frac

    def summary(self) -> dict:
        mean = self._sum / self._count if self._count else None
        return {
            "count": self._count,
            "sum": self._sum,
            "min": self._min,
            "max": self._max,
            "mean": mean,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class Metrics:
    """Registry of named counters/gauges/histograms + JSON snapshots."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, cap: int = 65536) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, cap)
            return h

    def snapshot(self, extra: Optional[dict] = None) -> dict:
        """Plain-JSON view of every registered metric.

        ``extra`` is merged in under its own keys (e.g. the
        predicted-vs-measured model-error table a benchmark attaches to
        its committed snapshot).
        """
        snap = {
            "schema": SNAPSHOT_SCHEMA,
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }
        if extra:
            for k, v in extra.items():
                snap[k] = v
        return snap

    def save(self, path: str, extra: Optional[dict] = None) -> str:
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.snapshot(extra), f, indent=2)
        return path

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


_metrics = Metrics()


def get_metrics() -> Metrics:
    """Process-global metrics registry."""
    return _metrics


def set_metrics(metrics: Metrics) -> Metrics:
    """Swap the global registry (tests); returns the previous one."""
    global _metrics
    prev, _metrics = _metrics, metrics
    return prev
