"""Arrival-pattern telemetry: per-participant timestamp deltas.

Proficz (arXiv:1804.05349) shows that *imbalanced process arrival
patterns* -- ranks reaching the collective at different times -- often
dominate allreduce wallclock in practice.  Before any PAP-aware
schedule can exist (ROADMAP item 4: sorted/pre-reduced variants that
let early arrivals start combining), the skew has to be *measured*.
This module is that measurement half:

* :class:`ArrivalRecorder` -- a host-side timestamp collector: each
  participant (device, worker process, request) calls
  :meth:`~ArrivalRecorder.record` when it reaches the rendezvous;
  :meth:`~ArrivalRecorder.stats` reduces the timestamps to deltas
  against the earliest arrival plus the max-min skew.  Pure stdlib, so
  multi-process workers can use it without importing jax.
* :func:`device_arrival_probe` -- an in-process probe over the visible
  jax devices: dispatches one identical tiny program per device
  asynchronously, then records each device's completion timestamp.  On
  forced-host virtual devices this measures scheduler-induced skew (the
  only kind that exists there); on a real multi-chip backend it
  measures per-chip readiness.  The tuning grid runs it per message
  size and persists the skew through the tuning cache
  (``Measurement.skew_us``).

>>> rec = ArrivalRecorder()
>>> for rank, ts in [(0, 10.0), (1, 10.5), (2, 12.0)]:
...     _ = rec.record(rank, ts_us=ts)
>>> st = rec.stats()
>>> st.skew_us
2.0
>>> st.deltas_us
(0.0, 0.5, 2.0)
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ArrivalStats:
    """Reduced arrival pattern of one rendezvous."""

    n: int                       # participants recorded
    deltas_us: Tuple[float, ...]  # per-rank arrival minus earliest, rank order
    skew_us: float               # max - min arrival (the PAP imbalance)
    mean_delta_us: float         # average lateness vs the earliest

    def to_dict(self) -> dict:
        return {"n": self.n, "deltas_us": list(self.deltas_us),
                "skew_us": self.skew_us,
                "mean_delta_us": self.mean_delta_us}


class ArrivalRecorder:
    """Collect per-participant arrival timestamps for one rendezvous.

    Ranks may record in any order and from any thread; re-recording a
    rank overwrites (the collective only cares about the *last* arrival
    before the operation fires).  Timestamps default to a monotonic
    microsecond clock shared by all participants in this process; a
    multi-process deployment passes its own synchronized ``ts_us``.
    """

    def __init__(self):
        self._ts: Dict[int, float] = {}
        self._lock = threading.Lock()

    def record(self, rank: int, ts_us: Optional[float] = None) -> float:
        ts = time.perf_counter_ns() / 1e3 if ts_us is None else float(ts_us)
        with self._lock:
            self._ts[int(rank)] = ts
        return ts

    @property
    def n(self) -> int:
        return len(self._ts)

    def stats(self) -> ArrivalStats:
        with self._lock:
            items = sorted(self._ts.items())
        if not items:
            return ArrivalStats(0, (), 0.0, 0.0)
        ts = [t for _, t in items]
        t0 = min(ts)
        deltas = tuple(round(t - t0, 3) for t in ts)
        return ArrivalStats(
            n=len(ts), deltas_us=deltas,
            skew_us=round(max(ts) - t0, 3),
            mean_delta_us=round(sum(deltas) / len(deltas), 3))

    def clear(self) -> None:
        with self._lock:
            self._ts.clear()


def device_arrival_probe(nbytes: int = 1 << 16, reps: int = 3,
                         devices=None) -> ArrivalStats:
    """Measure per-device completion skew of one identical dispatch.

    For each rep: put one ``nbytes`` buffer on every device, dispatch
    the same trivial jitted program on all of them back-to-back
    (asynchronously), then block on each device **in submission order**
    and record its completion timestamp.  The rep with the smallest
    skew is kept -- transient host noise only ever *adds* skew, so the
    minimum is the floor the fabric itself imposes.

    Returns an :class:`ArrivalStats` whose rank order is the device
    order.  Requires jax; with a single device the skew is trivially 0.
    """
    import jax
    import numpy as np

    devs = list(devices if devices is not None else jax.devices())
    n_elems = max(int(nbytes) // 4, 1)
    host = np.arange(n_elems, dtype=np.float32)
    bufs = [jax.device_put(host, d) for d in devs]
    fn = jax.jit(lambda v: v * 2.0 + 1.0)
    for b in bufs:
        jax.block_until_ready(fn(b))            # compile/warm every device

    best: Optional[ArrivalStats] = None
    for _ in range(max(int(reps), 1)):
        outs = [fn(b) for b in bufs]            # async dispatch, all devices
        rec = ArrivalRecorder()
        for rank, out in enumerate(outs):
            jax.block_until_ready(out)
            rec.record(rank)
        st = rec.stats()
        if best is None or st.skew_us < best.skew_us:
            best = st
    return best
