"""Measured autotuning: time the candidate grid on the running backend.

The analytic model ranks schedules; this module *times* them.  For each
message-size bucket it jits every candidate ``(kind, r, n_buckets)`` as
the same shard_map ppermute program the real executor runs, verifies it
against ``lax.psum`` once, and times all candidates interleaved
round-robin (best-of-``reps``), so machine-load drift hits every
candidate equally -- the timing discipline of
``benchmarks/executor_worker.py``.  Results are recorded into the
persistent :class:`~repro.tuning.cache.TuningCache` under the running
backend's fingerprint and summarized into a JSON payload for
``results/tuning.json``.

The size grid includes *ragged* entries (element counts coprime with the
device count) so the table measures the executor's exact-split path on
true moved bytes; the analytic pick reported next to each winner prices
those sizes with the ragged cost model
(:func:`repro.core.cost_model.ragged_schedule_cost`).

Requires more than one jax device in-process; the CLI driver
(``benchmarks/run.py tune``) spawns a worker with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for that.
"""

from __future__ import annotations

import os
import time
from dataclasses import asdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.autotune import choose
from repro.core.cost_model import HOST_CPU, Fabric
from repro.core.monoid import MONOIDS
from repro.core.schedule import (build_dual_root, build_generalized,
                                 build_ring, build_traff_rounds, max_r)
from repro.obs import trace as obs_trace
from repro.obs.log import data, get_logger
from repro.obs.skew import device_arrival_probe

from .cache import Measurement, TuningCache, current_fingerprint
from .policy import (NOISE_THRESHOLD, SKEW_THRESHOLD_US, skewed_cells,
                     unstable_cells)

_log = get_logger("repro.tuning.measure")

Candidate = Tuple[str, int, int]  # (kind, r, n_buckets)

# combine operators the grid times; each op gets its own measurements
# (policy lookups never answer across operators).  max covers the whole
# non-sum family: min/mean run the identical executor with one
# comparison/divide swapped, so their wallclock is max's.
GRID_OPS: Tuple[str, ...] = ("sum", "max")

# candidates whose per-bucket chunk would shrink below this are skipped:
# dispatch overhead dominates and the measurement is pure noise
MIN_BUCKET_CHUNK_BYTES = 8 * 1024

# "+36B" entries are deliberately *ragged*: 36 extra bytes = 9 extra f32
# elements, so the element count is coprime with the 8-device grid and
# the executor runs the balanced exact split -- these datapoints let the
# measured table pick different winners for badly-divisible sizes than
# the model's uniform-chunk ranking would.
SMOKE_SIZES: Sequence[Tuple[str, int]] = (
    ("64KiB", 64 << 10),
    ("64KiB+36B", (64 << 10) + 36),
    ("256KiB", 256 << 10),
)
FULL_SIZES: Sequence[Tuple[str, int]] = (
    ("64KiB", 64 << 10),
    ("64KiB+36B", (64 << 10) + 36),
    ("256KiB", 256 << 10),
    ("1MiB", 1 << 20),
    ("1MiB+36B", (1 << 20) + 36),
    ("4MiB", 4 << 20),
)


def candidate_grid(P: int, nbytes: int, *, smoke: bool = False) -> List[Candidate]:
    """Schedule kind x r x n_buckets grid for one message size.

    >>> candidate_grid(8, 1 << 20)[:3]
    [('generalized', 0, 1), ('generalized', 0, 2), ('generalized', 0, 4)]
    >>> [c for c in candidate_grid(8, 1 << 20) if c[0] == "ring"]
    [('ring', 0, 1), ('ring', 0, 2), ('ring', 0, 4)]
    >>> sorted({c[0] for c in candidate_grid(8, 1 << 20)})
    ['dual_root', 'generalized', 'ring', 'traff_rounds']
    """
    buckets = (1, 2) if smoke else (1, 2, 4)
    kinds: List[Tuple[str, int]] = [("generalized", r) for r in range(max_r(P) + 1)]
    kinds.append(("traff_rounds", 0))
    kinds.append(("dual_root", 0))
    kinds.append(("ring", 0))
    grid = []
    for kind, r in kinds:
        for b in buckets:
            if b > 1 and nbytes / P / b < MIN_BUCKET_CHUNK_BYTES:
                continue
            grid.append((kind, r, b))
    return grid


def _schedule(kind: str, P: int, r: int):
    if kind == "ring":
        return build_ring(P)
    if kind == "traff_rounds":
        return build_traff_rounds(P)
    if kind == "dual_root":
        return build_dual_root(P)
    return build_generalized(P, r)


def _bench_interleaved(variants: Dict[str, object], x, iters: int, reps: int):
    """(best, per_rep) round-robin timings: ``best[name]`` is the minimum
    per-call microseconds over reps, ``per_rep[name]`` every rep's own
    figure in rep order (the spread feeds ``Measurement.noise``)."""
    import jax

    for fn in variants.values():
        jax.block_until_ready(fn(x))  # warm-up / compile
    per_rep: Dict[object, List[float]] = {name: [] for name in variants}
    for _ in range(reps):
        for name, fn in variants.items():
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(x)
            jax.block_until_ready(out)
            per_rep[name].append((time.perf_counter() - t0) / iters * 1e6)
    best = {name: min(ts) for name, ts in per_rep.items()}
    return best, per_rep


def _noise(reps_us: Sequence[float]) -> float:
    """Relative rep-to-rep spread ``(max - min) / min`` of one cell."""
    if not reps_us:
        return 0.0
    lo = min(reps_us)
    return (max(reps_us) - lo) / lo if lo > 0 else 0.0


def run_tuning(
    *,
    smoke: bool = False,
    out: Optional[str] = None,
    cache_path: Optional[os.PathLike] = None,
    model_fabric: Fabric = HOST_CPU,
    iters: Optional[int] = None,
    reps: int = 3,
) -> dict:
    """Measure the grid, update the persistent cache, return the summary.

    ``out`` additionally writes the summary JSON (``results/tuning.json``).
    ``model_fabric`` is only used to report the analytic model's pick next
    to the measured winner -- measurements never depend on it.
    """
    import json

    import jax
    import numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P_

    from repro.compat import shard_map
    from repro.core.allreduce import allreduce_flat

    n = len(jax.devices())
    if n < 2:
        raise RuntimeError(
            "measured tuning needs >= 2 devices; launch via "
            "'python benchmarks/run.py tune' which forces 8 host devices"
        )
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    sizes = SMOKE_SIZES if smoke else FULL_SIZES
    iters = iters if iters is not None else (2 if smoke else 5)

    def jit_collective(fn):
        return jax.jit(
            shard_map(
                lambda v: fn(v[0])[None],
                mesh=mesh,
                in_specs=P_("data", None),
                out_specs=P_("data", None),
            )
        )

    fp = current_fingerprint()
    cache = TuningCache.load(cache_path)
    tracer = obs_trace.get_tracer()
    results = []
    refs = {
        "sum": lambda v: lax.psum(v, "data"),
        "max": lambda v: lax.pmax(v, "data"),
        "min": lambda v: lax.pmin(v, "data"),
    }
    for label, nbytes in sizes:
        m = nbytes // 4
        x = rng.standard_normal((n, m)).astype(np.float32)
        grid = candidate_grid(n, nbytes, smoke=smoke)
        # arrival-skew telemetry for this message size: how unevenly the
        # devices come ready for one identical dispatch (persisted per
        # measurement so PAP-aware scheduling has real data to start from:
        # the full per-device profile feeds policy.arrival_deltas and from
        # there the skew-aware path of autotune.choose)
        try:
            arr = device_arrival_probe(nbytes=nbytes)
            skew_us = arr.skew_us
            deltas_us = arr.deltas_us if len(arr.deltas_us) == n else None
        except Exception as e:  # never let telemetry sink a tuning run
            _log.warn("arrival_probe_failed", size=label, error=repr(e))
            skew_us = None
            deltas_us = None
        tracer.counter("arrival_skew_us", skew_us if skew_us is not None
                       else 0.0, cat="tuning")
        for op in GRID_OPS:
            monoid = MONOIDS[op]
            variants = {}
            for kind, r, b in grid:
                sched = _schedule(kind, n, r)
                variants[(kind, r, b)] = jit_collective(
                    lambda v, s=sched, nb=b, mo=monoid: allreduce_flat(
                        v, "data", s, n_buckets=nb, combine=mo
                    )
                )
            with tracer.span("tune.verify", cat="tuning", size=label, op=op,
                             n_candidates=len(variants)):
                ref = np.asarray(jit_collective(refs[op])(x))[0]
                for name, fn in variants.items():
                    np.testing.assert_allclose(
                        np.asarray(fn(x))[0],
                        ref,
                        rtol=1e-5,
                        atol=1e-5,
                        err_msg=f"candidate {op}:{name} disagrees with lax.p{op}",
                    )
            with tracer.span("tune.bench", cat="tuning", size=label, op=op,
                             iters=iters, reps=reps) as sp:
                timed, per_rep = _bench_interleaved(variants, x, iters, reps)
                sp.set(best_us=min(timed.values()))
            meas_rows = []
            for (kind, r, b), us in sorted(timed.items(), key=lambda kv: kv[1]):
                reps_us = tuple(round(t, 3) for t in per_rep[(kind, r, b)])
                noise = round(_noise(reps_us), 4)
                meas = Measurement(
                    P=n, nbytes=nbytes, kind=kind, r=r, n_buckets=b, us=us,
                    itemsize=4,  # the grid times f32 buffers
                    op=op,
                    reps_us=reps_us,
                    noise=noise,
                    skew_us=skew_us,
                    deltas_us=deltas_us,
                )
                cache.record(fp, meas)
                meas_rows.append(asdict(meas))
                data(f"tune,{label},{op},{kind},r={r},b={b},{us:.1f}")
                if noise > NOISE_THRESHOLD:
                    _log.warn("noisy_cell", size=label, op=op, kind=kind,
                              r=r, n_buckets=b, noise=noise)
            win = meas_rows[0]
            # benchmarks run f32 buffers: raggedness is per-element
            # (itemsize=4); candidates are priced with the op's gamma
            model = choose(
                n, nbytes, model_fabric, tune=False, itemsize=4, monoid=monoid
            )
            results.append(
                {
                    "label": label,
                    "bytes": nbytes,
                    "op": op,
                    "measured_winner": {
                        k: win[k] for k in ("kind", "r", "n_buckets", "us")
                    },
                    "model_pick": {
                        "kind": model.kind,
                        "r": model.r,
                        "n_buckets": model.n_buckets,
                        "model_us": round(model.cost * 1e6, 1),
                    },
                    "measurements": meas_rows,
                }
            )
    saved = cache.save(cache_path)
    all_meas = [Measurement.from_dict(m) for r_ in results
                for m in r_["measurements"]]
    unstable = unstable_cells(all_meas)
    if unstable:
        _log.warn("unstable_cells", count=len(unstable),
                  threshold=NOISE_THRESHOLD)
    skewed = skewed_cells(all_meas)
    if skewed:
        _log.warn("skewed_cells", count=len(skewed),
                  threshold_us=SKEW_THRESHOLD_US)
    payload = {
        "fingerprint": asdict(fp),
        "mode": "smoke" if smoke else "full",
        "model_fabric": model_fabric.name,
        "cache_path": str(saved),
        "noise_threshold": NOISE_THRESHOLD,
        "unstable_cells": unstable,
        "skew_threshold_us": SKEW_THRESHOLD_US,
        "skewed_cells": skewed,
        "notes": (
            "best-of-reps interleaved wallclock per call; candidates are the "
            "executor's own jitted shard_map programs, verified against "
            "lax.psum before timing. The cache keeps one figure per "
            "(fingerprint, P, size, kind, r, n_buckets) grid point."
        ),
        "results": results,
    }
    if out:
        os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        data(f"tune,WROTE,{out}")
    return payload
