"""Persistent on-disk tuning cache.

One JSON file holds every measurement this machine has ever taken, grouped
under a *backend fingerprint* (platform, device kind/count, jax version,
package version).  A measurement taken on 8 forced-host CPU devices under
jax 0.4.37 says nothing about a v5e pod under jax 0.6, so lookups only see
entries whose fingerprint matches the running backend exactly; stale
entries are kept on disk (they become live again when the matching backend
returns) but never consulted.

File handling rules:

* **location** -- ``REPRO_TUNING_CACHE`` env var when set, else
  ``$XDG_CACHE_HOME/repro-allreduce/tuning.json`` (``~/.cache`` fallback);
* **atomic writes** -- serialized to a temp file in the same directory and
  ``os.replace``d into place, so readers never observe a half-written
  table;
* **corrupt-file recovery** -- a truncated / garbage / wrong-schema file
  is moved aside to ``<path>.corrupt`` and treated as empty instead of
  raising; tuning degrades to the analytic model, it never breaks a run.
"""

from __future__ import annotations

import json
import os
import tempfile
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

SCHEMA_VERSION = 2
# Older schemas this reader still understands.  v1 rows lack the
# per-rep timings / noise / arrival-skew telemetry added in v2; they
# load with the v1 defaults (no rep detail, noise 0, no skew) and are
# rewritten as v2 on the next save.
COMPAT_VERSIONS = (1, SCHEMA_VERSION)


def _package_version() -> str:
    try:
        from importlib.metadata import version

        return version("repro-allreduce")
    except Exception:
        return "unknown"


@dataclass(frozen=True)
class Fingerprint:
    """Identity of the backend a measurement was taken on."""

    platform: str
    device_kind: str
    device_count: int
    jax_version: str
    package_version: str

    def key(self) -> str:
        return (
            f"{self.platform}|{self.device_kind}|{self.device_count}"
            f"|{self.jax_version}|{self.package_version}"
        )


def current_fingerprint() -> Fingerprint:
    """Fingerprint of the running backend (jax-free fallback: ``nojax``)."""
    try:
        import jax

        devs = jax.devices()
        return Fingerprint(
            platform=jax.default_backend(),
            device_kind=devs[0].device_kind if devs else "unknown",
            device_count=len(devs),
            jax_version=jax.__version__,
            package_version=_package_version(),
        )
    except Exception:
        return Fingerprint(
            platform="nojax",
            device_kind="none",
            device_count=0,
            jax_version="none",
            package_version=_package_version(),
        )


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return Path(env)
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache"
    )
    return Path(base) / "repro-allreduce" / "tuning.json"


@dataclass(frozen=True)
class Measurement:
    """One timed candidate: schedule family x pipelining x message size.

    ``itemsize`` records the element width of the benchmarked buffer
    (the grid runner times f32, so 4): raggedness is an *element*-count
    property, and a lookup must not answer a query whose element-ragged
    classification differs from what was measured.  ``op`` records the
    combine operator the grid timed ("sum" / "max" / ...): wallclock is
    op-specific in principle (different kernels, different fusion), so a
    lookup only consults measurements taken under its own operator.
    Entries written before either field existed load with the benchmark
    defaults (f32 sum grid).

    Schema v2 adds measurement-quality telemetry: ``reps_us`` keeps every
    rep's own best-of-iters figure (``us`` stays their minimum), ``noise``
    is the relative rep-to-rep spread ``(max - min) / min`` -- the figure
    :func:`repro.tuning.policy.unstable_cells` thresholds -- and
    ``skew_us`` is the per-device arrival skew the grid's probe observed
    around this measurement (None where not probed).  v1 rows load with
    all three absent/zero.

    ``deltas_us`` keeps the probe's full per-device arrival profile (one
    microsecond delta per device, min at 0) behind the scalar
    ``skew_us``: :func:`repro.tuning.policy.arrival_deltas` feeds it to
    the skew-aware path of :func:`repro.core.autotune.choose`.  Additive
    on schema v2 -- rows written before it existed load with ``None``.
    """

    P: int
    nbytes: int
    kind: str  # schedule family: "generalized" | "ring" | "traff_rounds" | ...
    r: int
    n_buckets: int
    us: float  # best-of-reps wallclock per call
    itemsize: int = 4  # element width of the measured buffer (f32 grid)
    op: str = "sum"  # combine operator the candidate was timed under
    reps_us: Optional[tuple] = None  # per-rep best-of-iters wallclocks
    noise: float = 0.0  # (max - min) / min over reps_us
    skew_us: Optional[float] = None  # device arrival skew near this cell
    deltas_us: Optional[tuple] = None  # per-device arrival deltas (probe)

    @property
    def ragged(self) -> bool:
        """Element count of the measured message does not divide P."""
        return (self.nbytes // max(self.itemsize, 1)) % self.P != 0

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        reps = d.get("reps_us")
        skew = d.get("skew_us")
        deltas = d.get("deltas_us")
        return cls(
            P=int(d["P"]),
            nbytes=int(d["nbytes"]),
            kind=str(d["kind"]),
            r=int(d["r"]),
            n_buckets=int(d["n_buckets"]),
            us=float(d["us"]),
            itemsize=int(d.get("itemsize", 4)),
            op=str(d.get("op", "sum")),
            reps_us=tuple(float(x) for x in reps) if reps else None,
            noise=float(d.get("noise", 0.0)),
            skew_us=float(skew) if skew is not None else None,
            deltas_us=tuple(float(x) for x in deltas) if deltas else None,
        )


@dataclass
class TuningCache:
    """In-memory view of the on-disk tuning table.

    >>> import os, tempfile
    >>> fp = Fingerprint("cpu", "host", 8, "0.4.37", "1.0.0")
    >>> cache = TuningCache()
    >>> cache.record(fp, Measurement(8, 1024, "generalized", 2, 1, 42.0))
    >>> cache.n_measurements
    1
    >>> path = cache.save(os.path.join(tempfile.mkdtemp(), "t.json"))
    >>> TuningCache.load(path).lookup(fp, 8)[0].us
    42.0
    """

    entries: Dict[str, dict] = field(default_factory=dict)
    path: Optional[Path] = None

    # ------------------------------------------------------------ loading
    @classmethod
    def load(cls, path: Optional[os.PathLike] = None) -> "TuningCache":
        """Load the cache at ``path`` (default: :func:`default_cache_path`).

        Any failure to read a well-formed schema-compatible table -- the
        file missing, truncated, non-JSON, or written by a different
        schema version -- yields an *empty* cache; corrupt files are moved
        aside to ``<path>.corrupt`` so the next save starts clean.
        """
        p = Path(path) if path is not None else default_cache_path()
        if not p.exists():
            return cls(path=p)
        try:
            with open(p) as f:
                raw = json.load(f)
            if not isinstance(raw, dict) or raw.get("version") not in COMPAT_VERSIONS:
                raise ValueError(f"unsupported tuning-cache schema in {p}")
            entries = raw["entries"]
            for ent in entries.values():
                Fingerprint(**ent["fingerprint"])  # validate shape
                for m in ent["measurements"]:
                    Measurement.from_dict(m)
        except Exception:
            _quarantine(p)
            return cls(path=p)
        return cls(entries=entries, path=p)

    # ------------------------------------------------------------ writing
    def record(self, fp: Fingerprint, meas: Measurement) -> None:
        """Insert/overwrite one measurement under ``fp``.

        Re-measuring the same candidate at the same size replaces the old
        number -- the table keeps one (latest) figure per grid point.
        """
        ent = self.entries.setdefault(
            fp.key(), {"fingerprint": asdict(fp), "measurements": []}
        )
        ident = _row_ident(asdict(meas))
        ent["measurements"] = [m for m in ent["measurements"] if _row_ident(m) != ident]
        ent["measurements"].append(asdict(meas))

    def save(self, path: Optional[os.PathLike] = None) -> Path:
        """Atomically write the table (temp file + ``os.replace``)."""
        p = Path(path) if path is not None else (self.path or default_cache_path())
        p.parent.mkdir(parents=True, exist_ok=True)
        payload = {"version": SCHEMA_VERSION, "entries": self.entries}
        fd, tmp = tempfile.mkstemp(dir=p.parent, prefix=p.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, p)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return p

    # ------------------------------------------------------------ queries
    def lookup(self, fp: Fingerprint, P: int) -> List[Measurement]:
        """All measurements for ``P`` devices under exactly ``fp``."""
        ent = self.entries.get(fp.key())
        if ent is None:
            return []
        out = [Measurement.from_dict(m) for m in ent["measurements"]]
        return [m for m in out if m.P == P]

    @property
    def n_measurements(self) -> int:
        return sum(len(e["measurements"]) for e in self.entries.values())


def _row_ident(m: dict) -> tuple:
    """Grid-point identity of one measurement row (operator included)."""
    return (m["P"], m["nbytes"], m["kind"], m["r"], m["n_buckets"], m.get("op", "sum"))


def _quarantine(p: Path) -> None:
    try:
        os.replace(p, p.with_suffix(p.suffix + ".corrupt"))
    except OSError:
        pass
