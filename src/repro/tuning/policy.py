"""Answer ``choose()``-style queries from the measured tuning table.

The policy layer is the read side of the tuning subsystem: given (P,
message size) it returns the measured-fastest ``Choice`` for the *running
backend*, or ``None`` when no compatible measurement exists -- the caller
(:func:`repro.core.autotune.choose`) then falls back to the analytic
alpha-beta-gamma model.  "Compatible" means the cache entry's backend
fingerprint matches :func:`~repro.tuning.cache.current_fingerprint`
exactly and the requested size sits within (or near) the measured range.

Size handling is nearest-size interpolation: costs for each candidate
``(kind, r, n_buckets)`` are interpolated log-linearly in message size
between the two bracketing measured sizes; outside the measured range the
nearest endpoint is used, but only up to a factor of
``MAX_EXTRAPOLATION_RATIO`` -- a 64 KiB measurement is not allowed to
decide a 1 GiB allreduce.
"""

from __future__ import annotations

import math
import os
from typing import Dict, List, Optional, Tuple

from repro.core.autotune import Choice

from .cache import (
    Fingerprint,
    Measurement,
    TuningCache,
    current_fingerprint,
    default_cache_path,
)

# beyond this ratio between the requested and the nearest measured size,
# the table is considered to have no opinion and the model decides
MAX_EXTRAPOLATION_RATIO = 4.0

# a cell whose rep-to-rep spread (max - min) / min exceeds this is
# considered unstable: its best-of-reps figure may rank candidates by
# luck rather than by fabric, so the grid should be re-measured (the
# tuning summary and the metrics snapshot both surface these cells)
NOISE_THRESHOLD = 0.25

# arrival skew (max - min over the probe's per-device deltas) above this
# is surfaced in the tuning summary next to unstable_cells: it marks
# sizes where the barrier cost model is mispricing real dispatches and
# the skew-aware path of ``choose()`` has something to act on
SKEW_THRESHOLD_US = 100.0

# (path, mtime_ns, size) -> TuningCache; reloads automatically when the
# file changes (e.g. after `benchmarks/run.py tune` repopulates it)
_loaded: Dict[Tuple[str, int, int], TuningCache] = {}
_fingerprint: Optional[Fingerprint] = None


def invalidate() -> None:
    """Drop every in-process cache (tests / after re-measuring)."""
    _loaded.clear()
    global _fingerprint
    _fingerprint = None
    from repro.core import autotune

    autotune.clear_cache()


def _cached_fingerprint() -> Fingerprint:
    global _fingerprint
    if _fingerprint is None:
        _fingerprint = current_fingerprint()
    return _fingerprint


def _load(path: Optional[os.PathLike]) -> TuningCache:
    p = str(path) if path is not None else str(default_cache_path())
    try:
        st = os.stat(p)
        key = (p, st.st_mtime_ns, st.st_size)
    except OSError:
        key = (p, -1, -1)
    cache = _loaded.get(key)
    if cache is None:
        _loaded.clear()  # at most one live table per process
        cache = TuningCache.load(p)
        _loaded[key] = cache
    return cache


def lookup(
    P: int,
    nbytes: int,
    *,
    allow_ring: bool = True,
    itemsize: int = 1,
    op: str = "sum",
    fingerprint: Optional[Fingerprint] = None,
    cache_path: Optional[os.PathLike] = None,
    compute_overlap_us: Optional[float] = None,
) -> Optional[Choice]:
    """Measured-fastest ``Choice`` for an allreduce of ``nbytes`` over
    ``P`` devices, or ``None`` when the table has no compatible entry.
    ``allow_ring=False`` honors the caller's schedule-family exclusion:
    ring measurements are dropped before the argmin.  ``itemsize`` is
    the query's element width: only measurements whose element-ragged
    classification (see :attr:`~repro.tuning.cache.Measurement.ragged`)
    matches the query's are considered, so an f32-measured ragged
    winner never answers a uniform-geometry message of another dtype.
    ``op`` is the query's combine operator: only measurements timed
    under the same operator answer (the grid times each op it covers;
    an op with no measurements falls back to the analytic model).
    ``compute_overlap_us`` marks an overlap-hinted query (the
    backward-overlapped gradient sync ranks by *exposed* cost): the
    grid times standalone collectives with no compute running, so no
    measurement carries overlap context and a hinted query is never
    answered from the table -- always ``None``, model decides."""
    if P <= 1:
        return None
    if compute_overlap_us is not None:
        return None
    fp = fingerprint if fingerprint is not None else _cached_fingerprint()
    meas = _load(cache_path).lookup(fp, P)
    if not allow_ring:
        meas = [m for m in meas if m.kind != "ring"]
    if not meas:
        return None
    return best_measured(meas, nbytes, itemsize=itemsize, op=op)


def unstable_cells(
    meas: List[Measurement], threshold: float = NOISE_THRESHOLD
) -> List[dict]:
    """Grid cells whose measured noise exceeds ``threshold``.

    Returns one plain dict per flagged cell (sorted worst first) --
    the shape the tuning summary and the benchmark metrics snapshot
    embed verbatim.  Cells measured without rep detail (schema-v1 rows)
    have ``noise == 0`` and are never flagged.

    >>> from repro.tuning.cache import Measurement
    >>> meas = [Measurement(8, 1024, "generalized", 1, 1, 50.0,
    ...                     reps_us=(50.0, 90.0), noise=0.8),
    ...         Measurement(8, 1024, "ring", 0, 1, 80.0, noise=0.01)]
    >>> [c["kind"] for c in unstable_cells(meas)]
    ['generalized']
    """
    flagged = [
        {
            "P": m.P,
            "nbytes": m.nbytes,
            "kind": m.kind,
            "r": m.r,
            "n_buckets": m.n_buckets,
            "op": m.op,
            "us": m.us,
            "noise": m.noise,
            "reps_us": list(m.reps_us) if m.reps_us else None,
        }
        for m in meas
        if m.noise > threshold
    ]
    flagged.sort(key=lambda c: -c["noise"])
    return flagged


def arrival_deltas(
    P: int,
    nbytes: int,
    *,
    op: str = "sum",
    fingerprint: Optional[Fingerprint] = None,
    cache_path: Optional[os.PathLike] = None,
) -> Optional[Tuple[float, ...]]:
    """Per-device arrival deltas (microseconds) the tuning grid's probe
    recorded nearest to ``nbytes``, or ``None`` when the table has none.

    This is the persisted-telemetry feed of the skew-aware path in
    :func:`repro.core.autotune.choose`: when a caller enables tuning but
    passes no live ``arrival_deltas_us``, the deltas measured alongside
    the nearest-size grid cell (same backend fingerprint, same combine
    operator, one delta per device) stand in.  Nearest is by log-size
    distance, capped at ``MAX_EXTRAPOLATION_RATIO`` like every other
    table answer.
    """
    if P <= 1:
        return None
    fp = fingerprint if fingerprint is not None else _cached_fingerprint()
    meas = _load(cache_path).lookup(fp, P)
    rows = [
        m
        for m in meas
        if m.op == op and m.deltas_us is not None and len(m.deltas_us) == P
    ]
    if not rows or nbytes <= 0:
        return None
    nearest = min(rows, key=lambda m: abs(math.log(m.nbytes) - math.log(nbytes)))
    ratio = max(nearest.nbytes, nbytes) / min(nearest.nbytes, nbytes)
    if ratio > MAX_EXTRAPOLATION_RATIO:
        return None
    return nearest.deltas_us


def skewed_cells(
    meas: List[Measurement], threshold_us: float = SKEW_THRESHOLD_US
) -> List[dict]:
    """Grid cells whose probed arrival skew exceeds ``threshold_us``.

    The companion of :func:`unstable_cells` for the *other* measurement
    hazard: ``unstable_cells`` flags noisy wallclock, this flags dispatch
    skew large enough that the skew-aware path of ``choose()`` may
    legitimately override the measured ranking.  One dict per flagged
    cell, worst first -- the shape the tuning summary embeds verbatim.

    >>> from repro.tuning.cache import Measurement
    >>> meas = [Measurement(8, 1024, "generalized", 1, 1, 50.0,
    ...                     skew_us=250.0, deltas_us=(0.0,) * 7 + (250.0,)),
    ...         Measurement(8, 1024, "ring", 0, 1, 80.0, skew_us=3.0)]
    >>> [c["kind"] for c in skewed_cells(meas)]
    ['generalized']
    """
    flagged = [
        {
            "P": m.P,
            "nbytes": m.nbytes,
            "kind": m.kind,
            "r": m.r,
            "n_buckets": m.n_buckets,
            "op": m.op,
            "skew_us": m.skew_us,
            "deltas_us": list(m.deltas_us) if m.deltas_us else None,
        }
        for m in meas
        if m.skew_us is not None and m.skew_us > threshold_us
    ]
    flagged.sort(key=lambda c: -c["skew_us"])
    return flagged


def best_measured(
    meas: List[Measurement],
    nbytes: int,
    *,
    itemsize: int = 1,
    op: str = "sum",
    compute_overlap_us: Optional[float] = None,
) -> Optional[Choice]:
    """Nearest-size interpolation over a measurement list (one backend,
    one P).  Exposed separately so tests can drive it without file I/O.
    Measurements whose element-ragged classification or combine operator
    differs from the query's are dropped *before* bracketing, so a
    query outside the measured range of its own class can never be
    answered by a wrong-class neighbor at the extrapolation boundary.
    ``compute_overlap_us`` marks an overlap-hinted query: no
    measurement carries overlap context, so it always returns ``None``
    (see :func:`lookup`).

    >>> from repro.tuning.cache import Measurement
    >>> meas = [Measurement(8, 1024, "generalized", 1, 1, 50.0),
    ...         Measurement(8, 1024, "ring", 0, 1, 80.0)]
    >>> c = best_measured(meas, 1024)
    >>> (c.kind, c.r, c.source)
    ('generalized', 1, 'measured')
    >>> best_measured(meas, 1 << 30) is None    # > 4x past the table
    True
    >>> best_measured(meas, 1024, compute_overlap_us=500.0) is None
    True
    """
    if not meas or nbytes <= 0 or compute_overlap_us is not None:
        return None
    ragged_q = (nbytes // max(int(itemsize), 1)) % meas[0].P != 0
    meas = [m for m in meas if m.ragged == ragged_q and m.op == op]
    if not meas:
        return None
    sizes = sorted({m.nbytes for m in meas})
    lo = max((s for s in sizes if s <= nbytes), default=None)
    hi = min((s for s in sizes if s >= nbytes), default=None)
    if lo is None:  # below the measured range: nearest is the smallest
        if hi / nbytes > MAX_EXTRAPOLATION_RATIO:
            return None
        lo = hi
    if hi is None:  # above the measured range: nearest is the largest
        if nbytes / lo > MAX_EXTRAPOLATION_RATIO:
            return None
        hi = lo

    at_lo = {(m.kind, m.r, m.n_buckets): m.us for m in meas if m.nbytes == lo}
    at_hi = {(m.kind, m.r, m.n_buckets): m.us for m in meas if m.nbytes == hi}
    best: Optional[Choice] = None
    for cand in set(at_lo) | set(at_hi):
        us_lo, us_hi = at_lo.get(cand), at_hi.get(cand)
        if us_lo is not None and us_hi is not None and hi != lo:
            t = (math.log(nbytes) - math.log(lo)) / (math.log(hi) - math.log(lo))
            us = us_lo + (us_hi - us_lo) * min(max(t, 0.0), 1.0)
        else:
            us = us_lo if us_lo is not None else us_hi
        cost = us * 1e-6
        if best is None or cost < best.cost:
            kind, r, n_buckets = cand
            best = Choice(kind, r, cost, n_buckets, source="measured")
    return best
