"""Measured autotuning: a persistent, fingerprint-keyed tuning database.

The analytic alpha-beta-gamma model predicts which schedule wins; this
subsystem *measures* it and remembers the answer:

* :mod:`~repro.tuning.measure` -- interleaved microbenchmarks over the
  candidate grid (schedule kind x r x n_buckets x message size);
* :mod:`~repro.tuning.cache` -- the versioned on-disk JSON table, keyed
  by a backend fingerprint, with atomic writes and corrupt-file recovery
  (location override: ``REPRO_TUNING_CACHE``);
* :mod:`~repro.tuning.policy` -- lookups with nearest-size interpolation;
  returns ``None`` (= fall back to the model) when nothing compatible is
  measured.

Opt in per call (``choose(..., tune=True)``), per run
(``ParallelConfig(tuning=True)``), or globally (``REPRO_TUNING=1``).
Populate the table with ``python benchmarks/run.py tune [--smoke]``.
"""

from .cache import (
    Fingerprint,
    Measurement,
    TuningCache,
    current_fingerprint,
    default_cache_path,
)
from .measure import candidate_grid, run_tuning
from .policy import best_measured, invalidate, lookup

__all__ = [
    "Fingerprint",
    "Measurement",
    "TuningCache",
    "best_measured",
    "candidate_grid",
    "current_fingerprint",
    "default_cache_path",
    "invalidate",
    "lookup",
    "run_tuning",
]
