"""The alpha-beta-gamma communication cost model (paper section 2).

tau_p2p = alpha + beta*m + gamma*m   for a message of m bytes:
  alpha -- per-message latency [s]
  beta  -- inverse bandwidth   [s/byte]
  gamma -- combine (reduction) speed [s/byte]

Closed forms from the paper (u = m / P):

  (15) naive/ring      : 2(P-1) a + 2(P-1) u b + (P-1) u g
  (25) bandwidth-opt   : 2ceil(lg P) a + 2(P-1) u b + (P-1) u g
  (36) intermediate(r) : (2ceil(lg P)-r) a
                         + (2(P-1) + (2^r - 1)(ceil(lg P)-1)) u b
                         + ((P-1) + (2^r - 1)(2 ceil(lg P)-2)) u g
  (44) latency-opt     : ceil(lg P) a + P ceil(lg P) u b + P(2 ceil(lg P)-2) u g
  (37) optimal r       : lg(a / (m (b + 2g))) + lg(P / ((lg P - 1) ln 2))

In addition to the closed forms we provide *exact* schedule-derived costs
(:func:`schedule_cost`) counting the actual per-step traffic of a compiled
schedule -- the closed forms are worst-case bounds, the schedule-derived
cost is what the executor really does.  Tests assert the two agree.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from .monoid import Monoid
from .schedule import (Schedule, ShapeError, _place_chunk_table,
                       build_generalized, build_ring, n_steps_log,
                       ragged_sizes, ragged_step_units)


def _gamma(f: "Fabric", monoid: Optional[Monoid]) -> float:
    """Per-monoid combine speed: the fabric's gamma scaled by the
    operator's cost relative to a plain add (1.0 for every built-in --
    add/max/min are each one VPU instruction per element and the kernel
    is memory-bound; a custom monoid carries its own factor)."""
    return f.gamma * (monoid.gamma_scale if monoid is not None else 1.0)


@dataclass(frozen=True)
class Fabric:
    """Point-to-point network/compute parameters."""

    alpha: float          # latency [s]
    beta: float           # 1/bandwidth [s/B]
    gamma: float          # combine speed [s/B]
    name: str = "fabric"


# the 10GE cluster of the paper's Table 2
PAPER_10GE = Fabric(alpha=3e-5, beta=1e-8, gamma=2e-10, name="paper-10GE")

# TPU v5e-like ICI fabric: ~1us latency, ~50 GB/s per link,
# combine speed bounded by HBM (~819 GB/s, 3 bytes moved per combined byte).
TPU_V5E_ICI = Fabric(alpha=1e-6, beta=1.0 / 50e9, gamma=3.0 / 819e9,
                     name="tpu-v5e-ici")

# Forced-host-device CPU "fabric" (8 XLA host devices sharing DRAM):
# rendezvous-dominated latency, memcpy-bound transfers, and combines that
# cost about as much as the copies they read -- which is why the combine
# overlap of the pipelined executor matters there.
HOST_CPU = Fabric(alpha=5e-6, beta=1.0 / 8e9, gamma=1.0 / 16e9,
                  name="host-cpu")


def chunk_size(m: float, P: int) -> float:
    return m / P


# ---------------------------------------------------------------------------
#  closed forms from the paper
# ---------------------------------------------------------------------------

def tau_ring(P: int, m: float, f: Fabric) -> float:
    """Eq (15): Ring / naive schedule."""
    if P == 1:
        return 0.0
    u = chunk_size(m, P)
    return 2 * (P - 1) * f.alpha + 2 * (P - 1) * u * f.beta + (P - 1) * u * f.gamma


def tau_bw_optimal(P: int, m: float, f: Fabric) -> float:
    """Eq (25): bandwidth-optimal generalized algorithm (r=0)."""
    if P == 1:
        return 0.0
    u = chunk_size(m, P)
    L = n_steps_log(P)
    return 2 * L * f.alpha + 2 * (P - 1) * u * f.beta + (P - 1) * u * f.gamma


def tau_intermediate(P: int, m: float, r: int, f: Fabric) -> float:
    """Eq (36): r distribution steps removed, 0 <= r < ceil(lg P)."""
    if P == 1:
        return 0.0
    L = n_steps_log(P)
    if r >= L:
        return tau_latency_optimal(P, m, f)
    if r == 0:
        return tau_bw_optimal(P, m, f)
    u = chunk_size(m, P)
    a = (2 * L - r) * f.alpha
    b = (2 * (P - 1) + (2 ** r - 1) * (L - 1)) * u * f.beta
    g = ((P - 1) + (2 ** r - 1) * (2 * L - 2)) * u * f.gamma
    return a + b + g


def tau_latency_optimal(P: int, m: float, f: Fabric) -> float:
    """Eq (44): worst case for the latency-optimal version."""
    if P == 1:
        return 0.0
    u = chunk_size(m, P)
    L = n_steps_log(P)
    # the paper's worst-case gamma coefficient P(2L-2) degenerates to 0 at
    # L=1 (P=2), where each device still performs one add per result copy.
    g_coeff = P * max(2 * L - 2, L)
    return L * f.alpha + P * L * u * f.beta + g_coeff * u * f.gamma


def tau_recursive_doubling(P: int, m: float, f: Fabric) -> float:
    """Latency-optimal butterfly; for non-power-of-two P the standard
    reduce-to-power-of-two workaround adds a preparation + finalization
    exchange of the full vector (overhead 2m, +2 steps)."""
    if P == 1:
        return 0.0
    L = math.floor(math.log2(P))
    Pp = 1 << L
    t = L * f.alpha + L * m * f.beta + L * m * f.gamma
    if Pp != P:
        t += 2 * f.alpha + 2 * m * f.beta + m * f.gamma
    return t


def tau_recursive_halving(P: int, m: float, f: Fabric) -> float:
    """Bandwidth-optimal butterfly with the same power-of-two workaround."""
    if P == 1:
        return 0.0
    L = math.floor(math.log2(P))
    Pp = 1 << L
    u = m / Pp
    t = 2 * L * f.alpha + 2 * (Pp - 1) * u * f.beta + (Pp - 1) * u * f.gamma
    if Pp != P:
        t += 2 * f.alpha + 2 * m * f.beta + m * f.gamma
    return t


def tau_best_sota(P: int, m: float, f: Fabric) -> float:
    """min over Ring / Recursive Halving / Recursive Doubling (Fig. 1)."""
    return min(tau_ring(P, m, f), tau_recursive_halving(P, m, f),
               tau_recursive_doubling(P, m, f))


def tau_openmpi_policy(P: int, m: float, f: Fabric) -> float:
    """OpenMPI default: Recursive Doubling below 10 KB, Ring above."""
    return tau_recursive_doubling(P, m, f) if m < 10 * 1024 else tau_ring(P, m, f)


# ---------------------------------------------------------------------------
#  optimal r
# ---------------------------------------------------------------------------

def optimal_r_analytic(P: int, m: float, f: Fabric) -> int:
    """Eq (37), clamped to the valid range [0, ceil(lg P)]."""
    L = n_steps_log(P)
    if P <= 2 or m <= 0:
        return L
    denom = m * (f.beta + 2 * f.gamma)
    if denom <= 0:
        return L
    lgp = math.log2(P)
    if lgp <= 1:
        return L
    r = math.log2(f.alpha / denom) + math.log2(P / ((lgp - 1) * math.log(2)))
    return int(min(max(round(r), 0), L))


def optimal_r_search(P: int, m: float, f: Fabric) -> int:
    """argmin over eq (36) -- exact discrete search (cheap: L+1 options).

    >>> optimal_r_search(127, 425.0, PAPER_10GE)    # small msg: latency
    7
    >>> optimal_r_search(127, 2.0 ** 26, PAPER_10GE)  # huge msg: bandwidth
    0
    """
    L = n_steps_log(P)
    return min(range(L + 1), key=lambda r: tau_intermediate(P, m, r, f))


# ---------------------------------------------------------------------------
#  exact schedule-derived cost
# ---------------------------------------------------------------------------

def schedule_cost(sched: Schedule, m: float, f: Fabric,
                  monoid: Optional[Monoid] = None) -> float:
    """Exact alpha-beta-gamma cost of a compiled schedule.

    Counts the real per-device traffic: sum over steps of
    alpha + (n_tx * u) * beta + (n_adds * u) * gamma, with gamma scaled
    by the monoid's per-element combine cost (see :func:`_gamma`).
    """
    P = sched.P
    u = chunk_size(m, P)
    g = _gamma(f, monoid)
    t = 0.0
    for st in sched.steps:
        if st.n_tx == 0 and st.n_adds == 0:
            continue  # bookkeeping-only step
        t += f.alpha + st.n_tx * u * f.beta + st.n_adds * u * g
    return t


def ragged_schedule_cost(sched: Schedule, m: int, f: Fabric,
                         itemsize: int = 1,
                         monoid: Optional[Monoid] = None) -> float:
    """Exact alpha-beta-gamma cost of a schedule under the *ragged* split.

    :func:`schedule_cost` prices every transmitted unit at a uniform
    ``m / P``; for a message whose *element count* does not divide ``P``
    the executor's chunks have unequal sizes, and an SPMD step only
    takes as long as its slowest device.  This charges, per step, the
    true per-device moved and reduced bytes of the balanced exact split
    (via :func:`repro.core.schedule.ragged_step_units`) -- no padding
    bytes ever enter the price.  ``m`` is bytes and ``itemsize`` the
    element width: the executor splits *elements*, so the chunk geometry
    is ``ragged_sizes(m // itemsize, P)`` scaled back to bytes.  For
    messages whose element count divides ``P`` it equals
    :func:`schedule_cost` exactly.

    >>> from repro.core.schedule import build_reduce_scatter
    >>> s = build_reduce_scatter(8)
    >>> ragged_schedule_cost(s, 1 << 20, PAPER_10GE) == schedule_cost(
    ...     s, 1 << 20, PAPER_10GE)
    True
    >>> # 1 MiB + 1: the padded executor would move ceil-width units
    >>> ragged_schedule_cost(s, (1 << 20) + 1, PAPER_10GE) < schedule_cost(
    ...     s, 8 * (((1 << 20) + 1 + 7) // 8), PAPER_10GE)
    True
    """
    elems = max(int(m) // max(int(itemsize), 1), 0)
    tx_units, add_units = ragged_step_units(sched, elems)
    g = _gamma(f, monoid)
    t = 0.0
    for st, tx, add in zip(sched.steps, tx_units, add_units):
        if st.n_tx == 0 and st.n_adds == 0:
            continue  # bookkeeping-only step
        # alpha is charged even when every transmitted chunk is empty
        # (m < P): the SPMD executor still runs the ppermute rendezvous
        t += (f.alpha + tx * itemsize * f.beta
              + add * itemsize * g)
    return t


def ragged_tick_costs(sched: Schedule, m: int, f: Fabric,
                      n_buckets: int = 1,
                      itemsize: int = 1,
                      monoid: Optional[Monoid] = None) -> list:
    """Per-tick predicted cost breakdown of the (pipelined) replay.

    This is the model's *timeline*: one entry per executor tick, in
    tick order, each a dict with the tick's predicted seconds split
    into its alpha / wire / combine components plus the true moved and
    reduced bytes (max over devices, from
    :func:`repro.core.schedule.ragged_step_units` -- padding bytes
    never enter).  The observability layer overlays these on measured
    per-tick spans (:mod:`repro.obs.validate`), so the breakdown must
    stay exactly consistent with the scalar costs:

    * ``n_buckets <= 1``: one tick per live step, serially priced
      (``alpha + comm + combine`` -- a step's combine cannot overlap
      its own arrival); the totals sum to
      :func:`ragged_schedule_cost` exactly.
    * ``n_buckets > 1``: the software-pipelined tick loop of
      :func:`repro.core.execplan.execute` -- tick t runs step ``t - j``
      of bucket j, each tick pays ``alpha + max(comm, combine)`` over
      its active buckets, fill/drain included; totals sum to
      :func:`ragged_pipelined_schedule_cost` exactly.

    >>> from repro.core.schedule import build_generalized
    >>> s = build_generalized(4, 1)
    >>> ticks = ragged_tick_costs(s, 4096, PAPER_10GE)
    >>> len(ticks) == sum(1 for st in s.steps if st.n_tx or st.n_adds)
    True
    >>> total = sum(t["total_s"] for t in ticks)
    >>> abs(total - ragged_schedule_cost(s, 4096, PAPER_10GE)) < 1e-18
    True
    """
    elems = max(int(m) // max(int(itemsize), 1), 0)
    tx_units, add_units = ragged_step_units(sched, elems)
    g = _gamma(f, monoid)
    live = [(tx * itemsize, add * itemsize) for st, tx, add in
            zip(sched.steps, tx_units, add_units)
            if st.n_tx or st.n_adds]
    S = len(live)
    B = max(int(n_buckets), 1)
    ticks = []
    for tick in range(S + B - 1):
        tx_b = add_b = 0.0
        steps_active = []
        for j in range(B):
            s = tick - j
            if 0 <= s < S:
                steps_active.append(s)
                tx_b += live[s][0] / B
                add_b += live[s][1] / B
        comm = tx_b * f.beta
        comb = add_b * g
        total = f.alpha + (comm + comb if B == 1 else max(comm, comb))
        ticks.append({
            "tick": tick,
            "steps": steps_active,
            "alpha_s": f.alpha,
            "comm_s": comm,
            "combine_s": comb,
            "total_s": total,
            "tx_bytes": tx_b,
            "add_bytes": add_b,
        })
    return ticks


def ragged_pipelined_schedule_cost(sched: Schedule, m: int, f: Fabric,
                                   n_buckets: int,
                                   itemsize: int = 1,
                                   monoid: Optional[Monoid] = None) -> float:
    """Ragged analogue of :func:`pipelined_schedule_cost`: the bucketed
    replay splits every chunk column-wise into ``n_buckets`` equal
    slices, so each bucket carries ``1 / n_buckets`` of every true
    per-step byte count; ticks overlap comm and combine across buckets
    exactly as in the uniform model.  Defined as the sum of the
    per-tick timeline (:func:`ragged_tick_costs`), so the scalar and
    the breakdown can never drift apart."""
    if n_buckets <= 1:
        return ragged_schedule_cost(sched, m, f, itemsize, monoid)
    return sum(t["total_s"] for t in
               ragged_tick_costs(sched, m, f, n_buckets, itemsize, monoid))


# ---------------------------------------------------------------------------
#  overlap roofline (backward-overlapped gradient sync)
# ---------------------------------------------------------------------------

def overlap_tick_costs(sched: Schedule, m: int, f: Fabric,
                       n_buckets: int = 1, *,
                       compute_us: float = 0.0,
                       itemsize: int = 1,
                       monoid: Optional[Monoid] = None) -> List[dict]:
    """Per-tick timeline with an overlappable-compute budget drained
    across it: ``ragged_tick_costs`` rows extended with ``compute_s``
    (budget consumed at this tick), ``hidden_s`` (the part of the tick's
    cost hidden behind that compute) and ``exposed_s`` (the remainder on
    the critical path).

    ``compute_us`` is the backward compute available to hide this
    collective behind -- for the backward-overlapped gradient sync, the
    per-bucket backward time between this bucket's dispatch and the end
    of the backward pass.  The budget drains greedily in tick order
    (earlier ticks hide first, exactly how an async dispatch overlaps),
    so the invariants hold by construction:

    * every row's ``total_s`` equals the :func:`ragged_tick_costs` row
      (the overlay never re-prices the collective);
    * ``sum(exposed_s) == max(0, total_cost - compute_us * 1e-6)`` --
      the bucket-granularity roofline
      ``exposed_comm = max(0, comm - backward_compute_per_bucket)``.

    >>> from repro.core.schedule import build_generalized
    >>> s = build_generalized(4, 1)
    >>> rows = overlap_tick_costs(s, 4096, PAPER_10GE, compute_us=0.0)
    >>> [abs(r["exposed_s"] - r["total_s"]) < 1e-18 for r in rows]
    [True, True, True]
    >>> total = ragged_schedule_cost(s, 4096, PAPER_10GE)
    >>> half = total * 0.5e6
    >>> rows = overlap_tick_costs(s, 4096, PAPER_10GE, compute_us=half)
    >>> abs(sum(r["exposed_s"] for r in rows) - total * 0.5) < 1e-15
    True
    >>> rows = overlap_tick_costs(s, 4096, PAPER_10GE, compute_us=1e9)
    >>> sum(r["exposed_s"] for r in rows)
    0.0
    """
    budget = max(float(compute_us), 0.0) * 1e-6
    ticks = ragged_tick_costs(sched, m, f, n_buckets, itemsize, monoid)
    out = []
    for t in ticks:
        hidden = min(t["total_s"], budget)
        budget -= hidden
        row = dict(t)
        row["compute_s"] = hidden
        row["hidden_s"] = hidden
        row["exposed_s"] = t["total_s"] - hidden
        out.append(row)
    return out


def overlap_exposed_cost(sched: Schedule, m: int, f: Fabric,
                         n_buckets: int = 1, *,
                         compute_us: float = 0.0,
                         itemsize: int = 1,
                         monoid: Optional[Monoid] = None) -> float:
    """Exposed (non-hidden) seconds of a schedule dispatched with
    ``compute_us`` of overlappable backward compute still to run --
    the scalar the overlap-aware tuner ranks candidates by.  Equals
    ``max(0, ragged_pipelined_schedule_cost(...) - compute_us * 1e-6)``
    by the :func:`overlap_tick_costs` drain invariant.

    >>> from repro.core.schedule import build_generalized
    >>> s = build_generalized(4, 1)
    >>> overlap_exposed_cost(s, 4096, PAPER_10GE, compute_us=1e9)
    0.0
    >>> c0 = overlap_exposed_cost(s, 4096, PAPER_10GE, compute_us=0.0)
    >>> abs(c0 - ragged_schedule_cost(s, 4096, PAPER_10GE)) < 1e-18
    True
    """
    return sum(t["exposed_s"] for t in
               overlap_tick_costs(sched, m, f, n_buckets,
                                  compute_us=compute_us,
                                  itemsize=itemsize, monoid=monoid))


def pipelined_schedule_cost(sched: Schedule, m: float, f: Fabric,
                            n_buckets: int,
                            monoid: Optional[Monoid] = None) -> float:
    """Extended cost model: the schedule replayed over ``n_buckets``
    software-pipelined buckets of ``m / n_buckets`` bytes each.

    Tick ``t`` runs step ``t - j`` of bucket ``j`` (see
    :func:`repro.core.execplan.execute`).  Within a tick the wire time of
    one bucket overlaps the combine time of another, so the tick pays
    ``alpha + max(sum tx_bytes * beta, sum add_bytes * gamma)`` over its
    active buckets; the pipeline fill/drain cost is the ``n_buckets - 1``
    extra ticks.  With one bucket a step's combine cannot overlap its own
    arrival, so the cost degenerates to the serial
    :func:`schedule_cost` exactly.
    """
    if n_buckets <= 1:
        return schedule_cost(sched, m, f, monoid)
    P = sched.P
    u = chunk_size(m, P) / n_buckets
    g = _gamma(f, monoid)
    steps = [st for st in sched.steps if st.n_tx or st.n_adds]
    S = len(steps)
    t = 0.0
    for tick in range(S + n_buckets - 1):
        comm = comb = 0.0
        for j in range(n_buckets):
            s = tick - j
            if 0 <= s < S:
                comm += steps[s].n_tx * u * f.beta
                comb += steps[s].n_adds * u * g
        t += f.alpha + max(comm, comb)
    return t


def choose_n_buckets(sched: Schedule, m: float, f: Fabric,
                     max_buckets: int = 8,
                     min_bucket_bytes: float = 32 * 1024,
                     monoid: Optional[Monoid] = None) -> int:
    """argmin over the pipelined cost of the bucket count for ``m`` bytes.

    Buckets below ``min_bucket_bytes`` of per-chunk payload are never
    considered: the model's alpha term does not capture per-dispatch
    overheads that dominate tiny transfers, so the message must be big
    enough for the fill/drain latency to amortize.
    """
    if sched.P <= 1 or m <= 0:
        return 1
    best_b, best_c = 1, schedule_cost(sched, m, f, monoid)
    for b in range(2, max_buckets + 1):
        if chunk_size(m, sched.P) / b < min_bucket_bytes:
            break
        c = pipelined_schedule_cost(sched, m, f, b, monoid)
        if c < best_c:
            best_b, best_c = b, c
    return best_b


def ragged_choose_n_buckets(sched: Schedule, m: int, f: Fabric,
                            max_buckets: int = 8,
                            min_bucket_bytes: float = 32 * 1024,
                            itemsize: int = 1,
                            monoid: Optional[Monoid] = None) -> int:
    """argmin over the *ragged* pipelined cost of the bucket count; same
    small-bucket guard as :func:`choose_n_buckets`."""
    if sched.P <= 1 or m <= 0:
        return 1
    best_b, best_c = 1, ragged_schedule_cost(sched, m, f, itemsize, monoid)
    for b in range(2, max_buckets + 1):
        if chunk_size(m, sched.P) / b < min_bucket_bytes:
            break
        c = ragged_pipelined_schedule_cost(sched, m, f, b, itemsize, monoid)
        if c < best_c:
            best_b, best_c = b, c
    return best_b


# ---------------------------------------------------------------------------
#  arrival-skew timeline (imbalanced process arrival patterns,
#  Proficz arXiv:1804.05349)
# ---------------------------------------------------------------------------

def skewed_schedule_cost(sched: Schedule, m: int, f: Fabric,
                         deltas_us, itemsize: int = 1,
                         monoid: Optional[Monoid] = None) -> float:
    """Completion time of a schedule whose devices *arrive late*.

    ``deltas_us[d]`` is the arrival delta of physical device ``d``
    (microseconds after the earliest arrival -- the quantity
    :mod:`repro.obs.skew` measures).  The barrier models
    (:func:`ragged_schedule_cost` and friends) charge every step at the
    slowest device and are therefore *order-blind*: under them a late
    arrival always costs ``max(delta)`` extra, wherever it sits.  This
    model tracks readiness per ``(row, device)`` instead -- a device's
    step-k message departs when the *transmitted rows* are ready, not
    when its last inbound row of step k-1 has landed -- which exposes
    the schedule's real slack: lateness only propagates along chains of
    rows that are actually re-transmitted, so *where* a late device
    stands in the rank order changes the completion time.  That is the
    quantity :func:`choose_arrival_order` minimizes and the sorted
    schedule kind (:func:`repro.core.schedule.build_sorted_generalized`)
    realizes.

    Per step, a device's message pays ``alpha + true_tx_bytes * beta``
    (exact ragged chunk geometry, like :func:`ragged_schedule_cost`) and
    each combined row pays its own bytes at the monoid-scaled gamma.
    Returns seconds, measured from the earliest device's arrival.

    >>> s = build_generalized(8, 1)
    >>> zero = skewed_schedule_cost(s, 1 << 20, PAPER_10GE, [0.0] * 8)
    >>> zero <= ragged_schedule_cost(s, 1 << 20, PAPER_10GE)
    True
    >>> late = skewed_schedule_cost(s, 1 << 20, PAPER_10GE,
    ...                             [0, 0, 0, 0, 0, 0, 0, 400.0])
    >>> late >= zero
    True
    >>> shifted = skewed_schedule_cost(s, 1 << 20, PAPER_10GE,
    ...                                [100.0] * 8)
    >>> abs(shifted - zero - 100e-6) < 1e-12     # uniform delay shifts all
    True
    """
    import numpy as np
    P = sched.P
    deltas = [float(d) for d in deltas_us]
    if len(deltas) != P:
        raise ShapeError("skewed_schedule_cost needs one delta per device",
                         expected=P, actual=len(deltas))
    g_comb = _gamma(f, monoid)
    elems = max(int(m) // max(int(itemsize), 1), 0)
    sizes = np.asarray(ragged_sizes(elems, P), dtype=np.int64)
    tbl = _place_chunk_table(sched)
    # ready[row, d]: seconds at which device d's copy of row is usable
    ready = np.tile(np.asarray(deltas, dtype=np.float64) * 1e-6, (P, 1))
    rows = sched.initial_slots
    for st in sched.steps:
        arrive = None
        if st.n_tx:
            depart = ready[list(st.tx_rows)].max(axis=0)          # (P,)
            tx_bytes = sum(sizes[tbl[rows[ri].place]]
                           for ri in st.tx_rows) * itemsize       # (P,)
            perm = np.asarray(sched.group.perm(st.shift))
            arrive = np.empty(P, dtype=np.float64)
            arrive[perm] = depart + f.alpha + tx_bytes * f.beta
        nxt = np.empty((len(st.out), P), dtype=np.float64)
        for i, (op, meta) in enumerate(zip(st.out, st.out_slots)):
            if op.kind == "keep":
                nxt[i] = ready[op.res]
            elif op.kind == "recv":
                nxt[i] = arrive
            else:
                row_bytes = sizes[tbl[meta.place]] * itemsize     # (P,)
                nxt[i] = (np.maximum(ready[op.res], arrive)
                          + row_bytes * g_comb)
        ready = nxt
        rows = st.out_slots
    return float(ready.max())


def choose_arrival_order(P: int, r: int, m: int, f: Fabric,
                         deltas_us, itemsize: int = 1,
                         monoid: Optional[Monoid] = None,
                         sweeps: int = 3):
    """Rank order minimizing :func:`skewed_schedule_cost` under measured
    arrival deltas.  Returns ``(order, cost_s)`` with ``order[j]`` the
    physical device assigned to logical position ``j`` -- the argument
    :func:`repro.core.schedule.build_sorted_generalized` takes.

    Evaluating an order never rebuilds a schedule: a relabeled schedule
    with physical deltas is the base schedule with *logically permuted*
    deltas (conjugation, see :class:`repro.core.group.RelabeledGroup`),
    so candidates are priced on ``build_generalized(P, r)`` directly.
    Search is deterministic: seed with identity / arrival-ascending /
    arrival-descending, then pairwise-swap hill climbing (at most
    ``sweeps`` passes) -- the identity order is always a candidate, so
    the result is never worse than leaving the ranks alone.

    >>> deltas = [0, 0, 0, 0, 0, 800.0]
    >>> order, c = choose_arrival_order(6, 1, 1 << 20, PAPER_10GE, deltas)
    >>> c <= skewed_schedule_cost(build_generalized(6, 1), 1 << 20,
    ...                           PAPER_10GE, deltas)
    True
    >>> sorted(order)
    [0, 1, 2, 3, 4, 5]
    """
    base = build_generalized(P, r)
    deltas = [float(d) for d in deltas_us]
    if len(deltas) != P:
        raise ShapeError("choose_arrival_order needs one delta per device",
                         expected=P, actual=len(deltas))

    def cost(order):
        return skewed_schedule_cost(base, m, f,
                                    [deltas[p] for p in order],
                                    itemsize, monoid)

    asc = tuple(sorted(range(P), key=lambda p: (deltas[p], p)))
    best = min((tuple(range(P)), asc, tuple(reversed(asc))), key=cost)
    best_c = cost(best)
    for _ in range(max(int(sweeps), 0)):
        improved = False
        for i in range(P):
            for j in range(i + 1, P):
                cand = list(best)
                cand[i], cand[j] = cand[j], cand[i]
                c = cost(tuple(cand))
                if c < best_c * (1.0 - 1e-12):
                    best, best_c, improved = tuple(cand), c, True
        if not improved:
            break
    return best, best_c


# ---------------------------------------------------------------------------
#  all-to-all (pure data movement: alpha + beta only, never gamma)
# ---------------------------------------------------------------------------

def a2a_cost(P: int, m: float, f: Fabric, kind: str = "direct") -> float:
    """Exact alpha-beta cost of the schedule-driven all-to-all.

    Matches the compiled plan tables step for step
    (:func:`repro.core.execplan.compile_a2a_plan`): ``direct`` pays P-1
    steps of one u-byte row each; ``bruck`` pays ceil(lg P) steps, step
    k moving the rows whose displacement has bit k set.

    >>> a2a_cost(8, 8 * 1024.0, PAPER_10GE, "direct") > \
        a2a_cost(8, 8 * 1024.0, PAPER_10GE, "bruck")   # tiny: latency wins
    True
    """
    if P <= 1:
        return 0.0
    u = chunk_size(m, P)
    if kind == "direct":
        return (P - 1) * (f.alpha + u * f.beta)
    if kind == "bruck":
        t, n = 0.0, 1
        while n < P:
            rows = sum(1 for e in range(1, P) if e & n)
            t += f.alpha + rows * u * f.beta
            n <<= 1
        return t
    raise ValueError(f"unknown all-to-all kind {kind!r}")


def choose_a2a(P: int, m: float, f: Fabric) -> str:
    """Pick the cheaper all-to-all family for an ``m``-byte local buffer:
    Bruck's log-step combining for latency-bound small messages, the
    direct exchange's minimal traffic for bandwidth-bound large ones.

    >>> choose_a2a(127, 425.0, PAPER_10GE)
    'bruck'
    >>> choose_a2a(127, float(1 << 26), PAPER_10GE)
    'direct'
    """
    if P <= 2:
        return "direct"   # identical plans at P <= 2; direct is canonical
    return min(("direct", "bruck"), key=lambda k: a2a_cost(P, m, f, k))


def best_schedule(P: int, m: float, f: Fabric,
                  include_ring: bool = True):
    """Pick the best compiled schedule (kind, r) for the given message size
    by exact schedule-derived cost.  Returns (schedule, cost)."""
    cands = []
    for r in range(n_steps_log(P) + 1):
        s = build_generalized(P, r)
        cands.append((s, schedule_cost(s, m, f)))
    if include_ring and P > 1:
        s = build_ring(P)
        cands.append((s, schedule_cost(s, m, f)))
    return min(cands, key=lambda c: c[1])
