"""JAX executors for compiled collective schedules.

Maps the paper's permutation-composition communication model onto JAX SPMD:

* every communication operator ``t_g`` is a static ``lax.ppermute``
  (a cyclic shift for ``CyclicGroup`` -- the native pattern of a TPU ICI
  ring/torus; a pairwise exchange for ``HypercubeGroup``);
* every distributed vector is one ``(u,)`` row of per-device state;
* combines are local adds (optionally the Pallas ``fused_combine`` kernel).

All functions below must be called *inside* ``jax.shard_map`` (manual SPMD)
over the axis (or tuple of axes) being reduced.  The schedule is compiled
and verified ahead of trace time (see :mod:`repro.core.schedule`), so the
traced program is a straight-line sequence of ppermutes and adds that XLA's
latency-hiding scheduler can overlap with compute.

TPU adaptation note (vs. the paper's 10GE cluster): the cyclic group's
powers ``t^k`` are *multi-hop* on a physical ring when k > 1.  XLA lowers a
``collective-permute`` with shift k to k ring hops (or uses the torus'
wraparound links), so the per-step latency term alpha grows with the hop
distance.  The schedules still apply unchanged -- only the Fabric
parameters used by the autotuner change (alpha_step ~ alpha_link * hops).

Hierarchical path (multi-pod / multi-node): a flat schedule over the
flattened ``(pod, data)`` index pays DCN latency and bandwidth on *every*
step, because each cyclic shift moves some pair of ranks across the pod
boundary and the SPMD step completes only when the slowest transfer lands.
:func:`hierarchical_allreduce` instead replays a
:class:`~repro.topology.hierarchical.HierarchicalSchedule`: reduce-scatter
over the fast inner axis (``lax.ppermute`` over ``"data"`` only -- pure
ICI), then the generalized allreduce with tunable ``r`` over the slow
outer axis on a 1/inner-sized chunk (the only DCN traffic), then
all-gather back over the inner axis.  The flat-vs-hierarchical decision
and the outer ``r`` are autotuned per message size by
:func:`repro.topology.hierarchical.choose_collective`.
"""
from __future__ import annotations

import math
from functools import partial
from typing import (TYPE_CHECKING, Callable, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat

from .autotune import Choice, choose, schedule_for
from .cost_model import Fabric, TPU_V5E_ICI
from .schedule import (Schedule, build_all_gather, build_generalized,
                       build_reduce_scatter, build_ring)

if TYPE_CHECKING:  # repro.topology is the layer above this one; importing
    # it at module scope would cycle through repro.core.__init__, so the
    # executors below bind to it at call time.
    from repro.topology.fabric import Topology
    from repro.topology.hierarchical import HierarchicalSchedule

AxisName = Union[str, Tuple[str, ...]]


def axis_size(axis_name: AxisName) -> int:
    if isinstance(axis_name, (tuple, list)):
        return math.prod(compat.axis_size(a) for a in axis_name)
    return compat.axis_size(axis_name)


def _perm_for(sched: Schedule, shift: int):
    """ppermute pairs (src, dst): device d sends to t_shift(d)."""
    g = sched.group
    return [(d, g.apply(shift, d)) for d in range(sched.P)]


def _initial_row_table(sched: Schedule) -> np.ndarray:
    """tbl[row, d] = which local chunk device d puts in initial row."""
    P = sched.P
    R = len(sched.initial_slots)
    tbl = np.zeros((R, P), dtype=np.int32)
    for k in range(R):
        for d in range(P):
            tbl[k, d] = sched.chunk_of_initial_row(k, d)
    return tbl


def _final_row_table(sched: Schedule) -> np.ndarray:
    """tbl[c, d] = which final row holds reduced chunk c on device d."""
    P = sched.P
    tbl = np.full((P, P), -1, dtype=np.int32)
    for k in range(len(sched.final_slots)):
        for d in range(P):
            tbl[sched.final_chunk_index(k, d), d] = k
    assert (tbl >= 0).all()
    return tbl


def _run_steps(rows, sched: Schedule, axis_name: AxisName,
               add: Callable = jnp.add):
    """Replay the compiled steps on a per-device row list."""
    for st in sched.steps:
        if st.n_tx:
            tx = jnp.stack([rows[i] for i in st.tx_rows])
            rx = lax.ppermute(tx, axis_name, perm=_perm_for(sched, st.shift))
        new_rows = []
        for op in st.out:
            if op.kind == "keep":
                new_rows.append(rows[op.res])
            elif op.kind == "recv":
                new_rows.append(rx[op.arr])
            else:
                new_rows.append(add(rows[op.res], rx[op.arr]))
        rows = new_rows
    return rows


def _pad_to_chunks(x: jnp.ndarray, P: int):
    m = x.shape[0]
    u = -(-m // P)
    pad = u * P - m
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x.reshape(P, u), m


# ---------------------------------------------------------------------------
#  flat (1-D) collectives; call inside shard_map
# ---------------------------------------------------------------------------

def allreduce_flat(x: jnp.ndarray, axis_name: AxisName,
                   sched: Schedule, *, accum_dtype=None,
                   add: Callable = jnp.add) -> jnp.ndarray:
    """Generalized allreduce of a flat vector using a compiled schedule."""
    P = sched.P
    assert P == axis_size(axis_name), (P, axis_name)
    if P == 1:
        return x
    orig_dtype = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    chunks, m = _pad_to_chunks(x, P)                       # (P, u)
    d = _linear_axis_index(axis_name)
    init_tbl = jnp.asarray(_initial_row_table(sched))      # (R0, P)
    rows_idx = jnp.take(init_tbl, d, axis=1)               # (R0,)
    stacked = jnp.take(chunks, rows_idx, axis=0)           # (R0, u)
    rows = [stacked[i] for i in range(stacked.shape[0])]
    rows = _run_steps(rows, sched, axis_name, add=add)
    fin_tbl = jnp.asarray(_final_row_table(sched))         # (P, P)
    order = jnp.take(fin_tbl, d, axis=1)                   # (P,)
    out = jnp.take(jnp.stack(rows), order, axis=0)         # (P, u)
    out = out.reshape(-1)[:m]
    return out.astype(orig_dtype)


def reduce_scatter_flat(x: jnp.ndarray, axis_name: AxisName,
                        sched: Optional[Schedule] = None, *,
                        accum_dtype=None,
                        add: Callable = jnp.add) -> jnp.ndarray:
    """Reduction phase only: returns this device's fully reduced chunk.

    Device d ends up owning chunk d (canonical place-0 layout).  The input
    length must already be padded to a multiple of P.
    """
    P = axis_size(axis_name)
    if sched is None:
        sched = build_reduce_scatter(P)
    if P == 1:
        return x
    orig_dtype = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    assert x.shape[0] % P == 0, "reduce_scatter_flat needs padded input"
    chunks = x.reshape(P, -1)
    d = _linear_axis_index(axis_name)
    init_tbl = jnp.asarray(_initial_row_table(sched))
    rows_idx = jnp.take(init_tbl, d, axis=1)
    stacked = jnp.take(chunks, rows_idx, axis=0)
    rows = [stacked[i] for i in range(stacked.shape[0])]
    rows = _run_steps(rows, sched, axis_name, add=add)
    assert len(rows) == 1
    # final row place 0 => device d owns chunk d already.
    return rows[0].astype(orig_dtype)


def all_gather_flat(chunk: jnp.ndarray, axis_name: AxisName,
                    sched: Optional[Schedule] = None) -> jnp.ndarray:
    """Distribution phase only: device d contributes chunk d, all devices
    end with the concatenation of all chunks."""
    P = axis_size(axis_name)
    if sched is None:
        sched = build_all_gather(P)
    if P == 1:
        return chunk
    rows = [chunk]
    rows = _run_steps(rows, sched, axis_name)
    d = _linear_axis_index(axis_name)
    fin_tbl = jnp.asarray(_final_row_table(sched))
    order = jnp.take(fin_tbl, d, axis=1)
    return jnp.take(jnp.stack(rows), order, axis=0).reshape(-1)


def _linear_axis_index(axis_name: AxisName):
    return lax.axis_index(axis_name)


# ---------------------------------------------------------------------------
#  pytree API with bucketing + autotuned schedule choice
# ---------------------------------------------------------------------------

def _flatten_tree(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(np.prod(s)) if len(s) else 1 for s in shapes]
    dtypes = [l.dtype for l in leaves]
    if leaves:
        common = jnp.result_type(*dtypes)
        flat = jnp.concatenate([l.reshape(-1).astype(common) for l in leaves])
    else:
        flat = jnp.zeros((0,))
    return flat, (treedef, shapes, sizes, dtypes)


def _unflatten_tree(flat, spec):
    treedef, shapes, sizes, dtypes = spec
    leaves, off = [], 0
    for sh, sz, dt in zip(shapes, sizes, dtypes):
        leaves.append(flat[off:off + sz].reshape(sh).astype(dt))
        off += sz
    return jax.tree.unflatten(treedef, leaves)


def allreduce_tree(tree, axis_name: AxisName, *,
                   r: Optional[int] = None,
                   mean: bool = False,
                   fabric: Fabric = TPU_V5E_ICI,
                   accum_dtype=jnp.float32,
                   add: Callable = jnp.add):
    """Allreduce (sum or mean) a pytree of arrays over ``axis_name`` using
    the generalized algorithm.

    If ``r`` is None the step count is autotuned from the fabric parameters
    via the paper's eq (37) / exact search (section 8).  All leaves are
    fused into one flat buffer so the whole gradient pays the per-step
    latency once -- the standard "bucketing" trick.
    """
    P = axis_size(axis_name)
    if P == 1:
        return tree
    flat, spec = _flatten_tree(tree)
    nbytes = flat.size * flat.dtype.itemsize
    if r is None:
        ch = choose(P, int(nbytes), fabric)
        sched = schedule_for(ch, P)
    else:
        sched = build_generalized(P, r)
    out = allreduce_flat(flat, axis_name, sched,
                         accum_dtype=accum_dtype, add=add)
    if mean:
        out = out / P
    return _unflatten_tree(out, spec)


# ---------------------------------------------------------------------------
#  hierarchical collectives over multi-level fabrics
# ---------------------------------------------------------------------------

def hierarchical_allreduce_flat(x: jnp.ndarray, axis_names: Sequence[str],
                                hs: "HierarchicalSchedule", *,
                                accum_dtype=None,
                                add: Callable = jnp.add) -> jnp.ndarray:
    """Replay a :class:`HierarchicalSchedule` over the named mesh axes.

    ``axis_names`` are ordered outermost (slowest) first, aligned with
    ``hs.topology.levels``; every ppermute runs over exactly one axis, so
    inner-level steps never touch the outer (DCN) links.
    """
    topo = hs.topology
    assert len(axis_names) == topo.n_levels, (axis_names, topo.describe())
    for name, lvl in zip(axis_names, topo.levels):
        assert compat.axis_size(name) == lvl.size, \
            f"axis {name!r} size != topology level {lvl.name}[{lvl.size}]"
    if topo.P == 1:
        return x
    orig_dtype = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    m = x.shape[0]
    inner = topo.inner_size
    mp = -(-m // inner) * inner
    if mp != m:
        x = jnp.concatenate([x, jnp.zeros((mp - m,), x.dtype)])
    # reduce-scatter down the inner axes, innermost (fastest) first
    inner_axes = [axis_names[i] for i in hs.inner_levels]
    cur = x
    for sched, axis in zip(hs.rs, inner_axes):
        cur = reduce_scatter_flat(cur, axis, sched, add=add)
    # generalized allreduce of the chunk across the outer axis
    cur = allreduce_flat(cur, axis_names[0], hs.ar, add=add)
    # all-gather back up, reverse order
    for sched, axis in zip(hs.ag, reversed(inner_axes)):
        cur = all_gather_flat(cur, axis, sched)
    return cur[:m].astype(orig_dtype)


def hierarchical_allreduce(tree, axis_names: Sequence[str],
                           topology: "Topology", *,
                           r: Optional[int] = None,
                           mean: bool = False,
                           accum_dtype=jnp.float32,
                           add: Callable = jnp.add):
    """Allreduce (sum or mean) a pytree over hierarchical mesh axes.

    ``r`` tunes the outer-level step count; with ``r=None`` the plan
    (flat vs hierarchical, and the step count) is autotuned per message
    size from the per-level fabric parameters.  A flat plan executes the
    chosen schedule over the flattened axis tuple -- hierarchical is only
    used when the cost model says it wins.
    """
    from repro.topology.hierarchical import (HierarchicalSchedule,
                                             build_hierarchical,
                                             choose_collective,
                                             schedules_for_plan)
    P = topology.P
    if P == 1:
        return tree
    flat, spec = _flatten_tree(tree)
    nbytes = flat.size * flat.dtype.itemsize
    if r is None:
        plan = choose_collective(topology, int(nbytes))
        sched = schedules_for_plan(plan, topology)
    else:
        sched = build_hierarchical(topology, r)
    if isinstance(sched, HierarchicalSchedule):
        out = hierarchical_allreduce_flat(flat, tuple(axis_names), sched,
                                          accum_dtype=accum_dtype, add=add)
    else:
        out = allreduce_flat(flat, tuple(axis_names), sched,
                             accum_dtype=accum_dtype, add=add)
    if mean:
        out = out / P
    return _unflatten_tree(out, spec)


def psum_tree(tree, axis_name: AxisName, *, mean: bool = False):
    """XLA-native baseline for comparisons."""
    out = lax.psum(tree, axis_name)
    if mean:
        out = jax.tree.map(lambda x: x / axis_size(axis_name), out)
    return out


# ---------------------------------------------------------------------------
#  ZeRO-style helpers: reduce-scatter grads / all-gather params over DP axis
# ---------------------------------------------------------------------------

def tree_reduce_scatter(tree, axis_name: AxisName, *, mean: bool = False,
                        accum_dtype=jnp.float32):
    """Fuse a pytree into one buffer, reduce-scatter it, and return this
    device's (padded_size/P,) shard plus the spec needed to reassemble."""
    P = axis_size(axis_name)
    flat, spec = _flatten_tree(tree)
    m = flat.shape[0]
    u = -(-m // P)
    pad = u * P - m
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = reduce_scatter_flat(flat, axis_name, accum_dtype=accum_dtype)
    if mean and P > 1:
        shard = shard / P
    return shard, (spec, m)


def tree_all_gather(shard, spec_m, axis_name: AxisName):
    """Inverse of :func:`tree_reduce_scatter`."""
    spec, m = spec_m
    flat = all_gather_flat(shard, axis_name)
    return _unflatten_tree(flat[:m], spec)
