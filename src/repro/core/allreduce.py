"""JAX executors for compiled collective schedules.

Maps the paper's permutation-composition communication model onto JAX SPMD:

* every communication operator ``t_g`` is a static ``lax.ppermute``
  (a cyclic shift for ``CyclicGroup`` -- the native pattern of a TPU ICI
  ring/torus; a pairwise exchange for ``HypercubeGroup``);
* every distributed vector is one row of a single stacked ``(R, u)``
  per-device buffer;
* combines are fused local adds (the Pallas ``combine_n`` kernel on TPU).

All functions below must be called *inside* ``jax.shard_map`` (manual SPMD)
over the axis (or tuple of axes) being reduced.  The schedule is compiled
and verified ahead of trace time (see :mod:`repro.core.schedule`), then
lowered once into a dense :class:`~repro.core.execplan.ExecPlan` of static
numpy index tables (cached per schedule), so the traced program is a
straight-line sequence of static gathers, ppermutes and batched combines
that XLA's latency-hiding scheduler can overlap with compute.  The old
per-row Python replay (one ``(u,)`` array per live vector, restacked every
step) is gone -- :func:`repro.core.execplan.execute` is the only replay.

**Multi-bucket pipelining**: ``n_buckets > 1`` splits the message into
equal buckets that replay the same plan staggered by one step, so bucket
``k``'s ``ppermute`` is staged while bucket ``k-1``'s combines run (the
doubly-pipelined structure of Traeff, arXiv:2109.12626).  The autotuned
bucket count comes from the extended cost model
(:func:`repro.core.cost_model.pipelined_schedule_cost`), which charges
pipeline fill/drain latencies against the comm/combine overlap.

TPU adaptation note (vs. the paper's 10GE cluster): the cyclic group's
powers ``t^k`` are *multi-hop* on a physical ring when k > 1.  XLA lowers a
``collective-permute`` with shift k to k ring hops (or uses the torus'
wraparound links), so the per-step latency term alpha grows with the hop
distance.  The schedules still apply unchanged -- only the Fabric
parameters used by the autotuner change (alpha_step ~ alpha_link * hops).

Hierarchical path (multi-pod / multi-node): a flat schedule over the
flattened ``(pod, data)`` index pays DCN latency and bandwidth on *every*
step, because each cyclic shift moves some pair of ranks across the pod
boundary and the SPMD step completes only when the slowest transfer lands.
:func:`hierarchical_allreduce` instead replays a
:class:`~repro.topology.hierarchical.HierarchicalSchedule`: reduce-scatter
over the fast inner axis (``lax.ppermute`` over ``"data"`` only -- pure
ICI), then the generalized allreduce with tunable ``r`` over the slow
outer axis on a 1/inner-sized chunk (the only DCN traffic), then
all-gather back over the inner axis.  The flat-vs-hierarchical decision,
the outer ``r`` and the outer bucket count are autotuned per message size
by :func:`repro.topology.hierarchical.choose_collective`.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import (TYPE_CHECKING, Callable, List, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat

from .autotune import choose, schedule_for
from .cost_model import (Fabric, TPU_V5E_ICI, choose_a2a, choose_n_buckets,
                         ragged_choose_n_buckets)
from .execplan import (ExecPlan, compile_a2a_plan, compile_plan, execute)
from .monoid import CombineLike, resolve_combine
from .schedule import (Schedule, ShapeError, build_all_gather,
                       build_generalized, build_reduce_scatter,
                       ragged_sizes)

if TYPE_CHECKING:  # repro.topology is the layer above this one; importing
    # it at module scope would cycle through repro.core.__init__, so the
    # executors below bind to it at call time.
    from repro.topology.fabric import Topology
    from repro.topology.hierarchical import HierarchicalSchedule

AxisName = Union[str, Tuple[str, ...]]
CombineFn = CombineLike   # legacy alias; combine= is monoid-aware now


def axis_size(axis_name: AxisName) -> int:
    if isinstance(axis_name, (tuple, list)):
        return math.prod(compat.axis_size(a) for a in axis_name)
    return compat.axis_size(axis_name)


# ---------------------------------------------------------------------------
#  ragged (exact-split) chunk plumbing
# ---------------------------------------------------------------------------
#  The balanced split of repro.core.schedule.ragged_sizes assigns chunk c
#  exactly sizes[c] elements (never rounding m up to a multiple of P).
#  ppermute rows must still be SPMD-uniform, so chunks share the physical
#  width u_max = ceil(m / P) with a zero-filled tail; combines only pair
#  rows holding the same chunk index per device, so the tails stay zero
#  and the final gather extracts each chunk's exact valid prefix.  For
#  divisible m every index table degenerates to a plain reshape.

def _build_extract_index(sizes: Tuple[int, ...], w: int) -> np.ndarray:
    idx = np.concatenate(
        [c * w + np.arange(s, dtype=np.int64)
         for c, s in enumerate(sizes)]) if sum(sizes) else \
        np.zeros((0,), np.int64)
    idx.setflags(write=False)
    return idx


_EXTRACT_CACHE_MAX_ELEMS = 1 << 20


@lru_cache(maxsize=64)
def _cached_extract_index(sizes: Tuple[int, ...], w: int) -> np.ndarray:
    return _build_extract_index(sizes, w)


def _ragged_extract_index(sizes: Tuple[int, ...], w: int) -> np.ndarray:
    """(sum(sizes),) indices extracting each chunk's valid prefix from a
    row-major ``(P, w)`` stacked buffer in chunk order.

    Only the general (caller-provided, possibly unbalanced) allgatherv
    path needs an index gather; the balanced split used everywhere else
    goes through the reshape-based :func:`exact_chunks` /
    :func:`_ragged_flatten`, which build no O(m) constants.  Caching is
    capped per entry (vectors above ``_EXTRACT_CACHE_MAX_ELEMS`` are
    rebuilt per call, never pinned) and by entry count; the worst-case
    resident set is maxsize * cap * 8 bytes, not unbounded.
    """
    if sum(sizes) > _EXTRACT_CACHE_MAX_ELEMS:
        return _build_extract_index(sizes, w)
    return _cached_extract_index(sizes, w)


def exact_chunks(x: jnp.ndarray, P: int):
    """Split a flat vector into the ``(P, u_max)`` chunk buffer of the
    balanced exact split (a plain reshape when ``P`` divides ``m``);
    returns ``(chunks, m)``.  Public counterpart of
    :func:`repro.core.schedule.ragged_sizes`: row ``c`` holds chunk
    ``c``'s ``sizes[c]`` valid elements, zero-filled to the common
    width.  Used by the executors here and by the zero1 optimizer to
    slice parameters with the same geometry as their gradient shards.

    The balanced split is two reshapes: the first ``rem = m % P`` chunks
    are full rows of width ``u + 1``, the rest are rows of width ``u``
    plus one zero column -- no O(m) gather or index constant.
    """
    m = x.shape[0]
    if m % P == 0 and m:
        return x.reshape(P, m // P), m
    u, rem = divmod(m, P)
    w = u + 1                                   # ceil(m / P); rem >= 1
    big = x[:rem * w].reshape(rem, w)
    small = x[rem * w:].reshape(P - rem, u) if u else \
        jnp.zeros((P - rem, 0), x.dtype)
    small = jnp.concatenate(
        [small, jnp.zeros((P - rem, 1), x.dtype)], axis=1)
    return jnp.concatenate([big, small], axis=0), m


def _ragged_flatten(stacked: jnp.ndarray, m: int) -> jnp.ndarray:
    """Inverse of :func:`exact_chunks` for a ``(P, w)`` buffer whose rows
    are reduced chunks in chunk order: exact ``(m,)`` concatenation
    (again two reshapes -- the zero tails are sliced off, not gathered).
    """
    P, w = stacked.shape
    if m == P * w:
        return stacked.reshape(-1)
    u, rem = divmod(m, P)
    if w != u + 1:
        raise ShapeError("_ragged_flatten: row width != ceil(m / P)",
                         expected=u + 1, actual=w)
    big = stacked[:rem].reshape(-1)
    small = stacked[rem:, :u].reshape(-1)
    return jnp.concatenate([big, small])


def _lazy_init_rows(chunks: jnp.ndarray, plan: ExecPlan, d) -> List:
    """Per-slot initial rows as *lazy* dynamic slices of the local chunk
    buffer: row k is ``chunks[init_rows[k, d]]``, left as a dynamic-slice
    op for XLA to fuse into its first consumer (the old executor
    materialized the whole (R0, u) gather up front).  Unwritten slots
    start as None."""
    rows: List = []
    for k in range(plan.n_rows0):
        idx = lax.dynamic_index_in_dim(jnp.asarray(plan.init_rows[k]), d,
                                       keepdims=False)
        rows.append(lax.dynamic_index_in_dim(chunks, idx, axis=0,
                                             keepdims=False))
    return rows + [None] * (plan.n_slots - plan.n_rows0)


def _bucket_rows(rows: List, n_buckets: int):
    """Split every slot row into n_buckets column slices (padding the
    row length to a multiple of the bucket count)."""
    u = next(r.shape[0] for r in rows if r is not None)
    n_buckets = max(1, min(int(n_buckets), u if u else 1))
    if n_buckets == 1:
        return [rows], u
    ub = -(-u // n_buckets)
    pad = ub * n_buckets - u

    def padded(r):
        return jnp.concatenate([r, jnp.zeros((pad,), r.dtype)]) if pad else r

    rows = [None if r is None else padded(r) for r in rows]
    return [[None if r is None else r[j * ub:(j + 1) * ub] for r in rows]
            for j in range(n_buckets)], u


def _merge_rows(bucket_rows: List[List], u: int) -> List:
    """Inverse of :func:`_bucket_rows`: full-width row per slot."""
    if len(bucket_rows) == 1:
        return bucket_rows[0]
    out = []
    for parts in zip(*bucket_rows):
        out.append(None if parts[0] is None
                   else jnp.concatenate(parts)[:u])
    return out


def _linear_axis_index(axis_name: AxisName):
    return lax.axis_index(axis_name)


def _final_gather(rows: List, plan: ExecPlan, d) -> jnp.ndarray:
    """One dynamic gather putting the reduced rows into chunk order.

    The final placement is device-dependent (chunk c sits in slot
    ``final_rows[c, d]``), so this pass cannot be static; the slot ->
    stack-position remap is, and composes with the table.
    """
    used = np.unique(plan.final_rows[plan.final_rows >= 0])
    pos = np.full(plan.n_slots, -1, dtype=np.int32)
    pos[used] = np.arange(len(used), dtype=np.int32)
    tbl = pos[plan.final_rows]                      # (P, P) stack positions
    full = jnp.stack([rows[int(s)] for s in used])
    order = jnp.take(jnp.asarray(tbl), d, axis=1)   # (P,)
    return jnp.take(full, order, axis=0)


# ---------------------------------------------------------------------------
#  flat (1-D) collectives; call inside shard_map
# ---------------------------------------------------------------------------

def allreduce_flat(x: jnp.ndarray, axis_name: AxisName,
                   sched: Schedule, *, accum_dtype=None,
                   combine: CombineFn = "auto",
                   n_buckets: int = 1,
                   tag: Optional[str] = None) -> jnp.ndarray:
    """Generalized allreduce of a flat vector using a compiled schedule.

    Accepts **any** length: uneven sizes run natively on the balanced
    exact split (chunk ``c`` carries ``sched.chunk_sizes(m)[c]``
    elements; the physical rows share the width ``ceil(m / P)`` with
    zero tails that the final gather drops).  ``n_buckets`` pipelines
    the message across equal buckets (see module docstring); ``combine``
    selects the combine *operator* (a Monoid, "sum" / "max" / "min" /
    "mean", or a binary callable) and/or its implementation ("auto",
    "add", "pallas" -- see
    :func:`repro.core.monoid.resolve_combine`).  Mean's divide and
    premul_sum's input scale run here, once over the whole message.
    ``tag`` labels the executor's trace span (see
    :func:`repro.core.execplan.execute`).
    """
    P = sched.P
    actual = axis_size(axis_name)
    if P != actual:
        raise ShapeError(f"schedule P != size of axis {axis_name!r}",
                         expected=P, actual=actual)
    monoid, _ = resolve_combine(combine)
    if P == 1:
        return monoid.finalize(monoid.prepare(x, P), P).astype(x.dtype)
    orig_dtype = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    x = monoid.prepare(x, P)
    chunks, m = exact_chunks(x, P)                        # (P, u_max)
    plan = compile_plan(sched)
    d = _linear_axis_index(axis_name)
    rows = _lazy_init_rows(chunks, plan, d)
    bucket_rows, u = _bucket_rows(rows, n_buckets)
    bucket_rows = execute(plan, bucket_rows, axis_name, combine=combine,
                          tag=tag)
    rows = _merge_rows(bucket_rows, u)
    out = _final_gather(rows, plan, d)                     # (P, u_max)
    out = _ragged_flatten(out, m)                          # exact (m,)
    out = monoid.finalize(out, P)
    return out.astype(orig_dtype)


def reduce_scatter_flat(x: jnp.ndarray, axis_name: AxisName,
                        sched: Optional[Schedule] = None, *,
                        accum_dtype=None,
                        combine: CombineFn = "auto",
                        n_buckets: int = 1) -> jnp.ndarray:
    """Reduction phase only: returns this device's fully reduced chunk.

    Device d ends up owning chunk d (canonical place-0 layout).  Any
    input length is accepted: under the balanced exact split device d's
    chunk is ``x[offsets[d] : offsets[d] + sizes[d]]`` with ``sizes =
    ragged_sizes(m, P)``; the returned buffer always has the physical
    width ``ceil(m / P)``, zero-filled past the valid prefix on devices
    whose chunk is one element short (for ``m`` divisible by ``P`` the
    whole buffer is valid, exactly as before).  Use
    :func:`all_gather_flat` with ``sizes=`` to reassemble exactly.
    ``combine`` selects the operator exactly as in
    :func:`allreduce_flat` (monoid bookends included).
    """
    P = axis_size(axis_name)
    if sched is None:
        sched = build_reduce_scatter(P)
    elif sched.P != P:
        raise ShapeError(f"schedule P != size of axis {axis_name!r}",
                         expected=sched.P, actual=P)
    monoid, _ = resolve_combine(combine)
    if P == 1:
        return monoid.finalize(monoid.prepare(x, P), P).astype(x.dtype)
    orig_dtype = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    x = monoid.prepare(x, P)
    chunks, _ = exact_chunks(x, P)
    plan = compile_plan(sched)
    d = _linear_axis_index(axis_name)
    rows = _lazy_init_rows(chunks, plan, d)
    bucket_rows, u = _bucket_rows(rows, n_buckets)
    bucket_rows = execute(plan, bucket_rows, axis_name, combine=combine)
    rows = _merge_rows(bucket_rows, u)
    # the single final row's slot is SPMD-uniform; canonical place-0
    # layout means device d already owns chunk d.
    slot = int(plan.final_rows.max())
    return monoid.finalize(rows[slot], P).astype(orig_dtype)


def all_gather_flat(chunk: jnp.ndarray, axis_name: AxisName,
                    sched: Optional[Schedule] = None, *,
                    n_buckets: int = 1,
                    sizes: Optional[Sequence[int]] = None) -> jnp.ndarray:
    """Distribution phase only: device d contributes chunk d, all devices
    end with the concatenation of all chunks.

    ``sizes`` turns this into an exact **allgatherv**: entry d is the
    valid prefix length of rank d's chunk (the physical rows stay
    uniform at ``chunk.shape[0]``), and the result is the exact
    ``sum(sizes)``-element concatenation of the prefixes -- the inverse
    of a ragged :func:`reduce_scatter_flat` when ``sizes =
    ragged_sizes(m, P)``.
    """
    P = axis_size(axis_name)
    if sched is None:
        sched = build_all_gather(P)
    elif sched.P != P:
        raise ShapeError(f"schedule P != size of axis {axis_name!r}",
                         expected=sched.P, actual=P)
    if sizes is not None:
        sizes = tuple(int(s) for s in sizes)
        if len(sizes) != P:
            raise ShapeError("all_gather_flat: sizes must have one entry "
                             "per rank", expected=P, actual=len(sizes))
        w = int(chunk.shape[0])
        if sizes and (max(sizes) > w or min(sizes) < 0):
            raise ShapeError("all_gather_flat: chunk valid prefix outside "
                             f"the physical row width {w}",
                             expected=f"0 <= size <= {w}",
                             actual=(min(sizes), max(sizes)))
    if P == 1:
        return chunk if sizes is None else chunk[:sizes[0]]
    plan = compile_plan(sched)
    rows = [chunk] + [None] * (plan.n_slots - 1)
    bucket_rows, u = _bucket_rows(rows, n_buckets)
    bucket_rows = execute(plan, bucket_rows, axis_name)
    rows = _merge_rows(bucket_rows, u)
    d = _linear_axis_index(axis_name)
    out = _final_gather(rows, plan, d)                     # (P, w)
    if sizes is None:
        return out.reshape(-1)
    total = sum(sizes)
    w = int(out.shape[1])
    if sizes == ragged_sizes(total, P) and \
            (total == P * w or w == total // P + 1):
        return _ragged_flatten(out, total)     # balanced: two reshapes
    idx = _ragged_extract_index(sizes, w)      # general allgatherv
    return jnp.take(out.reshape(-1), jnp.asarray(idx))


def all_to_all_flat(x: jnp.ndarray, axis_name: AxisName, *,
                    kind: str = "auto",
                    fabric: Fabric = TPU_V5E_ICI,
                    n_buckets: int = 1) -> jnp.ndarray:
    """Permutation-group all-to-all of a flat vector over ``axis_name``.

    Device ``d`` contributes ``P`` equal chunks ``x[c*u:(c+1)*u]``
    (chunk ``c`` destined for rank ``c``) and receives the concatenation
    of every rank's chunk ``d`` -- the exact transpose
    ``lax.all_to_all`` computes on equally-split buffers, replayed as
    the same static ``ppermute`` step tables the reductions use (see
    :func:`repro.core.execplan.compile_a2a_plan`).

    ``kind``: "direct" (P-1 single-row steps, minimal traffic),
    "bruck" (ceil(lg P) steps of ~P/2 rows, minimal latency), or
    "auto" -- picked per message size by the alpha-beta cost model
    (:func:`repro.core.cost_model.choose_a2a`).  ``n_buckets`` software-
    pipelines the exchange exactly like the reductions (there are no
    combines to overlap, but staging bucket ``k``'s ppermute behind
    bucket ``k-1``'s still splits the wire serialization on
    asynchronous fabrics).

    All-to-all is a pure permutation of P*P distinct blocks, so unlike
    the reductions it has no ragged form whose tails an SPMD program
    could drop uniformly: the length must divide ``P`` (the same
    contract as ``lax.all_to_all``), enforced as a typed
    :class:`~repro.core.schedule.ShapeError`.
    """
    P = axis_size(axis_name)
    if P == 1:
        return x
    m = x.shape[0]
    if m % P:
        raise ShapeError(
            f"all_to_all_flat needs P | m over axis {axis_name!r}",
            expected=f"multiple of {P}", actual=m)
    if kind == "auto":
        kind = choose_a2a(P, m * x.dtype.itemsize, fabric)
    plan = compile_a2a_plan(P, kind)
    chunks = x.reshape(P, m // P)
    d = _linear_axis_index(axis_name)
    rows = _lazy_init_rows(chunks, plan, d)
    bucket_rows, u = _bucket_rows(rows, n_buckets)
    bucket_rows = execute(plan, bucket_rows, axis_name)
    rows = _merge_rows(bucket_rows, u)
    return _final_gather(rows, plan, d).reshape(-1)


# ---------------------------------------------------------------------------
#  pytree API with bucketing + autotuned schedule choice
# ---------------------------------------------------------------------------

def _flatten_tree(tree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [leaf.shape for leaf in leaves]
    sizes = [int(np.prod(s)) if len(s) else 1 for s in shapes]
    dtypes = [leaf.dtype for leaf in leaves]
    if leaves:
        common = jnp.result_type(*dtypes)
        flat = jnp.concatenate([leaf.reshape(-1).astype(common)
                                for leaf in leaves])
    else:
        flat = jnp.zeros((0,))
    return flat, (treedef, shapes, sizes, dtypes)


def _unflatten_tree(flat, spec):
    treedef, shapes, sizes, dtypes = spec
    leaves, off = [], 0
    for sh, sz, dt in zip(shapes, sizes, dtypes):
        leaves.append(flat[off:off + sz].reshape(sh).astype(dt))
        off += sz
    return jax.tree.unflatten(treedef, leaves)


def allreduce_tree(tree, axis_name: AxisName, *,
                   r: Optional[int] = None,
                   mean: bool = False,
                   fabric: Fabric = TPU_V5E_ICI,
                   accum_dtype=jnp.float32,
                   combine: CombineFn = "auto",
                   n_buckets: Optional[int] = None,
                   tune: Optional[bool] = None,
                   compute_overlap_us: Optional[float] = None,
                   tag: Optional[str] = None):
    """Allreduce (sum or mean) a pytree of arrays over ``axis_name`` using
    the generalized algorithm.

    If ``r`` is None the step count is autotuned from the fabric parameters
    via the paper's eq (37) / exact search (section 8).  All leaves are
    fused into one flat buffer so the whole gradient pays the per-step
    latency once, then the buffer is *re-split* into ``n_buckets``
    pipelined buckets (``None`` = autotuned from the fabric via the
    extended cost model) so communication of bucket k overlaps combines
    of bucket k-1.  ``tune`` opts the autotuner into the measured tuning
    table (see :mod:`repro.tuning`; None reads ``REPRO_TUNING``).

    ``combine`` selects the operator for the whole family (any Monoid /
    "sum" / "max" / "min" / "mean" / callable): the autotuner prices
    candidates with the monoid's own gamma, and for non-add monoids the
    f32 accumulation cast is skipped (max/min lose nothing to the
    accumulator, and an int max must stay bit-exact past 2**24).
    ``mean`` composes only with the sum operator.

    ``compute_overlap_us`` is the backward-overlap hint forwarded to the
    autotuner (:func:`repro.core.autotune.choose`): the overlappable
    compute still running when this collective dispatches, which makes
    the chooser rank candidates by *exposed* rather than raw cost.  It
    only applies when the schedule is autotuned (``r is None``).
    ``tag`` labels the executor's trace span (per-bucket identification
    for the overlapped gradient sync).
    """
    P = axis_size(axis_name)
    monoid, _ = resolve_combine(combine)
    if mean and monoid.name not in ("sum", "mean"):
        raise ValueError(f"mean=True only composes with the sum operator, "
                         f"not {monoid.name!r}")
    if monoid.kind != "add":
        accum_dtype = None
    if P == 1:
        return tree
    flat, spec = _flatten_tree(tree)
    itemsize = int(flat.dtype.itemsize)
    nbytes = flat.size * itemsize
    if r is None:
        # raggedness is an *element*-count property: the executor splits
        # elements, so the chooser needs the itemsize, not just bytes
        ch = choose(P, int(nbytes), fabric, tune=tune, itemsize=itemsize,
                    monoid=monoid, compute_overlap_us=compute_overlap_us)
        sched = schedule_for(ch, P)
        if n_buckets is None:
            n_buckets = ch.n_buckets
    else:
        sched = build_generalized(P, r)
        if n_buckets is None:
            if flat.size % P:
                n_buckets = ragged_choose_n_buckets(sched, int(nbytes),
                                                    fabric,
                                                    itemsize=itemsize,
                                                    monoid=monoid)
            else:
                n_buckets = choose_n_buckets(sched, int(nbytes), fabric,
                                             monoid=monoid)
    out = allreduce_flat(flat, axis_name, sched, accum_dtype=accum_dtype,
                         combine=combine, n_buckets=n_buckets, tag=tag)
    if mean and monoid.name == "sum":
        out = out / P
    return _unflatten_tree(out, spec)


# ---------------------------------------------------------------------------
#  hierarchical collectives over multi-level fabrics
# ---------------------------------------------------------------------------

def hierarchical_allreduce_flat(x: jnp.ndarray, axis_names: Sequence[str],
                                hs: "HierarchicalSchedule", *,
                                accum_dtype=None,
                                combine: CombineFn = "auto",
                                n_buckets: int = 1) -> jnp.ndarray:
    """Replay a :class:`HierarchicalSchedule` over the named mesh axes.

    ``axis_names`` are ordered outermost (slowest) first, aligned with
    ``hs.topology.levels``; every ppermute runs over exactly one axis, so
    inner-level steps never touch the outer (DCN) links.  ``n_buckets``
    pipelines the outer-level allreduce -- the phase that rides the slow
    links and so profits most from comm/combine overlap.

    The monoid's affine bookends act on the *whole* composition, not per
    level: premul's scale is applied once before the first inner
    reduce-scatter and mean's divide once after the last all-gather
    (the per-level executors run the bookend-free core combine), so a
    2-level mesh scales by f -- not f^2 -- and mean divides by the full
    ``topology.P`` in one exact step.
    """
    topo = hs.topology
    if len(axis_names) != topo.n_levels:
        raise ShapeError(f"axis names {axis_names!r} != levels of "
                         f"{topo.describe()}", expected=topo.n_levels,
                         actual=len(axis_names))
    for name, lvl in zip(axis_names, topo.levels):
        if compat.axis_size(name) != lvl.size:
            raise ShapeError(f"axis {name!r} size != topology level "
                             f"{lvl.name}", expected=lvl.size,
                             actual=compat.axis_size(name))
    monoid, impl = resolve_combine(combine)
    if monoid.pre_scale is not None or monoid.post_divide:
        # strip the bookends off what the per-level executors see (they
        # must run the bare core combine -- a per-level prepare/finalize
        # would compound the scale once per stage); keep the caller's
        # Pallas hint where the string form can still express it
        core = dataclasses.replace(monoid, pre_scale=None,
                                   post_divide=False)
        combine = "pallas" if (impl == "pallas" and core.kind == "add") \
            else core
    if topo.P == 1:
        return monoid.finalize(monoid.prepare(x, 1), 1).astype(x.dtype)
    orig_dtype = x.dtype
    if accum_dtype is not None:
        x = x.astype(accum_dtype)
    x = monoid.prepare(x, topo.P)
    m = x.shape[0]
    inner = topo.inner_size
    # The per-level composition is kept on the divisible layout: each
    # inner reduce-scatter must hand the next level a chunk whose
    # boundaries all ranks agree on, and chaining *balanced* ragged
    # splits level-by-level would make the final all-gather's extraction
    # depend on every intermediate width.  One explicit pad to the inner
    # multiple (at most inner_size - 1 zeros) keeps the composition
    # exact; the outer allreduce below is ragged-native regardless.
    mp = -(-m // inner) * inner
    if mp != m:
        x = jnp.concatenate([x, jnp.zeros((mp - m,), x.dtype)])
    # reduce-scatter down the inner axes, innermost (fastest) first
    inner_axes = [axis_names[i] for i in hs.inner_levels]
    cur = x
    for sched, axis in zip(hs.rs, inner_axes):
        cur = reduce_scatter_flat(cur, axis, sched, combine=combine)
    # generalized allreduce of the chunk across the outer axis
    cur = allreduce_flat(cur, axis_names[0], hs.ar, combine=combine,
                         n_buckets=n_buckets)
    # all-gather back up, reverse order
    for sched, axis in zip(hs.ag, reversed(inner_axes)):
        cur = all_gather_flat(cur, axis, sched)
    return monoid.finalize(cur[:m], topo.P).astype(orig_dtype)


def hierarchical_allreduce(tree, axis_names: Sequence[str],
                           topology: "Topology", *,
                           r: Optional[int] = None,
                           mean: bool = False,
                           accum_dtype=jnp.float32,
                           combine: CombineFn = "auto",
                           n_buckets: Optional[int] = None,
                           tune: Optional[bool] = None):
    """Allreduce (sum or mean) a pytree over hierarchical mesh axes.

    ``r`` tunes the outer-level step count; with ``r=None`` the plan
    (flat vs hierarchical, the step count, and the pipelined bucket
    count) is autotuned per message size from the per-level fabric
    parameters.  A flat plan executes the chosen schedule over the
    flattened axis tuple -- hierarchical is only used when the cost
    model says it wins.  ``tune`` opts the plan chooser into the
    measured tuning table (single-level topologies only; see
    :func:`repro.topology.hierarchical.choose_collective`).
    """
    from repro.topology.hierarchical import (HierarchicalSchedule,
                                             build_hierarchical,
                                             choose_collective,
                                             schedules_for_plan)
    P = topology.P
    monoid, _ = resolve_combine(combine)
    if mean and monoid.name not in ("sum", "mean"):
        raise ValueError(f"mean=True only composes with the sum operator, "
                         f"not {monoid.name!r}")
    if monoid.kind != "add":
        accum_dtype = None
    if P == 1:
        return tree
    flat, spec = _flatten_tree(tree)
    nbytes = flat.size * flat.dtype.itemsize
    if r is None:
        plan = choose_collective(topology, int(nbytes), tune=tune,
                                 itemsize=int(flat.dtype.itemsize))
        sched = schedules_for_plan(plan, topology)
        if n_buckets is None:
            n_buckets = plan.n_buckets
    else:
        sched = build_hierarchical(topology, r)
    if n_buckets is None:
        n_buckets = 1
    if isinstance(sched, HierarchicalSchedule):
        out = hierarchical_allreduce_flat(flat, tuple(axis_names), sched,
                                          accum_dtype=accum_dtype,
                                          combine=combine,
                                          n_buckets=n_buckets)
    else:
        out = allreduce_flat(flat, tuple(axis_names), sched,
                             accum_dtype=accum_dtype, combine=combine,
                             n_buckets=n_buckets)
    if mean and monoid.name == "sum":
        out = out / P
    return _unflatten_tree(out, spec)


def psum_tree(tree, axis_name: AxisName, *, mean: bool = False):
    """XLA-native baseline for comparisons."""
    out = lax.psum(tree, axis_name)
    if mean:
        out = jax.tree.map(lambda x: x / axis_size(axis_name), out)
    return out


# ---------------------------------------------------------------------------
#  ZeRO-style helpers: reduce-scatter grads / all-gather params over DP axis
# ---------------------------------------------------------------------------

def tree_reduce_scatter(tree, axis_name: AxisName, *, mean: bool = False,
                        accum_dtype=jnp.float32):
    """Fuse a pytree into one buffer, reduce-scatter it, and return this
    device's ``(ceil(size / P),)`` shard plus the spec needed to
    reassemble.  The total size need not divide ``P``: the shard is the
    exact ragged chunk of the balanced split, zero-filled past its valid
    prefix (``ragged_sizes(size, P)[d]`` elements)."""
    P = axis_size(axis_name)
    flat, spec = _flatten_tree(tree)
    m = flat.shape[0]
    shard = reduce_scatter_flat(flat, axis_name, accum_dtype=accum_dtype)
    if mean and P > 1:
        shard = shard / P
    return shard, (spec, m)


def tree_all_gather(shard, spec_m, axis_name: AxisName):
    """Inverse of :func:`tree_reduce_scatter` (exact allgatherv: each
    rank contributes only its ragged chunk's valid prefix)."""
    spec, m = spec_m
    P = axis_size(axis_name)
    flat = all_gather_flat(shard, axis_name,
                           sizes=ragged_sizes(m, P) if P > 1 else None)
    return _unflatten_tree(flat[:m], spec)
