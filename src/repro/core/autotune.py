"""Algorithm / step-count selection for the generalized allreduce.

Given a fabric description (alpha, beta, gamma) and a message size, pick the
schedule minimizing the exact schedule-derived cost.  This is what the
training framework uses per gradient bucket: small buckets get
latency-leaning schedules (large r), large buckets get the
bandwidth-optimal r=0 (or Ring on very large, cache-bound buckets).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from .cost_model import (Fabric, TPU_V5E_ICI, choose_n_buckets,
                         optimal_r_search, pipelined_schedule_cost,
                         schedule_cost)
from .schedule import Schedule, build_generalized, build_ring, n_steps_log


@dataclass(frozen=True)
class Choice:
    kind: str          # "generalized" | "ring"
    r: int
    cost: float
    n_buckets: int = 1   # pipelined buckets for the ExecPlan executor


@lru_cache(maxsize=None)
def choose(P: int, nbytes: int, fabric: Fabric = TPU_V5E_ICI,
           allow_ring: bool = True) -> Choice:
    """Pick (kind, r) minimizing modeled time for an allreduce of
    ``nbytes`` over ``P`` devices."""
    if P <= 1:
        return Choice("generalized", 0, 0.0)
    best: Optional[Choice] = None
    for r in range(n_steps_log(P) + 1):
        c = schedule_cost(build_generalized(P, r), nbytes, fabric)
        if best is None or c < best.cost:
            best = Choice("generalized", r, c)
    if allow_ring:
        c = schedule_cost(build_ring(P), nbytes, fabric)
        if c < best.cost:
            best = Choice("ring", 0, c)
    # re-cost the winner with software pipelining: the bucket count that
    # overlaps its wire time with its combine time (fill/drain charged)
    sched = schedule_for(best, P)
    b = choose_n_buckets(sched, nbytes, fabric)
    if b > 1:
        best = Choice(best.kind, best.r,
                      pipelined_schedule_cost(sched, nbytes, fabric, b), b)
    return best


def schedule_for(choice: Choice, P: int) -> Schedule:
    if choice.kind == "ring":
        return build_ring(P)
    return build_generalized(P, choice.r)
