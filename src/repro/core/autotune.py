"""Algorithm / step-count selection for the generalized allreduce.

Given a fabric description (alpha, beta, gamma) and a message size, pick the
schedule minimizing the exact schedule-derived cost.  This is what the
training framework uses per gradient bucket: small buckets get
latency-leaning schedules (large r), large buckets get the
bandwidth-optimal r=0 (or Ring on very large, cache-bound buckets).

Two sources feed the decision:

* the **analytic model** (always available) -- exact per-step traffic of
  the compiled schedule priced by the fabric's alpha/beta/gamma;
* the **measured tuning table** (opt-in) -- wallclock microbenchmarks of
  the real executor persisted by :mod:`repro.tuning`.  When tuning is
  enabled and a measurement compatible with the running backend exists,
  it wins; otherwise the model decides.  ``Choice.source`` records which
  one answered.

Enable measured tuning per call (``tune=True``), or globally with
``REPRO_TUNING=1`` (``tune=None`` reads the env var); ``tune=False``
forces the model.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from .cost_model import (Fabric, TPU_V5E_ICI, choose_n_buckets,
                         pipelined_schedule_cost, schedule_cost)
from .schedule import Schedule, build_generalized, build_ring, n_steps_log


@dataclass(frozen=True)
class Choice:
    kind: str          # "generalized" | "ring"
    r: int
    cost: float        # modeled seconds, or measured seconds when tuned
    n_buckets: int = 1   # pipelined buckets for the ExecPlan executor
    source: str = "model"  # "model" | "measured"


def _tune_default() -> bool:
    return os.environ.get("REPRO_TUNING", "").lower() in ("1", "true", "on")


def choose(P: int, nbytes: int, fabric: Fabric = TPU_V5E_ICI,
           allow_ring: bool = True, tune: Optional[bool] = None) -> Choice:
    """Pick (kind, r, n_buckets) minimizing time for an allreduce of
    ``nbytes`` over ``P`` devices.

    With ``tune`` enabled (explicitly, or via ``REPRO_TUNING=1`` when
    ``tune=None``) the measured tuning table is consulted first; it
    answers only when it holds measurements taken on a backend whose
    fingerprint matches this process (see :mod:`repro.tuning.policy`).
    Everything else falls through to the analytic model.
    """
    if P <= 1:
        return Choice("generalized", 0, 0.0)
    if _tune_default() if tune is None else tune:
        from repro.tuning import policy  # deferred: tuning sits above core
        measured = policy.lookup(P, int(nbytes), allow_ring=allow_ring)
        if measured is not None:
            return measured
    return _choose_model(P, int(nbytes), fabric, allow_ring)


@lru_cache(maxsize=None)
def _choose_model(P: int, nbytes: int, fabric: Fabric,
                  allow_ring: bool) -> Choice:
    """Analytic pick from the exact schedule-derived cost model."""
    best: Optional[Choice] = None
    for r in range(n_steps_log(P) + 1):
        c = schedule_cost(build_generalized(P, r), nbytes, fabric)
        if best is None or c < best.cost:
            best = Choice("generalized", r, c)
    if allow_ring:
        c = schedule_cost(build_ring(P), nbytes, fabric)
        if c < best.cost:
            best = Choice("ring", 0, c)
    # re-cost the winner with software pipelining: the bucket count that
    # overlaps its wire time with its combine time (fill/drain charged)
    sched = schedule_for(best, P)
    b = choose_n_buckets(sched, nbytes, fabric)
    if b > 1:
        best = Choice(best.kind, best.r,
                      pipelined_schedule_cost(sched, nbytes, fabric, b), b)
    return best


def clear_cache() -> None:
    """Drop memoized analytic picks (tests; after fabric/table changes)."""
    _choose_model.cache_clear()


def schedule_for(choice: Choice, P: int) -> Schedule:
    if choice.kind == "ring":
        return build_ring(P)
    return build_generalized(P, choice.r)
