"""Algorithm / step-count selection for the generalized allreduce.

Given a fabric description (alpha, beta, gamma) and a message size, pick the
schedule minimizing the exact schedule-derived cost.  This is what the
training framework uses per gradient bucket: small buckets get
latency-leaning schedules (large r), large buckets get the
bandwidth-optimal r=0 (or Ring on very large, cache-bound buckets).

Two sources feed the decision:

* the **analytic model** (always available) -- exact per-step traffic of
  the compiled schedule priced by the fabric's alpha/beta/gamma;
* the **measured tuning table** (opt-in) -- wallclock microbenchmarks of
  the real executor persisted by :mod:`repro.tuning`.  When tuning is
  enabled and a measurement compatible with the running backend exists,
  it wins; otherwise the model decides.  ``Choice.source`` records which
  one answered.

Enable measured tuning per call (``tune=True``), or globally with
``REPRO_TUNING=1`` (``tune=None`` reads the env var); ``tune=False``
forces the model.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from .cost_model import (Fabric, TPU_V5E_ICI, choose_n_buckets,
                         pipelined_schedule_cost, ragged_choose_n_buckets,
                         ragged_pipelined_schedule_cost, ragged_schedule_cost,
                         schedule_cost)
from .monoid import Monoid
from .schedule import Schedule, build_generalized, build_ring, n_steps_log


@dataclass(frozen=True)
class Choice:
    kind: str          # "generalized" | "ring"
    r: int
    cost: float        # modeled seconds, or measured seconds when tuned
    n_buckets: int = 1   # pipelined buckets for the ExecPlan executor
    source: str = "model"  # "model" | "measured"


def _tune_default() -> bool:
    return os.environ.get("REPRO_TUNING", "").lower() in ("1", "true", "on")


def choose(P: int, nbytes: int, fabric: Fabric = TPU_V5E_ICI,
           allow_ring: bool = True, tune: Optional[bool] = None,
           itemsize: int = 1, monoid: Optional[Monoid] = None) -> Choice:
    """Pick (kind, r, n_buckets) minimizing time for an allreduce of
    ``nbytes`` over ``P`` devices.

    ``itemsize`` is the element width in bytes: the executor splits
    *elements*, so raggedness (and the exact ragged chunk geometry) is
    decided by ``nbytes // itemsize`` -- an f32 message of 16394
    elements is ragged over P=8 even though its 65576 bytes divide 8.

    ``monoid`` is the combine operator: its per-element cost scales the
    gamma term of every candidate (see
    :func:`repro.core.cost_model.schedule_cost`), and measured-table
    lookups only consider measurements taken under the same operator.

    With ``tune`` enabled (explicitly, or via ``REPRO_TUNING=1`` when
    ``tune=None``) the measured tuning table is consulted first; it
    answers only when it holds measurements taken on a backend whose
    fingerprint matches this process (see :mod:`repro.tuning.policy`).
    Everything else falls through to the analytic model.

    >>> choose(8, 1 << 26, tune=False)      # big message: bandwidth-optimal
    Choice(kind='generalized', r=0, cost=0.00235581024, n_buckets=2, \
source='model')
    >>> choose(8, 512, tune=False).r        # tiny message: latency-optimal
    3
    """
    if P <= 1:
        return Choice("generalized", 0, 0.0)
    if _tune_default() if tune is None else tune:
        from repro.tuning import policy  # deferred: tuning sits above core
        measured = policy.lookup(P, int(nbytes), allow_ring=allow_ring,
                                 itemsize=max(int(itemsize), 1),
                                 op=monoid.name if monoid is not None
                                 else "sum")
        if measured is not None:
            return measured
    return _choose_model(P, int(nbytes), fabric, allow_ring,
                         max(int(itemsize), 1), monoid)


@lru_cache(maxsize=None)
def _choose_model(P: int, nbytes: int, fabric: Fabric,
                  allow_ring: bool, itemsize: int = 1,
                  monoid: Optional[Monoid] = None) -> Choice:
    """Analytic pick from the exact schedule-derived cost model.

    For a message whose *element count* (``nbytes // itemsize``) does
    not divide ``P`` the candidates are priced by the ragged cost (true
    per-device moved bytes of the balanced exact split, see
    :func:`repro.core.cost_model.ragged_schedule_cost`) instead of the
    uniform ``m / P`` approximation, so badly-divisible sizes can
    legitimately flip the winner.
    """
    ragged = (nbytes // itemsize) % P != 0
    best: Optional[Choice] = None
    for r in range(n_steps_log(P) + 1):
        s = build_generalized(P, r)
        c = (ragged_schedule_cost(s, nbytes, fabric, itemsize, monoid)
             if ragged else schedule_cost(s, nbytes, fabric, monoid))
        if best is None or c < best.cost:
            best = Choice("generalized", r, c)
    if allow_ring:
        s = build_ring(P)
        c = (ragged_schedule_cost(s, nbytes, fabric, itemsize, monoid)
             if ragged else schedule_cost(s, nbytes, fabric, monoid))
        if c < best.cost:
            best = Choice("ring", 0, c)
    # re-cost the winner with software pipelining: the bucket count that
    # overlaps its wire time with its combine time (fill/drain charged)
    sched = schedule_for(best, P)
    if ragged:
        b = ragged_choose_n_buckets(sched, nbytes, fabric,
                                    itemsize=itemsize, monoid=monoid)
        if b > 1:
            best = Choice(best.kind, best.r,
                          ragged_pipelined_schedule_cost(sched, nbytes,
                                                         fabric, b,
                                                         itemsize, monoid),
                          b)
    else:
        b = choose_n_buckets(sched, nbytes, fabric, monoid=monoid)
        if b > 1:
            best = Choice(best.kind, best.r,
                          pipelined_schedule_cost(sched, nbytes, fabric, b,
                                                  monoid), b)
    return best


def clear_cache() -> None:
    """Drop memoized analytic picks (tests; after fabric/table changes)."""
    _choose_model.cache_clear()


def schedule_for(choice: Choice, P: int) -> Schedule:
    if choice.kind == "ring":
        return build_ring(P)
    return build_generalized(P, choice.r)
