"""Algorithm / step-count selection for the generalized allreduce.

Given a fabric description (alpha, beta, gamma) and a message size, pick the
schedule minimizing the exact schedule-derived cost.  This is what the
training framework uses per gradient bucket: small buckets get
latency-leaning schedules (large r), large buckets get the
bandwidth-optimal r=0 (or Ring on very large, cache-bound buckets).

Two sources feed the decision:

* the **analytic model** (always available) -- exact per-step traffic of
  the compiled schedule priced by the fabric's alpha/beta/gamma;
* the **measured tuning table** (opt-in) -- wallclock microbenchmarks of
  the real executor persisted by :mod:`repro.tuning`.  When tuning is
  enabled and a measurement compatible with the running backend exists,
  it wins; otherwise the model decides.  ``Choice.source`` records which
  one answered.

Enable measured tuning per call (``tune=True``), or globally with
``REPRO_TUNING=1`` (``tune=None`` reads the env var); ``tune=False``
forces the model.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Optional, Sequence, Tuple

from .cost_model import (Fabric, TPU_V5E_ICI, choose_arrival_order,
                         choose_n_buckets, pipelined_schedule_cost,
                         ragged_choose_n_buckets,
                         ragged_pipelined_schedule_cost, ragged_schedule_cost,
                         schedule_cost, skewed_schedule_cost)
from .monoid import Monoid
from .schedule import (Schedule, build_dual_root, build_generalized,
                       build_ring, build_sorted_generalized,
                       build_traff_rounds, n_steps_log)

# The skew-aware path engages only when the measured arrival spread is
# worth acting on: at least this fraction of the best barrier-model cost
# (tiny relative skews cannot change any winner) AND at least one fabric
# alpha (absolute floor below which the probe is pure noise).
SKEW_COST_FRACTION = 0.05


@dataclass(frozen=True)
class Choice:
    kind: str  # "generalized" | "ring" | "sorted" | "traff_rounds" | "dual_root"
    r: int
    cost: float        # modeled seconds, or measured seconds when tuned
    n_buckets: int = 1   # pipelined buckets for the ExecPlan executor
    source: str = "model"  # "model" | "measured" | "skew"
    # arrival-sorted rank order (kind == "sorted" only): order[j] is the
    # physical device at logical position j.  repr-suppressed so the
    # common kinds keep their stable printed form.
    order: Optional[Tuple[int, ...]] = field(default=None, repr=False)


def _tune_default() -> bool:
    return os.environ.get("REPRO_TUNING", "").lower() in ("1", "true", "on")


def choose(P: int, nbytes: int, fabric: Fabric = TPU_V5E_ICI,
           allow_ring: bool = True, tune: Optional[bool] = None,
           itemsize: int = 1, monoid: Optional[Monoid] = None,
           arrival_deltas_us: Optional[Sequence[float]] = None,
           compute_overlap_us: Optional[float] = None) -> Choice:
    """Pick (kind, r, n_buckets) minimizing time for an allreduce of
    ``nbytes`` over ``P`` devices.

    ``itemsize`` is the element width in bytes: the executor splits
    *elements*, so raggedness (and the exact ragged chunk geometry) is
    decided by ``nbytes // itemsize`` -- an f32 message of 16394
    elements is ragged over P=8 even though its 65576 bytes divide 8.

    ``monoid`` is the combine operator: its per-element cost scales the
    gamma term of every candidate (see
    :func:`repro.core.cost_model.schedule_cost`), and measured-table
    lookups only consider measurements taken under the same operator.

    With ``tune`` enabled (explicitly, or via ``REPRO_TUNING=1`` when
    ``tune=None``) the measured tuning table is consulted first; it
    answers only when it holds measurements taken on a backend whose
    fingerprint matches this process (see :mod:`repro.tuning.policy`).
    Everything else falls through to the analytic model.

    ``arrival_deltas_us`` engages the arrival-skew timeline
    (:func:`repro.core.cost_model.skewed_schedule_cost`): per-device
    arrival deltas in microseconds, e.g. from
    :func:`repro.obs.skew.device_arrival_probe` or a runtime's step
    barrier.  When omitted and tuning is on, the deltas persisted in the
    tuning cache (``Measurement.deltas_us``) are used.  If the spread
    clears the threshold (``SKEW_COST_FRACTION`` of the best barrier
    cost and at least one fabric alpha), every candidate -- including
    the arrival-sorted relabeling
    (:func:`repro.core.schedule.build_sorted_generalized`) -- is priced
    by the skew timeline instead; such choices carry
    ``source="skew"``.  Skew below the threshold changes nothing.

    >>> choose(8, 1 << 26, tune=False)      # big message: bandwidth-optimal
    Choice(kind='generalized', r=0, cost=0.00235581024, n_buckets=2, \
source='model')
    >>> choose(8, 512, tune=False).r        # tiny message: latency-optimal
    3
    >>> c = choose(8, 512, tune=False, fabric=TPU_V5E_ICI,
    ...            arrival_deltas_us=[0, 0, 0, 0, 0, 0, 0, 300.0])
    >>> c.source                            # heavy skew: timeline-priced
    'skew'

    ``compute_overlap_us`` is the backward-overlap hint: the
    overlappable compute (microseconds) still running when this
    collective dispatches (the per-bucket backward remainder of the
    backward-overlapped gradient sync).  When set and positive,
    candidates are ranked by *exposed* cost
    (:func:`repro.core.cost_model.overlap_exposed_cost` -- the part of
    the collective the compute cannot hide), with the raw pipelined
    cost as tie-break: under a generous budget many candidates fully
    hide and the cheapest raw schedule wins, while under a tight budget
    the ranking is unchanged from the plain model.  ``Choice.cost`` is
    then the exposed seconds.  Measured-table lookups are skipped for
    hinted queries (no measurement carries overlap context, see
    :func:`repro.tuning.policy.lookup`), so the hint always answers
    from the model.

    >>> choose(8, 1 << 26, tune=False, compute_overlap_us=1e9).cost
    0.0
    >>> choose(8, 1 << 26, tune=False,
    ...        compute_overlap_us=0.0)      # zero budget == plain model
    Choice(kind='generalized', r=0, cost=0.00235581024, n_buckets=2, \
source='model')
    """
    if P <= 1:
        return Choice("generalized", 0, 0.0)
    itemsize = max(int(itemsize), 1)
    op = monoid.name if monoid is not None else "sum"
    tuned = _tune_default() if tune is None else tune
    if compute_overlap_us is not None and compute_overlap_us > 0.0:
        if tuned:
            from repro.tuning import policy  # deferred: tuning sits above core
            measured = policy.lookup(P, int(nbytes), allow_ring=allow_ring,
                                     itemsize=itemsize, op=op,
                                     compute_overlap_us=compute_overlap_us)
            if measured is not None:        # today: always None (overlap
                return measured             # measurements do not exist yet)
        # quantize the budget to whole microseconds so the cache key
        # space stays bounded while a drifting per-step estimate varies
        return _choose_overlap(P, int(nbytes), fabric, allow_ring,
                               itemsize, monoid,
                               int(round(compute_overlap_us)))
    deltas = arrival_deltas_us
    if deltas is None and tuned:
        from repro.tuning import policy  # deferred: tuning sits above core
        deltas = policy.arrival_deltas(P, int(nbytes), op=op)
    if deltas is not None and len(deltas) == P:
        base = _choose_model(P, int(nbytes), fabric, allow_ring,
                             itemsize, monoid)
        skew_s = (max(deltas) - min(deltas)) * 1e-6
        if skew_s >= max(SKEW_COST_FRACTION * base.cost, fabric.alpha):
            return _choose_skewed(P, int(nbytes), fabric, allow_ring,
                                  itemsize, monoid,
                                  tuple(int(round(d)) for d in deltas))
    if tuned:
        from repro.tuning import policy  # deferred: tuning sits above core
        measured = policy.lookup(P, int(nbytes), allow_ring=allow_ring,
                                 itemsize=itemsize, op=op)
        if measured is not None:
            return measured
    return _choose_model(P, int(nbytes), fabric, allow_ring,
                         itemsize, monoid)


@lru_cache(maxsize=None)
def _choose_model(P: int, nbytes: int, fabric: Fabric,
                  allow_ring: bool, itemsize: int = 1,
                  monoid: Optional[Monoid] = None) -> Choice:
    """Analytic pick from the exact schedule-derived cost model.

    For a message whose *element count* (``nbytes // itemsize``) does
    not divide ``P`` the candidates are priced by the ragged cost (true
    per-device moved bytes of the balanced exact split, see
    :func:`repro.core.cost_model.ragged_schedule_cost`) instead of the
    uniform ``m / P`` approximation, so badly-divisible sizes can
    legitimately flip the winner.
    """
    ragged = (nbytes // itemsize) % P != 0
    best: Optional[Choice] = None
    candidates = [("generalized", r, build_generalized(P, r))
                  for r in range(n_steps_log(P) + 1)]
    candidates += [("traff_rounds", 0, build_traff_rounds(P)),
                   ("dual_root", 0, build_dual_root(P))]
    if allow_ring:
        candidates.append(("ring", 0, build_ring(P)))
    for kind, r, s in candidates:
        c = (ragged_schedule_cost(s, nbytes, fabric, itemsize, monoid)
             if ragged else schedule_cost(s, nbytes, fabric, monoid))
        if best is None or c < best.cost:
            best = Choice(kind, r, c)
    # re-cost the winner with software pipelining: the bucket count that
    # overlaps its wire time with its combine time (fill/drain charged)
    sched = schedule_for(best, P)
    if ragged:
        b = ragged_choose_n_buckets(sched, nbytes, fabric,
                                    itemsize=itemsize, monoid=monoid)
        if b > 1:
            best = Choice(best.kind, best.r,
                          ragged_pipelined_schedule_cost(sched, nbytes,
                                                         fabric, b,
                                                         itemsize, monoid),
                          b)
    else:
        b = choose_n_buckets(sched, nbytes, fabric, monoid=monoid)
        if b > 1:
            best = Choice(best.kind, best.r,
                          pipelined_schedule_cost(sched, nbytes, fabric, b,
                                                  monoid), b)
    return best


# bounded: keyed by the whole-microsecond overlap budget, whose
# cardinality is unbounded when a drifting per-step compute estimate
# feeds the hint
@lru_cache(maxsize=512)
def _choose_overlap(P: int, nbytes: int, fabric: Fabric, allow_ring: bool,
                    itemsize: int, monoid: Optional[Monoid],
                    overlap_us: int) -> Choice:
    """Overlap-aware analytic pick: rank candidates by exposed cost.

    Each candidate is priced at its own best bucket count (the bucket
    sweep of :func:`_choose_model`, re-run per candidate because
    pipelining interacts with the overlap budget: more buckets start
    the wire earlier in the drain), then ranked by
    ``exposed = max(0, pipelined_cost - budget)`` with the raw
    pipelined cost as tie-break -- under a generous budget several
    candidates expose 0.0 and the cheapest raw schedule (which frees
    the fabric soonest) wins.  ``Choice.cost`` is the exposed seconds,
    which is what the caller's step-time roofline adds up.
    """
    ragged = (nbytes // itemsize) % P != 0
    candidates = [("generalized", r, build_generalized(P, r))
                  for r in range(n_steps_log(P) + 1)]
    candidates += [("traff_rounds", 0, build_traff_rounds(P)),
                   ("dual_root", 0, build_dual_root(P))]
    if allow_ring:
        candidates.append(("ring", 0, build_ring(P)))
    best: Optional[Choice] = None
    best_raw = 0.0
    for kind, r, s in candidates:
        if ragged:
            b = ragged_choose_n_buckets(s, nbytes, fabric,
                                        itemsize=itemsize, monoid=monoid)
            raw = ragged_pipelined_schedule_cost(s, nbytes, fabric, b,
                                                 itemsize, monoid)
        else:
            b = choose_n_buckets(s, nbytes, fabric, monoid=monoid)
            raw = (pipelined_schedule_cost(s, nbytes, fabric, b, monoid)
                   if b > 1 else schedule_cost(s, nbytes, fabric, monoid))
        exposed = max(0.0, raw - overlap_us * 1e-6)
        if best is None or (exposed, raw) < (best.cost, best_raw):
            best, best_raw = Choice(kind, r, exposed, b), raw
    return best


# bounded: keyed by the quantized delta tuple, whose cardinality is
# unbounded when a long-lived runtime's arrival pattern drifts
@lru_cache(maxsize=512)
def _choose_skewed(P: int, nbytes: int, fabric: Fabric, allow_ring: bool,
                   itemsize: int, monoid: Optional[Monoid],
                   deltas_us: Tuple[int, ...]) -> Choice:
    """Skew-timeline pick under measured arrival deltas.

    Every candidate is priced by
    :func:`repro.core.cost_model.skewed_schedule_cost` -- under heavy
    skew the winner legitimately flips toward larger ``r`` (fewer steps
    after the last arrival's data enters the combine tree), which the
    barrier model cannot see.  The arrival-sorted relabeling of the
    winning ``r`` is taken when its timeline beats the identity order
    (on the vertex-transitive cyclic schedules the margin comes from
    aligning the ragged +1-element chunks away from late devices, so it
    is small but never negative -- identity is always a candidate).
    ``n_buckets`` stays 1: the skew timeline prices whole-step messages,
    and bucketing decisions under skew would be guesses.
    """
    deltas = [float(d) for d in deltas_us]
    best: Optional[Choice] = None
    candidates = [("generalized", r, build_generalized(P, r))
                  for r in range(n_steps_log(P) + 1)]
    candidates += [("traff_rounds", 0, build_traff_rounds(P)),
                   ("dual_root", 0, build_dual_root(P))]
    if allow_ring:
        candidates.append(("ring", 0, build_ring(P)))
    for kind, r, s in candidates:
        c = skewed_schedule_cost(s, nbytes, fabric, deltas, itemsize, monoid)
        if best is None or c < best.cost:
            best = Choice(kind, r, c, source="skew")
    if best.kind == "generalized":
        order, c = choose_arrival_order(P, best.r, nbytes, fabric, deltas,
                                        itemsize, monoid)
        if c < best.cost and order != tuple(range(P)):
            sched = build_sorted_generalized(P, best.r, order)
            # exact physical-delta cost of the relabeled schedule (the
            # search priced it by logical-delta conjugation, which is
            # off by the ragged chunk placement)
            c_exact = skewed_schedule_cost(sched, nbytes, fabric, deltas,
                                           itemsize, monoid)
            if c_exact < best.cost:
                best = Choice("sorted", best.r, c_exact, source="skew",
                              order=order)
    return best


def clear_cache() -> None:
    """Drop memoized analytic picks (tests; after fabric/table changes)."""
    _choose_model.cache_clear()
    _choose_skewed.cache_clear()
    _choose_overlap.cache_clear()


def schedule_for(choice: Choice, P: int) -> Schedule:
    if choice.kind == "ring":
        return build_ring(P)
    if choice.kind == "sorted":
        return build_sorted_generalized(P, choice.r, choice.order)
    if choice.kind == "traff_rounds":
        return build_traff_rounds(P)
    if choice.kind == "dual_root":
        return build_dual_root(P)
    return build_generalized(P, choice.r)
