"""Symbolic schedule compiler for the generalized Allreduce.

This module compiles the paper's algorithm family into an explicit list of
communication steps that an SPMD executor (numpy simulator or JAX
``shard_map`` + ``lax.ppermute``) can replay.

Vocabulary (paper section 5):

* A **distributed vector** ``t_e q`` is a vector whose i-th element (a
  "chunk" of size u = m/P) lives on process ``t_e(i)``.  In SPMD terms every
  process holds exactly one chunk of every live distributed vector, so the
  per-device state is simply a list of rows, one row per live vector.

* A **slot** is our symbolic name for a live distributed vector: its
  ``place`` (the group element index e such that the vector is ``t_e q``)
  plus its ``content`` (the set of original vector indices that have been
  summed into it).  ``(place, content)`` uniquely identifies the
  distributed vector, which lets the compiler deduplicate intermediates
  shared between the ``s`` shifted copies of the reduction schedule --
  exactly the sharing the paper exploits in section 8.

* A **communication step** applies one group element ``o`` to a subset of
  slots: every device sends its piece of each TX row to device ``o(d)``
  (``lax.ppermute`` with a static permutation), then local combines run.

The compiler follows the paper exactly:

* reduction phase: equations (17)-(24), generalized to ``s`` shifted
  copies per section 8 (equations (26)-(35)); the latency-optimal version
  of section 9 is the corner case ``s = P``.
* distribution phase: the reversed reduction steps, of which the first
  ``r`` are omitted because the reduction already produced ``s = N_{L-r}``
  copies of the result.

Every compiled schedule is *verified by construction*: the compiler tracks
chunk contents symbolically and raises if any combine would double-count a
contribution or if the final state is not "every process holds every fully
reduced chunk".
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, FrozenSet, List, Optional, Tuple

from .group import (CyclicGroup, HypercubeGroup, MixedRadixGroup,
                    RelabeledGroup)


class InvalidScheduleError(ValueError):
    pass


class ShapeError(ValueError):
    """A collective was handed data whose shape violates its contract.

    Unlike a bare ``assert`` (stripped under ``python -O``), this always
    fires and carries the offending sizes:

    >>> err = ShapeError("axis size mismatch", expected=8, actual=6)
    >>> err.expected, err.actual
    (8, 6)
    >>> str(err)
    'axis size mismatch (expected 8, got 6)'
    """

    def __init__(self, message: str, *, expected=None, actual=None):
        self.expected = expected
        self.actual = actual
        if expected is not None or actual is not None:
            message = f"{message} (expected {expected}, got {actual})"
        super().__init__(message)


@dataclass(frozen=True)
class Slot:
    """A live distributed vector: placement group-element + summed contents."""

    place: int
    content: FrozenSet[int]

    def key(self):
        return (self.place, tuple(sorted(self.content)))


@dataclass(frozen=True)
class OutOp:
    """How one output row of a step is produced.

    kind == "keep": output = rows[res]
    kind == "recv": output = arrivals[arr]
    kind == "add" : output = rows[res] (+) arrivals[arr]
    """

    kind: str
    res: int = -1
    arr: int = -1


@dataclass(frozen=True)
class CommStep:
    """One communication step: ppermute TX rows by group element ``shift``,
    then rebuild the row list via ``out`` ops."""

    shift: int                    # group element index o; device d -> o(d)
    tx_rows: Tuple[int, ...]      # indices into the *current* row list
    out: Tuple[OutOp, ...]        # recipe for the *next* row list
    out_slots: Tuple[Slot, ...]   # symbolic metadata (debug / verification)

    @property
    def n_tx(self) -> int:
        return len(self.tx_rows)

    @property
    def n_adds(self) -> int:
        return sum(1 for o in self.out if o.kind == "add")


@dataclass(frozen=True)
class Schedule:
    """A fully compiled collective schedule.

    The initial per-device state is ``P`` rows; row ``e`` of device ``d``
    holds chunk ``chunk_of_initial_row(e, d)`` of the device's own input
    vector.  After replaying ``steps``, row ``k`` of device ``d`` holds the
    fully-reduced chunk ``final_chunk_index(k, d)`` (for allreduce /
    all-gather style results) -- see the executor for the gather.
    """

    P: int
    group: MixedRadixGroup
    kind: str   # "generalized" | "ring" | "sorted" | "traff_rounds" |
                # "dual_root" | "reduce_scatter" | "all_gather" | ...
    r: int                        # removed distribution steps (generalized only)
    s: int                        # result multiplicity after reduction
    steps: Tuple[CommStep, ...]
    initial_slots: Tuple[Slot, ...]
    final_slots: Tuple[Slot, ...]

    # ---------------- stats for the cost model --------------------------
    @property
    def n_steps(self) -> int:
        return len(self.steps)

    @property
    def units_sent(self) -> int:
        """Total chunk-units transmitted per device over the schedule."""
        return sum(st.n_tx for st in self.steps)

    @property
    def units_reduced(self) -> int:
        """Total chunk-unit combine operations per device."""
        return sum(st.n_adds for st in self.steps)

    @property
    def max_rows(self) -> int:
        n = len(self.initial_slots)
        best = n
        for st in self.steps:
            n = len(st.out)
            best = max(best, n)
        return best

    # ---------------- data placement maps --------------------------------
    def chunk_of_initial_row(self, row: int, device: int) -> int:
        """Which chunk of its own vector device ``device`` stores in initial
        row ``row``.

        Initial row e is the distributed vector t_e q_e with element i of
        q_e = chunk i of V_{t_e(i)}; device d = t_e(i) holds element
        i = t_e^{-1}(d).
        """
        e = self.initial_slots[row].place
        return self.group.apply(self.group.inverse(e), device)

    def final_chunk_index(self, row: int, device: int) -> int:
        """Which fully-reduced chunk final row ``row`` holds on ``device``."""
        e = self.final_slots[row].place
        return self.group.apply(self.group.inverse(e), device)

    def chunk_sizes(self, m: int) -> Tuple[int, ...]:
        """Per-rank chunk-size vector for an ``m``-element message.

        Chunk ``c`` (the c-th entry of the group enumeration ``g_0 ..
        g_{P-1}``, owned by rank ``c`` after the reduction phase) carries
        ``chunk_sizes(m)[c]`` elements under the balanced exact split --
        no chunk is ever rounded up to a common width, so the sizes sum
        to exactly ``m``.  The symbolic verification is size-independent:
        slots track *which* chunks were summed, and a combine only ever
        pairs rows holding the same chunk index on each device, so every
        per-chunk width is preserved through every step.

        >>> build_generalized(5, r=1).chunk_sizes(12)
        (3, 3, 2, 2, 2)
        """
        return ragged_sizes(m, self.P)


# --------------------------------------------------------------------------
#  ragged (uneven) chunk geometry
# --------------------------------------------------------------------------

def ragged_sizes(m: int, P: int) -> Tuple[int, ...]:
    """Balanced exact split of ``m`` elements into ``P`` chunks.

    The first ``m % P`` chunks get one extra element, so no chunk is ever
    pure padding and sizes differ by at most one -- the uneven-shard
    analogue of the paper's non-power-of-two process counts (it never
    rounds ``m`` up to a multiple of ``P``).  Chunks are indexed by the
    group enumeration, so rank ``d`` owns chunk ``d`` of ``sizes[d]``
    elements after a reduce-scatter.

    >>> ragged_sizes(10, 4)
    (3, 3, 2, 2)
    >>> ragged_sizes(3, 5)          # fewer elements than ranks
    (1, 1, 1, 0, 0)
    >>> sum(ragged_sizes(1000003, 7))
    1000003
    """
    if P < 1:
        raise ShapeError("ragged_sizes needs P >= 1", expected=">= 1", actual=P)
    if m < 0:
        raise ShapeError("ragged_sizes needs m >= 0", expected=">= 0", actual=m)
    u, rem = divmod(m, P)
    return tuple(u + 1 if c < rem else u for c in range(P))


def ragged_offsets(sizes: Tuple[int, ...]) -> Tuple[int, ...]:
    """Start offset of each chunk of a ragged split.

    >>> ragged_offsets(ragged_sizes(10, 4))
    (0, 3, 6, 8)
    """
    out, off = [], 0
    for s in sizes:
        out.append(off)
        off += s
    return tuple(out)


# --------------------------------------------------------------------------
#  helpers
# --------------------------------------------------------------------------

def n_steps_log(P: int) -> int:
    return max(0, math.ceil(math.log2(P))) if P > 1 else 0


def vector_counts(P: int) -> List[int]:
    """The sequence N_i of live-vector counts, eq (18): N_0 = P,
    N_{i+1} = ceil(N_i / 2); ends with N_L = 1."""
    out = [P]
    while out[-1] > 1:
        out.append((out[-1] + 1) // 2)
    return out


def result_multiplicity(P: int, r: int) -> int:
    """Number of result copies s the reduction phase must produce so the
    first r steps of the distribution phase can be omitted: s = N_{L-r}."""
    counts = vector_counts(P)
    L = len(counts) - 1
    if not (0 <= r <= L):
        raise InvalidScheduleError(f"r={r} out of range [0, {L}] for P={P}")
    return counts[L - r]


# --------------------------------------------------------------------------
#  the compiler
# --------------------------------------------------------------------------

class _Builder:
    """Mutable state shared by the reduction / distribution compilers."""

    def __init__(self, group: MixedRadixGroup):
        self.group = group
        self.P = group.order
        # canonical global row order: list of Slots
        self.rows: List[Slot] = [
            Slot(place=e, content=frozenset([e])) for e in range(self.P)
        ]
        self.steps: List[CommStep] = []
        self.initial_slots = tuple(self.rows)

    def row_index(self) -> Dict[Slot, int]:
        return {s: i for i, s in enumerate(self.rows)}

    def emit(self, shift: int, tx_slots: List[Slot], new_rows: List[Slot],
             combines: Dict[Slot, Tuple[Slot, Slot]]):
        """Build a CommStep.

        combines: new_slot -> (resident_slot, arrival_slot) for "add" rows.
        Arrival slots are the TX slots re-placed by ``shift``.
        """
        idx = self.row_index()
        tx_slots = sorted(set(tx_slots), key=Slot.key)
        tx_rows = tuple(idx[s] for s in tx_slots)
        # arrival j corresponds to tx_slots[j] with updated place
        arrivals: Dict[Slot, int] = {}
        for j, s in enumerate(tx_slots):
            a = Slot(place=self.group.compose(shift, s.place), content=s.content)
            arrivals[a] = j
        out_ops: List[OutOp] = []
        for ns in new_rows:
            if ns in idx:                       # value already materialised
                out_ops.append(OutOp("keep", res=idx[ns]))
            elif ns in combines:
                res, arr = combines[ns]
                if res not in idx:
                    raise InvalidScheduleError(f"combine resident {res} missing")
                if arr not in arrivals:
                    raise InvalidScheduleError(f"combine arrival {arr} missing")
                if res.content & arr.content:
                    raise InvalidScheduleError(
                        f"double-count: {sorted(res.content & arr.content)}")
                if res.place != ns.place or arr.place != ns.place:
                    raise InvalidScheduleError("combine placement mismatch")
                out_ops.append(OutOp("add", res=idx[res], arr=arrivals[arr]))
            elif ns in arrivals:
                out_ops.append(OutOp("recv", arr=arrivals[ns]))
            else:
                raise InvalidScheduleError(f"cannot build slot {ns}")
        self.steps.append(CommStep(
            shift=shift, tx_rows=tx_rows, out=tuple(out_ops),
            out_slots=tuple(new_rows)))
        self.rows = list(new_rows)


def _reduction_phase(b: _Builder, s: int,
                     offsets: Optional[Tuple[int, ...]] = None) -> None:
    """Reduction with ``s`` shifted copies (paper sections 7-9).

    Copy c (c = 0..s-1) runs the base schedule with every vector re-labelled
    by the group element ``offsets[c]`` (default ``c``); all copies share
    the same communication operator each step so their TX sets merge
    (deduplicated by slot).  Copy c's fully-reduced vector ends at place
    ``offsets[c]`` -- non-contiguous offsets are how the dual-root kind
    plants its two roots half a ring apart.
    """
    g = b.group
    P = b.P
    if offsets is None:
        offsets = tuple(range(s))
    if len(offsets) != s or len(set(offsets)) != s:
        raise InvalidScheduleError(f"need {s} distinct copy offsets, "
                                   f"got {offsets}")
    counts = vector_counts(P)
    L = len(counts) - 1
    # per-copy ordered slot lists; copy c slot j: place compose(off_c, g_j)
    copies: List[List[Slot]] = []
    for off in offsets:
        copies.append([Slot(place=g.compose(off, j),
                            content=frozenset([g.compose(off, j)]))
                       for j in range(P)])

    for i in range(L):
        N = counts[i]
        ceil_ = (N + 1) // 2
        f = N // 2
        shift = g.inverse(f)          # operator t^{-floor(N/2)}, eq (19)
        tx: List[Slot] = []
        combines: Dict[Slot, Tuple[Slot, Slot]] = {}
        new_copies: List[List[Slot]] = []
        for c in range(s):
            cur = copies[c]
            assert len(cur) == N, (len(cur), N)
            # local TX indices [ceil, N-1] -> arrive at local index
            # j' with g_{j'} = g_f^{-1} g_j  (cyclic: j' = j - f)
            arriving: Dict[int, Slot] = {}
            for j in range(ceil_, N):
                tx.append(cur[j])
                jp = g.compose(g.inverse(f), j)
                if not (0 <= jp < ceil_):
                    raise InvalidScheduleError(
                        f"group {g.describe()} incompatible with schedule at "
                        f"step {i}: local {j} -> {jp} outside [0,{ceil_})")
                if jp in arriving:
                    raise InvalidScheduleError("arrival collision")
                arrived = Slot(place=g.compose(shift, cur[j].place),
                               content=cur[j].content)
                if arrived.place != cur[jp].place:
                    raise InvalidScheduleError("pairing placement mismatch")
                arriving[jp] = arrived
            nxt: List[Slot] = []
            for jp in range(ceil_):
                if jp in arriving:
                    res, arr = cur[jp], arriving[jp]
                    if res.content & arr.content:
                        raise InvalidScheduleError("per-copy double count")
                    ns = Slot(place=res.place, content=res.content | arr.content)
                    prev = combines.get(ns)
                    if prev is not None and prev != (res, arr):
                        # two copies disagree on how to form the same slot --
                        # keep the first recipe; both produce the same value.
                        pass
                    else:
                        combines[ns] = (res, arr)
                    nxt.append(ns)
                else:
                    nxt.append(cur[jp])          # q* kept (odd N), eq (23)
            new_copies.append(nxt)

        # global new row list: dedup, canonical order
        seen = {}
        new_rows: List[Slot] = []
        for cl in new_copies:
            for sl in cl:
                if sl not in seen:
                    seen[sl] = True
                    new_rows.append(sl)
        new_rows.sort(key=Slot.key)
        b.emit(shift, tx, new_rows, combines)
        copies = new_copies

    full = frozenset(range(P))
    for c, off in enumerate(offsets):
        assert len(copies[c]) == 1
        got = copies[c][0]
        want = Slot(place=g.compose(off, 0), content=full)
        if got != want:
            raise InvalidScheduleError(f"copy {c} reduced to {got}, want {want}")


def _distribution_phase(b: _Builder, r: int) -> None:
    """Reversed reduction steps i = L-r-1 .. 0 (no combines)."""
    g = b.group
    P = b.P
    counts = vector_counts(P)
    L = len(counts) - 1
    full = frozenset(range(P))
    for i in range(L - r - 1, -1, -1):
        N = counts[i]
        ceil_ = (N + 1) // 2
        f = N // 2
        shift = f  # operator t^{+floor(N/2)}, reverse of the reduction step
        cur = b.rows
        assert len(cur) == counts[i + 1] == ceil_, (len(cur), counts[i + 1])
        tx: List[Slot] = []
        new_rows: List[Slot] = list(cur)
        for j in range(ceil_ - f, ceil_):
            src = next(x for x in cur if x.place == j)
            tx.append(src)
            arr = Slot(place=g.compose(f, src.place), content=full)
            new_rows.append(arr)
        new_rows.sort(key=Slot.key)
        b.emit(shift, tx, new_rows, {})


@lru_cache(maxsize=None)
def build_generalized(P: int, r: int = 0,
                      group_kind: str = "cyclic") -> Schedule:
    """Compile the generalized allreduce for ``P`` processes.

    r = 0              : bandwidth-optimal (paper section 7) -- 2*ceil(log P)
                         steps, 2(P-1) units sent (Recursive-Halving-like).
    r = ceil(log P)    : latency-optimal (paper section 9) -- ceil(log P)
                         steps (Recursive-Doubling-like).
    0 < r < ceil(log P): intermediate trade-off (paper section 8).

    group_kind: "cyclic" (any P), "hypercube" (P = 2^k), or
    "mixed:r0,r1,..." for an arbitrary direct product of cyclic factors
    (the paper's "any suitable group T_P").  Suitability is decided by the
    compiler itself: the enumeration g_0..g_{P-1} must satisfy
    g_f^{-1} g_j landing inside the kept prefix at every halving step --
    true iff every halving boundary is digit-borrow-free (e.g. Z2xZ3
    works for P=6, Z3xZ2 provably does not); an unsuitable group raises
    InvalidScheduleError rather than miscompiling.

    >>> s = build_generalized(6, r=1)      # P=6: non-power-of-two
    >>> s.n_steps, s.units_sent, s.units_reduced, s.s
    (5, 12, 8, 2)
    >>> build_generalized(6, r=99)
    Traceback (most recent call last):
        ...
    repro.core.schedule.InvalidScheduleError: r=99 out of range [0, 3] for P=6
    """
    if P < 1:
        raise InvalidScheduleError("P must be >= 1")
    if group_kind == "cyclic":
        g = CyclicGroup(P)
    elif group_kind == "hypercube":
        g = HypercubeGroup(P)
    elif group_kind.startswith("mixed:"):
        from .group import MixedRadixGroup
        radices = tuple(int(x) for x in group_kind[6:].split(","))
        g = MixedRadixGroup(radices)
        if g.order != P:
            raise InvalidScheduleError(f"group order {g.order} != P {P}")
    else:
        raise ValueError(f"unknown group kind {group_kind!r}")
    b = _Builder(g)
    if P == 1:
        sched = Schedule(P=P, group=g, kind="generalized", r=0, s=1,
                         steps=(), initial_slots=b.initial_slots,
                         final_slots=b.initial_slots)
        _verify(sched)
        return sched
    s = result_multiplicity(P, r)
    _reduction_phase(b, s)
    _distribution_phase(b, r)
    sched = Schedule(P=P, group=g, kind="generalized", r=r, s=s,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched)
    return sched


# bounded: keyed by the relabeling permutation, whose cardinality is
# unbounded when arrival patterns drift in a long-lived process
@lru_cache(maxsize=512)
def build_sorted_generalized(P: int, r: int = 0,
                             order: Optional[Tuple[int, ...]] = None
                             ) -> Schedule:
    """The generalized allreduce over an arrival-sorted rank order.

    ``order[j]`` is the physical device assigned to logical position
    ``j`` of the cyclic enumeration -- the arrival-pattern-aware
    relabeling (Proficz, arXiv:1804.05349): devices whose data shows up
    early sit at the positions whose rows feed the combine tree first,
    late devices at positions whose lateness the schedule's own slack
    absorbs (see :func:`repro.core.cost_model.choose_arrival_order`).

    The compiled object is *structurally identical* to
    ``build_generalized(P, r)`` -- same steps, same traffic, same
    symbolic verification -- acting through a
    :class:`repro.core.group.RelabeledGroup`, so every executor
    (simulator, ExecPlan, shard_map) replays it unchanged and the result
    stays bit-exact: the relabeling only permutes *which device* plays
    which role.

    >>> s = build_sorted_generalized(6, r=1, order=(2, 0, 5, 1, 4, 3))
    >>> s.kind, s.n_steps, s.units_sent, s.s
    ('sorted', 5, 12, 2)
    >>> base = build_generalized(6, r=1)
    >>> [st.tx_rows for st in s.steps] == [st.tx_rows for st in base.steps]
    True
    """
    if P < 1:
        raise InvalidScheduleError("P must be >= 1")
    if order is None:
        order = tuple(range(P))
    order = tuple(int(x) for x in order)
    if sorted(order) != list(range(P)):
        raise InvalidScheduleError(
            f"order {order} is not a permutation of 0..{P - 1}")
    g = RelabeledGroup(CyclicGroup(P), order)
    b = _Builder(g)
    if P == 1:
        sched = Schedule(P=P, group=g, kind="sorted", r=0, s=1,
                         steps=(), initial_slots=b.initial_slots,
                         final_slots=b.initial_slots)
        _verify(sched)
        return sched
    s = result_multiplicity(P, r)
    _reduction_phase(b, s)
    _distribution_phase(b, r)
    sched = Schedule(P=P, group=g, kind="sorted", r=r, s=s,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched)
    return sched


@lru_cache(maxsize=None)
def build_reduce_scatter(P: int, group_kind: str = "cyclic") -> Schedule:
    """Reduction phase only (s=1): every device ends with one fully reduced
    chunk -- a reduce-scatter in ceil(log P) steps for any P."""
    g = CyclicGroup(P) if group_kind == "cyclic" else HypercubeGroup(P)
    b = _Builder(g)
    if P > 1:
        _reduction_phase(b, 1)
    sched = Schedule(P=P, group=g, kind="reduce_scatter", r=0, s=1,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched, expect_final_rows=1)
    return sched


@lru_cache(maxsize=None)
def build_all_gather(P: int, group_kind: str = "cyclic") -> Schedule:
    """Distribution phase only: start from one distributed vector (each
    device owns chunk d), end with every device owning every chunk."""
    g = CyclicGroup(P) if group_kind == "cyclic" else HypercubeGroup(P)
    b = _Builder(g)
    full = frozenset(range(P))
    b.rows = [Slot(place=0, content=full)]
    b.initial_slots = tuple(b.rows)
    if P > 1:
        _distribution_phase(b, 0)
    sched = Schedule(P=P, group=g, kind="all_gather", r=0, s=1,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched, check_initial=False)
    return sched


@lru_cache(maxsize=None)
def build_bruck_all_gather(P: int) -> Schedule:
    """Bruck's allgather [Bruck & Ho '93] in the same formalism.

    ceil(lg P) steps with power-of-two shifts 1, 2, 4, ... sending
    min(2^i, P - 2^i) rows each -- same step count and total traffic as
    the paper's distribution phase, but the shifts differ: Bruck's rows
    land at places {2^i ..} so the chunk each device holds in row k is
    rotated by the device index (the "additional data shift" the paper's
    section 7 says its own algorithm avoids).  Our executor absorbs that
    rotation in the final gather map, which is exactly the materialized
    form of the extra pass; the schedule exists to quantify the
    comparison (see tests + benchmarks).
    """
    g = CyclicGroup(P)
    b = _Builder(g)
    full = frozenset(range(P))
    b.rows = [Slot(place=0, content=full)]
    b.initial_slots = tuple(b.rows)
    n = 1
    while n < P:
        take = min(n, P - n)
        cur = b.rows
        tx = [next(x for x in cur if x.place == j) for j in range(take)]
        arrivals = [Slot(place=(j + n) % P, content=full)
                    for j in range(take)]
        new_rows = sorted(cur + arrivals, key=Slot.key)
        b.emit(n, tx, new_rows, {})
        n += take
    sched = Schedule(P=P, group=g, kind="bruck_all_gather", r=0, s=1,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched, check_initial=False, expect_final_rows=P)
    return sched


@lru_cache(maxsize=None)
def build_ring(P: int) -> Schedule:
    """The Ring algorithm (paper section 6, eq (16)): 2(P-1) steps with the
    single communication operator t (the group generator)."""
    g = CyclicGroup(P)
    b = _Builder(g)
    if P > 1:
        # reduction: accumulator starts as t^0 q_0, each step moves by t and
        # absorbs the resident vector.
        acc = b.rows[0]
        for i in range(P - 1):
            shift = 1
            moved = Slot(place=g.compose(1, acc.place), content=acc.content)
            resident = next(x for x in b.rows if x.place == moved.place
                            and x is not acc and not (x.content & moved.content))
            ns = Slot(place=moved.place, content=moved.content | resident.content)
            new_rows = [x for x in b.rows if x not in (acc, resident)] + [ns]
            new_rows.sort(key=Slot.key)
            b.emit(shift, [acc], new_rows, {ns: (resident, moved)})
            acc = ns
        # distribution: shift the result around the ring P-1 times.
        full = frozenset(range(P))
        assert acc.content == full
        cur = acc
        for i in range(P - 1):
            moved = Slot(place=g.compose(1, cur.place), content=full)
            new_rows = sorted(b.rows + [moved], key=Slot.key)
            b.emit(1, [cur], new_rows, {})
            cur = moved
        # drop the leftover singleton q rows: final slots are the full ones
        finals = sorted((x for x in b.rows if x.content == full), key=Slot.key)
        # rebuild final row list to contain only results, via a zero-comm step
        idx = b.row_index()
        b.steps.append(CommStep(shift=0, tx_rows=(),
                                out=tuple(OutOp("keep", res=idx[x]) for x in finals),
                                out_slots=tuple(finals)))
        b.rows = finals
    sched = Schedule(P=P, group=g, kind="ring", r=0, s=P,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched)
    return sched


def _traff_rs_rounds(b: _Builder) -> None:
    """Binary-merge reduce-scatter rounds (Traff, arXiv:2410.14234).

    Round k (distance ``w = 2^k``) keeps the invariant that the live
    distributed vectors sit at places ``w*j < P`` and the vector at place
    ``w*j`` has content ``[w*j, min(w*(j+1), P))`` -- a contiguous block,
    so merges never double-count for *any* P, primes included.  Odd-j
    vectors move by ``t^{-w}`` onto their even-j neighbour and merge;
    when ``w*(j+1) >= P`` the even-j vector simply survives the round.
    ceil(lg P) rounds, P-1 chunk-units total: Traff's optimal
    non-pipelined round count and volume for arbitrary P.
    """
    g = b.group
    P = b.P
    w = 1
    while w < P:
        shift = g.inverse(w % P)
        cur = b.rows
        by_place = {sl.place: sl for sl in cur}
        tx: List[Slot] = []
        combines: Dict[Slot, Tuple[Slot, Slot]] = {}
        new_rows: List[Slot] = []
        for sl in cur:
            j = sl.place // w
            if j % 2 == 1:
                tx.append(sl)                 # odd j: sent and consumed
                continue
            partner = by_place.get(sl.place + w)
            if partner is None:
                new_rows.append(sl)           # no odd neighbour: survives
            else:
                arr = Slot(place=sl.place, content=partner.content)
                ns = Slot(place=sl.place,
                          content=sl.content | partner.content)
                combines[ns] = (sl, arr)
                new_rows.append(ns)
        new_rows.sort(key=Slot.key)
        b.emit(shift, tx, new_rows, combines)
        w *= 2


def _traff_ag_rounds(b: _Builder) -> None:
    """Mirror of :func:`_traff_rs_rounds`: doubling all-gather rounds.

    Round k (descending ``w = 2^k``) starts with the result replicated at
    every place divisible by ``2w`` and sends each copy by ``t^{+w}``
    (when the target place exists), ending with all multiples of ``w``
    full; after the last round every place holds the result.  P-1
    chunk-units over ceil(lg P) rounds.
    """
    g = b.group
    P = b.P
    full = frozenset(range(P))
    for k in range(n_steps_log(P) - 1, -1, -1):
        w = 1 << k
        cur = b.rows
        tx = [sl for sl in cur if sl.place + w < P]
        arrivals = [Slot(place=g.compose(w, sl.place), content=full)
                    for sl in tx]
        new_rows = sorted(list(cur) + arrivals, key=Slot.key)
        b.emit(w % P, tx, new_rows, {})


@lru_cache(maxsize=None)
def build_traff_rounds(P: int) -> Schedule:
    """Traff's optimal non-pipelined allreduce rounds (arXiv:2410.14234).

    Reduce-scatter by binary merging at doubling distances 1, 2, 4, ...
    then the mirrored doubling all-gather: ``2*ceil(lg P)`` rounds and
    ``2*(P-1)`` chunk-units for *arbitrary* P including primes -- the
    round- and volume-optimal non-pipelined schedule.  Same aggregate
    cost as ``build_generalized(P, 0)`` but a different permutation step
    table: power-of-two shifts instead of the halving ``floor(N/2)``
    pattern, so the combine tree, the per-round ragged chunk placement
    and the skew timeline all differ -- which is exactly why it enters
    the tuning grid as its own family.

    >>> s = build_traff_rounds(7)
    >>> s.n_steps, s.units_sent, s.units_reduced
    (6, 12, 6)
    >>> sorted(st.shift for st in s.steps[:3])   # RS shifts: -1, -2, -4
    [3, 5, 6]
    """
    if P < 1:
        raise InvalidScheduleError("P must be >= 1")
    g = CyclicGroup(P)
    b = _Builder(g)
    if P > 1:
        _traff_rs_rounds(b)
        _traff_ag_rounds(b)
    sched = Schedule(P=P, group=g, kind="traff_rounds", r=0, s=1,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched)
    return sched


def _dual_root_distribution(b: _Builder, h: int) -> None:
    """Doubling broadcast from the two roots over their ring halves.

    Root place 0 covers places ``[0, h)``, root place ``h`` covers
    ``[h, P)``; distribution round k moves full copies by the shared
    shift ``t^{+2^k}`` inside both halves at once, so both roots stay
    active every round.  ``ceil(lg h)`` rounds (the larger half
    dominates).
    """
    g = b.group
    P = b.P
    full = frozenset(range(P))
    blocks = ((0, h), (h, P - h))               # (start, size) per root
    w = 1
    while w < h:
        by_place = {sl.place: sl for sl in b.rows}
        tx: List[Slot] = []
        new_rows: List[Slot] = list(b.rows)
        for start, size in blocks:
            for rel in range(w, min(2 * w, size)):
                tx.append(by_place[start + rel - w])
                new_rows.append(Slot(place=start + rel, content=full))
        new_rows.sort(key=Slot.key)
        b.emit(w % P, tx, new_rows, {})
        w *= 2


@lru_cache(maxsize=None)
def build_dual_root(P: int) -> Schedule:
    """Dual-root reduction-to-all (after Traff, arXiv:2109.12626).

    The reduction phase runs two relabelled copies whose roots sit half a
    ring apart (copy offsets ``{0, ceil(P/2)}`` via
    :func:`_reduction_phase`), producing two fully-reduced distributed
    vectors; the distribution phase then doubles each root's copy out
    over its own half of the place ring with one shared shift per round
    (:func:`_dual_root_distribution`).  Total ``2*ceil(lg P) - 1``
    rounds -- one fewer than the bandwidth-optimal AR(0) -- at the
    bandwidth of AR(1), a distinct latency/bandwidth point for the
    tuning grid.  The paper's *double* pipelining (the second root's
    up-phase overlapping the first root's down-phase) is expressed by
    the executor's existing ``n_buckets`` software pipelining over this
    schedule's tick structure.

    >>> s = build_dual_root(8)
    >>> s.n_steps, s.s
    (5, 2)
    >>> sorted(sl.place for sl in s.final_slots) == list(range(8))
    True
    >>> build_dual_root(2).n_steps         # degenerate: one exchange
    1
    """
    if P < 1:
        raise InvalidScheduleError("P must be >= 1")
    g = CyclicGroup(P)
    b = _Builder(g)
    if P == 1:
        sched = Schedule(P=P, group=g, kind="dual_root", r=0, s=1,
                         steps=(), initial_slots=b.initial_slots,
                         final_slots=b.initial_slots)
        _verify(sched)
        return sched
    h = (P + 1) // 2
    _reduction_phase(b, 2, offsets=(0, h))
    _dual_root_distribution(b, h)
    sched = Schedule(P=P, group=g, kind="dual_root", r=0, s=2,
                     steps=tuple(b.steps), initial_slots=b.initial_slots,
                     final_slots=tuple(b.rows))
    _verify(sched)
    return sched


# --------------------------------------------------------------------------
#  verification
# --------------------------------------------------------------------------

def _verify(sched: Schedule, expect_final_rows: Optional[int] = None,
            check_initial: bool = True) -> None:
    """Structural checks; numeric equivalence is covered by the simulator."""
    P = sched.P
    full = frozenset(range(P))
    if expect_final_rows is None and sched.kind in ("generalized", "ring",
                                                    "sorted", "traff_rounds",
                                                    "dual_root"):
        expect_final_rows = P
    if expect_final_rows is not None and len(sched.final_slots) != expect_final_rows:
        raise InvalidScheduleError(
            f"{sched.kind}: final rows {len(sched.final_slots)} != {expect_final_rows}")
    for sl in sched.final_slots:
        if sl.content != full:
            raise InvalidScheduleError(f"final slot {sl} not fully reduced")
    if sched.kind in ("generalized", "ring", "sorted", "traff_rounds",
                      "dual_root"):
        places = sorted(s.place for s in sched.final_slots)
        if places != list(range(P)):
            raise InvalidScheduleError(f"final placements {places} incomplete")
    # replay symbolically to make sure indices are coherent
    rows = list(sched.initial_slots)
    for k, st in enumerate(sched.steps):
        arrivals = []
        for ri in st.tx_rows:
            src = rows[ri]
            arrivals.append(Slot(place=sched.group.compose(st.shift, src.place),
                                 content=src.content))
        nxt = []
        for op, meta in zip(st.out, st.out_slots):
            if op.kind == "keep":
                got = rows[op.res]
            elif op.kind == "recv":
                got = arrivals[op.arr]
            else:
                a, b_ = rows[op.res], arrivals[op.arr]
                if a.place != b_.place:
                    raise InvalidScheduleError(f"step {k}: add place mismatch")
                if a.content & b_.content:
                    raise InvalidScheduleError(f"step {k}: add double-count")
                got = Slot(place=a.place, content=a.content | b_.content)
            if got != meta:
                raise InvalidScheduleError(f"step {k}: slot mismatch {got} != {meta}")
            nxt.append(got)
        rows = nxt
    if tuple(rows) != sched.final_slots:
        raise InvalidScheduleError("replay does not reach final slots")


# --------------------------------------------------------------------------
#  per-step placement tables (ragged true-byte accounting)
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def step_place_tables(sched: Schedule) -> Tuple[Tuple[Tuple[int, ...], ...],
                                                Tuple[Tuple[int, ...], ...]]:
    """Per-step group-element places of the TX rows and the combine outputs.

    Returns ``(tx_places, add_places)``: for step ``k``, ``tx_places[k][j]``
    is the place of the j-th transmitted slot *before* the shift (device
    ``d`` therefore sends its piece of chunk ``t_e^{-1}(d)``), and
    ``add_places[k][i]`` is the place of the i-th combined output slot.
    These are what turn a per-chunk size vector into exact per-device,
    per-step moved/reduced element counts -- the quantities the ragged
    cost model charges instead of a uniform ``m / P``.
    """
    rows: Tuple[Slot, ...] = sched.initial_slots
    tx_places: List[Tuple[int, ...]] = []
    add_places: List[Tuple[int, ...]] = []
    for st in sched.steps:
        tx_places.append(tuple(rows[ri].place for ri in st.tx_rows))
        add_places.append(tuple(meta.place
                                for op, meta in zip(st.out, st.out_slots)
                                if op.kind == "add"))
        rows = st.out_slots
    return tuple(tx_places), tuple(add_places)


@lru_cache(maxsize=None)
def _place_chunk_table(sched: Schedule):
    """For every group-element place a schedule's steps mention:
    ``tbl[e][d] = t_e^{-1}(d)``, the chunk the slot placed at ``e``
    holds on device ``d``.  Vectorized over the mixed-radix digits and
    built only for the places actually used (O(P) per place), so large
    flattened device indexes never materialize an O(P^2) action table.
    Cached per schedule: the key set is the small set of compiled
    schedules, each entry O(n_places * P).

    A :class:`repro.core.group.RelabeledGroup` acts through its device
    relabeling pi: tbl'[e][p] = pi[tbl_base[e][pi^-1[p]]] -- the base
    group's vectorized digit arithmetic composed with the permutation,
    never an O(P^2) action table."""
    import numpy as np
    g = sched.group
    relabel = getattr(g, "relabel", None)
    if relabel is not None:
        g = g.base
    P = g.order
    x = np.arange(P, dtype=np.int64)
    digs = []
    for r in reversed(g.radices):
        digs.append(x % r)
        x = x // r
    digs = np.stack(list(reversed(digs)), axis=1)            # (P, n)
    radices = np.asarray(g.radices, dtype=np.int64)
    tx_places, add_places = step_place_tables(sched)
    needed = sorted({e for places in tx_places + add_places
                     for e in places})
    if relabel is not None:
        pi = np.asarray(relabel, dtype=np.int64)
        pi_inv = np.empty(P, dtype=np.int64)
        pi_inv[pi] = np.arange(P, dtype=np.int64)
    out = {}
    for e in needed:
        diff = (digs - digs[e]) % radices                    # (P, n)
        idx = np.zeros(P, dtype=np.int64)
        for k, r in enumerate(g.radices):
            idx = idx * r + diff[:, k]
        if relabel is not None:
            idx = pi[idx[pi_inv]]
        idx.setflags(write=False)
        out[e] = idx
    return out


# bounded: keyed by message length, whose cardinality is unbounded in a
# long-lived process (entries are small tuples, but they never die)
@lru_cache(maxsize=4096)
def ragged_step_units(sched: Schedule, m: int) -> Tuple[Tuple[int, ...],
                                                        Tuple[int, ...]]:
    """Exact per-step SPMD element counts for an ``m``-element message.

    For every step, the *maximum over devices* of the true elements that
    device transmits / combines under the balanced ragged split -- an
    SPMD step completes when the slowest transfer lands, so this is the
    width the alpha-beta-gamma model should charge.  For ``m`` divisible
    by ``P`` every chunk has ``m // P`` elements and the counts collapse
    to the uniform ``n_tx * m/P`` / ``n_adds * m/P``.

    >>> sched = build_reduce_scatter(4)
    >>> ragged_step_units(sched, 8)     # uniform: 2 elements per chunk
    ((4, 2), (4, 2))
    >>> ragged_step_units(sched, 9)     # ragged: no device moves 2*ceil
    ((5, 3), (5, 3))
    """
    import numpy as np
    P = sched.P
    sizes = np.asarray(ragged_sizes(m, P), dtype=np.int64)
    tbl = _place_chunk_table(sched)
    tx_places, add_places = step_place_tables(sched)

    def units(places: Tuple[int, ...]) -> int:
        if not places:
            return 0
        # per-device true elements: sum over slots of this device's chunk
        per_dev = np.zeros(P, dtype=np.int64)
        for e in places:
            per_dev += sizes[tbl[e]]
        return int(per_dev.max())

    return (tuple(units(txp) for txp in tx_places),
            tuple(units(addp) for addp in add_places))


# --------------------------------------------------------------------------
#  convenience
# --------------------------------------------------------------------------

def max_r(P: int) -> int:
    return n_steps_log(P)


def schedule_summary(sched: Schedule) -> dict:
    """Step/traffic accounting of a compiled schedule (units of one chunk).

    >>> schedule_summary(build_ring(4))  # doctest: +NORMALIZE_WHITESPACE
    {'P': 4, 'kind': 'ring', 'group': 'Z4', 'r': 0, 's': 4, 'steps': 7,
     'units_sent': 6, 'units_reduced': 3, 'max_rows': 4}
    """
    return {
        "P": sched.P,
        "kind": sched.kind,
        "group": sched.group.describe(),
        "r": sched.r,
        "s": sched.s,
        "steps": sched.n_steps,
        "units_sent": sched.units_sent,
        "units_reduced": sched.units_reduced,
        "max_rows": sched.max_rows,
    }
