"""Abelian transitive permutation groups used to describe communication.

The paper ("A Generalization of the Allreduce Operation", Kolmakov & Zhang,
2020) describes communication between P processes by an abelian permutation
group T_P = {t_0 .. t_{P-1}} of order P acting transitively on {0..P-1}.

Every finite abelian transitive group of order P acting on P points is (up to
relabeling) a direct product of cyclic groups Z_{p1} x ... x Z_{pn} with
P = p1 * ... * pn, acting on the mixed-radix representation of the point
index.  We therefore implement the whole family with a single `MixedRadixGroup`:

  * ``CyclicGroup(P)``     == MixedRadixGroup([P])          -- Ring-style shifts.
  * ``HypercubeGroup(2^k)`` == MixedRadixGroup([2]*k)        -- the group H of the
    paper's Table 1.b, whose elements are self-inverse; with it the
    bandwidth-optimal / latency-optimal algorithms reduce exactly to
    Recursive Halving / Recursive Doubling.

Group elements are indexed 0..P-1; index arithmetic is digit-wise modular
addition over the radix vector.  ``t_0`` is always the identity.

The action on process ranks:  ``apply(g, p)`` = rank reached from ``p`` by the
permutation ``t_g``.  For the cyclic group this is ``(p + g) % P``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Sequence, Tuple


def _to_digits(x: int, radices: Tuple[int, ...]) -> Tuple[int, ...]:
    out = []
    for r in reversed(radices):
        out.append(x % r)
        x //= r
    return tuple(reversed(out))


def _from_digits(digits: Sequence[int], radices: Tuple[int, ...]) -> int:
    x = 0
    for d, r in zip(digits, radices):
        x = x * r + d
    return x


@dataclass(frozen=True)
class MixedRadixGroup:
    """Direct product of cyclic groups Z_{r0} x Z_{r1} x ... acting on
    {0 .. prod(r)-1} via digit-wise modular addition.

    This is an abelian, transitive permutation group of order P = prod(r).
    """

    radices: Tuple[int, ...]

    def __post_init__(self):
        if not self.radices or any(r < 1 for r in self.radices):
            raise ValueError(f"invalid radices {self.radices}")

    @property
    def order(self) -> int:
        return math.prod(self.radices)

    # --- element arithmetic (elements are indices 0..P-1) -------------
    def compose(self, a: int, b: int) -> int:
        """Index of t_a . t_b (abelian, so order does not matter)."""
        da = _to_digits(a, self.radices)
        db = _to_digits(b, self.radices)
        return _from_digits(
            [(x + y) % r for x, y, r in zip(da, db, self.radices)], self.radices
        )

    def inverse(self, a: int) -> int:
        da = _to_digits(a, self.radices)
        return _from_digits([(-x) % r for x, r in zip(da, self.radices)], self.radices)

    def apply(self, g: int, p: int) -> int:
        """Rank that the permutation t_g maps rank ``p`` to."""
        return self.compose(g, p)

    def perm(self, g: int):
        """Full permutation table of t_g: perm[p] = t_g(p)."""
        return [self.apply(g, p) for p in range(self.order)]

    @property
    def is_cyclic(self) -> bool:
        return len(self.radices) == 1

    def describe(self) -> str:
        return "Z" + "xZ".join(str(r) for r in self.radices)

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"MixedRadixGroup({self.describe()})"


@dataclass(frozen=True)
class RelabeledGroup:
    """A :class:`MixedRadixGroup` acting through a device relabeling.

    ``relabel[j]`` is the physical device standing at logical position
    ``j`` of the base group's enumeration.  Element arithmetic (compose /
    inverse, i.e. everything the schedule compiler reasons about) is the
    base group's unchanged; only the *action* on device ranks is
    conjugated: ``apply(g, p) = relabel[base.apply(g, relabel^-1[p])]``.
    Conjugation preserves every group law, so a schedule compiled over a
    relabeled group is the same symbolic object replayed on permuted
    devices -- this is how the skew-sorted allreduce assigns late
    arrivals to forgiving positions without touching the compiler.

    >>> g = RelabeledGroup(CyclicGroup(4), (2, 0, 3, 1))
    >>> g.order, g.inverse(3), g.compose(1, 2)   # element arithmetic: base
    (4, 1, 3)
    >>> g.apply(1, 2)   # device 2 is logical 0; t_1 -> logical 1 = device 0
    0
    >>> sorted(g.perm(1)) == [0, 1, 2, 3]        # still a permutation
    True
    """

    base: MixedRadixGroup
    relabel: Tuple[int, ...]

    def __post_init__(self):
        if sorted(self.relabel) != list(range(self.base.order)):
            raise ValueError(
                f"relabel {self.relabel} is not a permutation of "
                f"0..{self.base.order - 1}")

    @property
    def order(self) -> int:
        return self.base.order

    @property
    def radices(self) -> Tuple[int, ...]:
        return self.base.radices

    def logical(self, p: int) -> int:
        """Logical position of physical device ``p`` (relabel^-1)."""
        return self.relabel.index(p)

    def compose(self, a: int, b: int) -> int:
        return self.base.compose(a, b)

    def inverse(self, a: int) -> int:
        return self.base.inverse(a)

    def apply(self, g: int, p: int) -> int:
        return self.relabel[self.base.apply(g, self.logical(p))]

    def perm(self, g: int):
        return [self.apply(g, p) for p in range(self.order)]

    @property
    def is_cyclic(self) -> bool:
        return self.base.is_cyclic

    def describe(self) -> str:
        return f"{self.base.describe()}@{','.join(map(str, self.relabel))}"

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"RelabeledGroup({self.describe()})"


def CyclicGroup(P: int) -> MixedRadixGroup:
    """The cyclic group T_P with generator c = (1 2 ... P-1 0).

    Works for every P (including primes); this is the default group of the
    generalized allreduce and maps directly onto a TPU ICI ring via
    ``lax.ppermute`` with a constant shift.

    >>> g = CyclicGroup(5)
    >>> g.apply(2, 4)                  # t_2 maps rank 4 to rank 1
    1
    >>> g.compose(3, 4), g.inverse(3)  # index arithmetic mod 5
    (2, 2)
    >>> g.perm(1)                      # the generator's ppermute table
    [1, 2, 3, 4, 0]
    """
    return MixedRadixGroup((P,))


def HypercubeGroup(P: int) -> MixedRadixGroup:
    """Elementary abelian 2-group (paper Table 1.b).  Requires P = 2^k.

    With this group the generalized algorithm reproduces Recursive
    Halving (r=0) / Recursive Doubling (r=log P) exactly: every element is
    self-inverse so each communication step is a pairwise exchange.
    """
    k = P.bit_length() - 1
    if P != 1 << k:
        raise ValueError(f"HypercubeGroup needs power-of-two order, got {P}")
    return MixedRadixGroup(tuple([2] * max(k, 1)))


@lru_cache(maxsize=None)
def default_group(P: int, kind: str = "cyclic") -> MixedRadixGroup:
    if kind == "cyclic":
        return CyclicGroup(P)
    if kind == "hypercube":
        return HypercubeGroup(P)
    raise ValueError(f"unknown group kind {kind!r}")
