"""Pure-numpy multi-process simulator for compiled schedules.

Executes a :class:`~repro.core.schedule.Schedule` over P simulated
processes, each owning a vector of m elements.  This is the oracle used by
the test-suite to prove numeric correctness of every schedule for arbitrary
P and r, and by the benchmark harness to count per-step traffic.  The
replay is kind-agnostic: every family the compiler emits -- generalized
AR(r), ring, the arrival-sorted relabeling, Traeff's optimal rounds
(``traff_rounds``) and the dual-root reduction-to-all (``dual_root``) --
runs through the same step loop with no family-specific cases.

The simulator mirrors exactly what the JAX ``shard_map`` executor does,
just with explicit per-process state instead of SPMD code.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from .execplan import final_row_table, initial_row_table
from .schedule import Schedule, ShapeError, ragged_offsets, ragged_sizes


@dataclass
class SimTrace:
    """Per-step traffic accounting (units of one chunk per device)."""

    steps: int
    units_sent_per_device: int
    adds_per_device: int


def _chunks(vec: np.ndarray, P: int) -> List[np.ndarray]:
    """Split a vector into P exact ragged chunks (balanced split).

    No padding: chunk ``c`` really has ``ragged_sizes(m, P)[c]`` elements.
    The symbolic replay moves whole rows between processes and only ever
    combines rows holding the *same* chunk index on each device, so
    variable-width chunks flow through every schedule unchanged -- this
    is the true-moved-bytes oracle the ragged cost model prices.
    """
    sizes = ragged_sizes(vec.shape[0], P)
    offs = ragged_offsets(sizes)
    return [vec[offs[c]:offs[c] + sizes[c]] for c in range(P)]


def _initial_state(sched: Schedule,
                   vectors: List[np.ndarray]) -> List[List[np.ndarray]]:
    """Per-device row state from the schedule's initial slot layout.

    The placement table is cached per schedule (see
    :func:`repro.core.execplan.initial_row_table`), so repeated
    simulations stop re-running the O(P^2) placement loops.
    """
    P = sched.P
    tbl = initial_row_table(sched)
    state: List[List[np.ndarray]] = []
    for d in range(P):
        ch = _chunks(vectors[d], P)
        state.append([ch[tbl[row, d]].copy()
                      for row in range(len(sched.initial_slots))])
    return state


def _replay(sched: Schedule, state: List[List[np.ndarray]],
            op: Callable[[np.ndarray, np.ndarray], np.ndarray] = np.add):
    """Replay the compiled steps over per-device row state, in place.

    Returns (units_sent_per_device, adds_per_device).
    """
    P = sched.P
    units_sent = 0
    adds = 0
    for st in sched.steps:
        # communications: device d sends its piece of each TX row to
        # device perm[d] where perm = action of the shift element.
        perm = sched.group.perm(st.shift)
        arrivals: List[List[np.ndarray]] = [[None] * len(st.tx_rows)
                                            for _ in range(P)]
        for d in range(P):
            for j, ri in enumerate(st.tx_rows):
                arrivals[perm[d]][j] = state[d][ri]
        units_sent += len(st.tx_rows)
        for d in range(P):
            new_rows = []
            for o in st.out:
                if o.kind == "keep":
                    new_rows.append(state[d][o.res])
                elif o.kind == "recv":
                    new_rows.append(arrivals[d][o.arr])
                else:
                    new_rows.append(op(state[d][o.res], arrivals[d][o.arr]))
            state[d] = new_rows
        adds += sum(1 for o in st.out if o.kind == "add")
    return units_sent, adds


def simulate(sched: Schedule, vectors: List[np.ndarray],
             op: Callable[[np.ndarray, np.ndarray], np.ndarray] = np.add,
             return_trace: bool = False):
    """Run the schedule over explicit per-process vectors.

    vectors: list of P arrays of identical shape (m, ...).
    Returns list of P result arrays (each the full reduction), optionally
    with a :class:`SimTrace`.  Any length works -- uneven sizes flow
    through as true variable-width chunks (see :func:`_chunks`).

    >>> import numpy as np
    >>> from repro.core.schedule import build_generalized
    >>> vecs = [np.arange(5) + 10 * d for d in range(3)]   # 5 % 3 != 0
    >>> out = simulate(build_generalized(3, 0), vecs)
    >>> out[0].tolist()                 # every rank: the exact full sum
    [30, 33, 36, 39, 42]
    """
    P = sched.P
    assert len(vectors) == P
    # uniform-length contract: a device with a different m would produce
    # chunks of the wrong width, which numpy broadcasting could silently
    # swallow (e.g. a width-1 chunk against a width-2 resident) -- raise
    # the typed error instead of mis-reducing
    for d, v in enumerate(vectors[1:], start=1):
        if v.shape != vectors[0].shape:
            raise ShapeError(f"simulate: device {d} vector shape disagrees",
                             expected=vectors[0].shape, actual=v.shape)

    state = _initial_state(sched, vectors)
    units_sent, adds = _replay(sched, state, op)

    # gather: reduced chunk c of device d sits in final row tbl[c, d]
    # (cached per schedule)
    tbl = final_row_table(sched)
    results = []
    for d in range(P):
        out_chunks: List[Optional[np.ndarray]] = [
            state[d][tbl[c, d]] if tbl[c, d] >= 0 else None
            for c in range(P)]
        if any(c is None for c in out_chunks):
            # partial results (reduce-scatter): return rows as-is
            results.append([c for c in out_chunks if c is not None])
        else:
            # exact ragged chunks concatenate back to exactly m elements
            results.append(np.concatenate(out_chunks))
    trace = SimTrace(steps=sched.n_steps, units_sent_per_device=units_sent,
                     adds_per_device=adds)
    return (results, trace) if return_trace else results


def simulate_reduce_scatter(sched: Schedule, vectors: List[np.ndarray],
                            op: Callable[[np.ndarray, np.ndarray],
                                         np.ndarray] = np.add):
    """Like :func:`simulate` but for reduce-scatter schedules: returns, per
    device, the single fully reduced chunk it owns (device d owns chunk d for
    the canonical place-0 result)."""
    P = sched.P
    state = _initial_state(sched, vectors)
    _replay(sched, state, op)
    return [state[d][0] for d in range(P)], [
        sched.final_chunk_index(0, d) for d in range(P)]


def simulate_all_gather(sched: Schedule, chunks: List[np.ndarray]):
    """Replay an all-gather schedule: device d contributes ``chunks[d]``
    (the canonical place-0 layout, i.e. chunk d of the result), every
    device returns the concatenation of all chunks."""
    P = sched.P
    assert len(chunks) == P
    state: List[List[np.ndarray]] = [[chunks[d].copy()] for d in range(P)]
    _replay(sched, state)
    tbl = final_row_table(sched)
    assert (tbl >= 0).all()
    return [np.concatenate([state[d][tbl[c, d]] for c in range(P)])
            for d in range(P)]
