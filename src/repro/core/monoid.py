"""First-class combine operators for the generalized collective family.

The paper's group-theoretic machinery (sections 5-9) describes
*communication*: which rows move under which group element, and which
resident row each arrival pairs with.  Nothing in the schedules, the
ExecPlan lowering, or the pipelined replay depends on the pairing being
``+`` -- any associative binary operation with an identity factors
through the exact same permutation step tables (this is how Traeff's
reduce-scatter/allreduce family and MPI's ``MPI_Op`` treat the
collective: one parameterized object, not one algorithm per operator).

A :class:`Monoid` packages everything an executor layer needs to run a
schedule under a different operator:

* ``kind``    -- the elementwise combine ("add" | "max" | "min" |
  "custom"); the first three route through the fused Pallas kernel
  (:func:`repro.kernels.fused_combine.combine_n`) on TPU;
* ``identity``-- the neutral element (used by tests to check the monoid
  laws; the executors themselves never need it -- ragged/bucket padding
  columns are dropped by the final gather before they can meet data);
* ``pre_scale`` / ``post_divide`` -- the affine bookends that turn the
  plain reduction into ``premul_sum`` (NCCL's ``ncclRedOpPreMulSum``)
  and ``mean``;
* ``gamma_scale`` -- per-monoid combine cost relative to a plain add,
  consumed by the alpha-beta-gamma cost model (a custom op that is not
  one fused VPU instruction per element should say so here).

Padding-safety note: every executor layer zero-fills physical chunk
tails (ragged split) and bucket padding.  That is safe for *any*
elementwise monoid -- combines never mix columns, tails are dropped by
exact-prefix extraction -- so ``identity`` is a law-checking aid, not a
correctness requirement of the replay.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import numpy as np

_NP_OPS = {"add": np.add, "max": np.maximum, "min": np.minimum}


@dataclass(frozen=True)
class Monoid:
    """An associative combine with identity, plus executor metadata.

    ``fn`` (and ``np_fn`` for the numpy oracles) override the built-in
    elementwise op when ``kind == "custom"``.  Instances are hashable so
    autotuner caches can key on them.

    >>> SUM.kind, MAX.kind, MEAN.post_divide
    ('add', 'max', True)
    >>> premul_sum(0.5).pre_scale
    0.5
    """

    name: str
    kind: str = "add"               # "add" | "max" | "min" | "custom"
    gamma_scale: float = 1.0        # combine cost relative to a plain add
    pre_scale: Optional[float] = None   # multiply inputs before reducing
    post_divide: bool = False       # divide by P after reducing (mean)
    fn: Optional[Callable] = field(default=None, compare=False, repr=False)
    np_fn: Optional[Callable] = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.kind not in ("add", "max", "min", "custom"):
            raise ValueError(f"unknown monoid kind {self.kind!r}")
        if self.kind == "custom" and self.fn is None:
            raise ValueError("custom monoid needs fn=")

    # ------------------------------------------------------------ ops
    @property
    def jax_op(self) -> Callable:
        """Elementwise binary combine for traced (jnp) operands."""
        if self.kind == "custom":
            return self.fn
        import jax.numpy as jnp
        return {"add": jnp.add, "max": jnp.maximum,
                "min": jnp.minimum}[self.kind]

    @property
    def np_op(self) -> Callable:
        """Elementwise binary combine for the numpy oracles."""
        if self.kind == "custom":
            return self.np_fn if self.np_fn is not None else self.fn
        return _NP_OPS[self.kind]

    @property
    def fuses_pallas(self) -> bool:
        """Whether the fused Pallas ``combine_n`` kernel implements it."""
        return self.kind in ("add", "max", "min")

    # ------------------------------------------------------------ laws
    def identity(self, dtype) -> np.ndarray:
        """Neutral element as a zero-dim array of ``dtype``.

        >>> int(SUM.identity(np.int32)), int(MAX.identity(np.int32))
        (0, -2147483648)
        """
        dt = np.dtype(dtype)
        if self.kind == "add":
            return np.zeros((), dt)
        if self.kind == "max":
            return np.array(np.finfo(dt).min if dt.kind == "f"
                            else np.iinfo(dt).min, dt)
        if self.kind == "min":
            return np.array(np.finfo(dt).max if dt.kind == "f"
                            else np.iinfo(dt).max, dt)
        raise NotImplementedError(f"no identity recorded for {self.name}")

    # -------------------------------------------------- affine bookends
    def prepare(self, x, P: int):
        """Apply the pre-reduction bookend (premul_sum's scale).

        The scale is applied in the input dtype (no hidden widening), so
        a fractional factor on an integer buffer would silently truncate
        to zero -- that is refused loudly instead:

        >>> premul_sum(0.5).prepare(np.float32([4.0, 6.0]), 2).tolist()
        [2.0, 3.0]
        >>> premul_sum(0.5).prepare(np.int32([4, 6]), 2)
        Traceback (most recent call last):
            ...
        TypeError: premul_sum(0.5) on integer dtype int32 would truncate \
the factor; cast to an inexact dtype first
        """
        if self.pre_scale is None:
            return x
        dt = np.dtype(getattr(x, "dtype", np.float64))
        if dt.kind in "iub" and self.pre_scale != int(self.pre_scale):
            raise TypeError(
                f"premul_sum({self.pre_scale:g}) on integer dtype {dt} "
                f"would truncate the factor; cast to an inexact dtype "
                f"first")
        return x * np.asarray(self.pre_scale, dtype=dt)

    def finalize(self, x, P: int):
        """Apply the post-reduction bookend (mean's divide)."""
        if self.post_divide:
            return x / P
        return x

    def reference(self, stacked: np.ndarray) -> np.ndarray:
        """Ground-truth reduction of a (P, ...) numpy stack -- what the
        matching ``lax`` collective (psum/pmax/pmin, mean = psum / P)
        computes.

        >>> MEAN.reference(np.array([[2.0, 4.0], [4.0, 8.0]])).tolist()
        [3.0, 6.0]
        """
        P = stacked.shape[0]
        x = self.prepare(stacked, P)
        out = x[0]
        for d in range(1, P):
            out = self.np_op(out, x[d])
        return self.finalize(out, P)


SUM = Monoid("sum", "add")
MAX = Monoid("max", "max")
MIN = Monoid("min", "min")
MEAN = Monoid("mean", "add", post_divide=True)


def premul_sum(factor: float, name: Optional[str] = None) -> Monoid:
    """NCCL-style pre-multiplied sum: every input is scaled by ``factor``
    before reduction (e.g. loss-scale unscaling fused into the gradient
    allreduce).  The combine itself stays a plain add, so it rides the
    fused kernel; only the O(m) prepare pass is extra."""
    return Monoid(name or f"premul_sum({factor:g})", "add",
                  pre_scale=float(factor))


def custom(fn: Callable, *, name: str = "custom", np_fn: Optional[Callable] = None,
           gamma_scale: float = 1.0) -> Monoid:
    """Wrap an arbitrary associative ``fn(a, b)`` as a Monoid.  The
    caller vouches for associativity; the conformance harness checks it
    on integer samples for the built-ins."""
    return Monoid(name, "custom", fn=fn, np_fn=np_fn,
                  gamma_scale=gamma_scale)


MONOIDS = {"sum": SUM, "add": SUM, "max": MAX, "min": MIN, "mean": MEAN}

# legacy execplan combine= spellings that select an *implementation* for
# the sum monoid rather than an operator
_IMPL_STRINGS = ("auto", "pallas")

CombineLike = Union[str, Monoid, Callable]


def resolve_combine(combine: CombineLike) -> tuple:
    """Normalize an executor ``combine=`` argument to ``(monoid, impl)``.

    Accepted spellings (the historical impl strings stay valid so every
    existing call site keeps its meaning):

    * a :class:`Monoid`                      -> (monoid, "auto")
    * "sum" / "max" / "min" / "mean"         -> (that monoid, "auto")
    * "auto" / "pallas"                      -> (SUM, that impl)
    * "add"                                  -> (SUM, "op") -- the
      historical "plain jnp.add, no Pallas" spelling
    * "<op>:pallas" e.g. "max:pallas"        -> (op, "pallas")
    * a bare callable                        -> (custom monoid, "op")

    >>> resolve_combine("max")[0].name, resolve_combine("max")[1]
    ('max', 'auto')
    >>> resolve_combine("pallas")
    (Monoid(name='sum', kind='add', gamma_scale=1.0, pre_scale=None, \
post_divide=False), 'pallas')
    >>> resolve_combine("min:pallas")[1]
    'pallas'
    """
    if isinstance(combine, Monoid):
        return combine, "auto"
    if callable(combine):
        return custom(combine), "op"
    if not isinstance(combine, str):
        raise TypeError(f"combine must be a str, Monoid or callable, "
                        f"got {type(combine).__name__}")
    if combine == "add":
        return SUM, "op"
    if combine in _IMPL_STRINGS:
        return SUM, combine
    name, sep, impl = combine.partition(":")
    monoid = MONOIDS.get(name)
    if monoid is None:
        raise ValueError(
            f"unknown combine {combine!r}: expected a Monoid, a callable, "
            f"one of {sorted(set(MONOIDS))}, 'auto'/'add'/'pallas', or "
            f"'<op>:pallas'")
    if sep and impl not in ("pallas", "op", "auto"):
        raise ValueError(f"unknown combine impl {impl!r} in {combine!r}")
    return monoid, (impl if sep else "auto")
