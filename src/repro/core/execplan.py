"""Lowered execution layer: Schedule -> ExecPlan -> vectorized replay.

The schedule compiler (:mod:`repro.core.schedule`) emits symbolic steps
over *row lists*; the original executor replayed them as Python lists of
per-device ``(u,)`` arrays with a ``jnp.stack``/unstack round-trip per
step and per-row Python loops rebuilt at every trace.  This module
compiles a verified :class:`~repro.core.schedule.Schedule` **once** into
an :class:`ExecPlan` of dense, static numpy index tables, then executes
the whole replay *in place* on a single stacked ``(R, u)`` buffer:

* the compiler register-allocates every live distributed vector to a
  fixed **slot** of the buffer for its whole lifetime: rows that a step
  keeps are never copied, a combine writes its result into the slot of
  the resident row it consumes, and a received row lands in a slot freed
  by a row that died -- so each step is one static gather feeding the
  ``ppermute`` plus two static in-place updates (slices where the slots
  are contiguous, scatters otherwise), instead of one op per live row;
* the slot tables compose every storage reordering, so no permutation
  is ever materialized at runtime; zero-communication bookkeeping steps
  (e.g. the Ring schedule's final row compaction) fold away entirely;
* initial/final placement tables (previously rebuilt with O(P^2) Python
  loops at every trace) are precomputed and cached per schedule.

On top of the lowered plan, :func:`execute` implements **multi-bucket
software pipelining**: the caller splits the message into ``n_buckets``
bucket buffers and the tick loop stages bucket ``k``'s ``ppermute``
while bucket ``k-1``'s combines run (program order within a tick: all
sends first, then all combines), which lets an asynchronous backend
overlap the wire time of one bucket with the combine time of another --
the doubly-pipelined structure of Traeff (arXiv:2109.12626).  All
combines of a tick are batched into one fused call routed through the
Pallas :func:`~repro.kernels.fused_combine.combine_n` kernel instead of
per-bucket chained ``jnp.add`` -- by default on TPU only; off-TPU
``combine="auto"`` stays on ``jnp.add`` (interpret-mode Pallas is a
correctness path, not a fast one) and ``combine="pallas"`` opts into
the kernel explicitly.

:func:`simulate_plan` is a pure-numpy runner over the *same* tables,
used by the tests to prove the lowering bit-exact against the symbolic
simulator oracle for every (P, r, kind).

The training stack feeds this executor two ways: the post-backward path
reduces one flat gradient tensor through a single (possibly
multi-bucket) :func:`execute`, while the backward-overlapped path
(:func:`repro.parallel.api.attach_overlap_sync`) dispatches one
``execute`` per reverse-layer gradient bucket *as the backward pass
produces it*, tagging each dispatch (``tag="grad_bucket<k>"``) so the
trace timeline and the exposed-comm roofline
(:func:`repro.core.cost_model.overlap_tick_costs`) can line the
per-bucket dispatches up against backward compute.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace as obs_trace

from .monoid import CombineLike, Monoid, resolve_combine
from .schedule import Schedule, ShapeError, ragged_offsets, ragged_sizes


def _frozen(a) -> np.ndarray:
    a = np.asarray(a, dtype=np.int32)
    a.setflags(write=False)
    return a


# ---------------------------------------------------------------------------
#  cached placement tables (previously O(P^2) Python loops per trace)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def initial_row_table(sched: Schedule) -> np.ndarray:
    """tbl[row, d] = which local chunk device d puts in initial row."""
    P = sched.P
    R = len(sched.initial_slots)
    tbl = np.zeros((R, P), dtype=np.int32)
    for k in range(R):
        for d in range(P):
            tbl[k, d] = sched.chunk_of_initial_row(k, d)
    return _frozen(tbl)


@lru_cache(maxsize=None)
def final_row_table(sched: Schedule) -> np.ndarray:
    """tbl[c, d] = which final *schedule* row holds reduced chunk c on d
    (-1 where the schedule does not materialize that chunk)."""
    P = sched.P
    tbl = np.full((P, P), -1, dtype=np.int32)
    for k in range(len(sched.final_slots)):
        for d in range(P):
            tbl[sched.final_chunk_index(k, d), d] = k
    return _frozen(tbl)


# ---------------------------------------------------------------------------
#  the lowered plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExecStep:
    """One lowered communication step over the slot-allocated buffer.

    Execution (all reads of ``buf`` precede all writes; destination slot
    sets are disjoint by construction):

        tx  = buf[tx_slots]                        # one static gather
        rx  = ppermute(tx)
        buf[add_dst] = buf[add_src] (+) rx[add_arr]   # combines
        buf[recv_slots] = rx[recv_arr]                # freed slots

    ``add_src == add_dst`` almost always (the combine absorbs the
    resident row in place); a resident that survives the step elsewhere
    forces a fresh destination slot.  Slots a step does not mention keep
    their rows untouched -- kept rows are never copied.
    """

    shift: int
    perm: Tuple[Tuple[int, int], ...]   # ppermute (src, dst) pairs
    tx_slots: np.ndarray                # (T,)  slots to send
    add_src: np.ndarray                 # (A,)  resident slots read
    add_dst: np.ndarray                 # (A,)  slots written with the sum
    add_arr: np.ndarray                 # (A,)  arrival index per combine
    recv_slots: np.ndarray              # (Rv,) slots receiving new rows
    recv_arr: np.ndarray                # (Rv,) arrival index per recv

    @property
    def n_tx(self) -> int:
        return len(self.tx_slots)

    @property
    def n_adds(self) -> int:
        return len(self.add_src)

    @property
    def in_place_adds(self) -> bool:
        return bool((self.add_src == self.add_dst).all())


@dataclass(frozen=True)
class ExecPlan:
    """Dense, trace-free lowering of one compiled Schedule.

    ``n_slots``            -- buffer height; the executor runs the whole
    replay on one ``(n_slots, u)`` array per device.
    ``init_rows[row, d]``  -- chunk of device d's input placed in slot
    ``row`` (initial rows occupy slots 0..R0-1 in schedule order).
    ``final_rows[c, d]``   -- slot holding reduced chunk c on device d
    after the last step (-1 where the chunk is not materialized).  Slot
    assignment is SPMD-uniform; only the chunk labels differ per device.
    """

    P: int
    kind: str
    n_rows0: int
    n_slots: int
    steps: Tuple[ExecStep, ...]
    init_rows: np.ndarray               # (R0, P)
    final_rows: np.ndarray              # (P, P)

    @property
    def n_steps(self) -> int:
        return len(self.steps)


def tick_structure(plan: ExecPlan, n_buckets: int) -> List[List[Tuple[int, int]]]:
    """The executor's software-pipelining timeline as data.

    Returns one entry per tick of :func:`execute` /
    :func:`simulate_plan`: the ``(bucket, step)`` pairs active at that
    tick, in bucket order -- tick ``t`` runs step ``t - j`` of bucket
    ``j``, over ``n_steps + n_buckets - 1`` ticks.  This is the single
    source of truth the per-tick cost model
    (:func:`repro.core.cost_model.ragged_tick_costs`) and the traced
    replay (:mod:`repro.obs.instrument`) both follow, so predicted and
    measured timelines line up tick-for-tick by construction.

    >>> from repro.core.schedule import build_generalized
    >>> plan = compile_plan(build_generalized(4, 0))
    >>> tick_structure(plan, 2)[:3]
    [[(0, 0)], [(0, 1), (1, 0)], [(0, 2), (1, 1)]]
    >>> len(tick_structure(plan, 2)) == plan.n_steps + 1
    True
    """
    B = max(int(n_buckets), 1)
    S = plan.n_steps
    return [[(j, t - j) for j in range(B) if 0 <= t - j < S]
            for t in range(S + B - 1)]


@lru_cache(maxsize=None)
def compile_plan(sched: Schedule) -> ExecPlan:
    """Lower a verified Schedule into slot-allocated index tables (cached).

    Register allocation over buffer slots: ``slot_of`` maps each live
    symbolic row to its fixed physical slot.  A kept row keeps its slot;
    a combine reuses the slot of the resident row it consumes (unless
    that row survives the step elsewhere, which forces a fresh slot);
    received rows fill the lowest freed/unused slots in arrival order --
    which keeps hot index ranges contiguous, so the executor's gathers
    and updates lower to static slices wherever the schedule allows.

    >>> from repro.core.schedule import build_generalized
    >>> plan = compile_plan(build_generalized(4, 0))
    >>> plan.n_steps, plan.n_slots, plan.n_rows0
    (4, 4, 4)
    >>> plan is compile_plan(build_generalized(4, 0))   # cached
    True
    """
    g = sched.group
    P = sched.P
    R0 = len(sched.initial_slots)
    slot_of = {row: row for row in range(R0)}   # symbolic row -> slot
    n_slots = R0
    free: List[int] = []
    steps: List[ExecStep] = []
    for st in sched.steps:
        keeps = [i for i, op in enumerate(st.out) if op.kind == "keep"]
        recvs = [i for i, op in enumerate(st.out) if op.kind == "recv"]
        adds = [i for i, op in enumerate(st.out) if op.kind == "add"]
        tx_slots = [slot_of[r] for r in st.tx_rows]
        if st.n_tx == 0 and not recvs and not adds:
            # pure bookkeeping: re-label surviving rows, free the rest.
            new_slot_of = {i: slot_of[st.out[i].res] for i in keeps}
            free = sorted((set(free) | set(slot_of.values()))
                          - set(new_slot_of.values()))
            slot_of = new_slot_of
            continue
        kept_rows = {st.out[i].res for i in keeps}
        res_uses: dict = {}
        for i in adds:
            res_uses[st.out[i].res] = res_uses.get(st.out[i].res, 0) + 1
        new_slot_of = {i: slot_of[st.out[i].res] for i in keeps}
        in_place = [i for i in adds
                    if st.out[i].res not in kept_rows
                    and res_uses[st.out[i].res] == 1]
        fresh = [i for i in adds if i not in in_place]
        for i in in_place:
            new_slot_of[i] = slot_of[st.out[i].res]
        # slots whose rows die this step become free for new arrivals
        surviving = set(new_slot_of.values())
        free = sorted((set(free) | set(slot_of.values())) - surviving)

        def alloc() -> int:
            nonlocal n_slots
            if free:
                return free.pop(0)
            n_slots += 1
            return n_slots - 1

        for i in recvs + fresh:
            new_slot_of[i] = alloc()
        add_all = in_place + fresh
        steps.append(ExecStep(
            shift=st.shift,
            perm=tuple((d, g.apply(st.shift, d)) for d in range(P)),
            tx_slots=_frozen(tx_slots),
            add_src=_frozen([slot_of[st.out[i].res] for i in add_all]),
            add_dst=_frozen([new_slot_of[i] for i in add_all]),
            add_arr=_frozen([st.out[i].arr for i in add_all]),
            recv_slots=_frozen([new_slot_of[i] for i in recvs]),
            recv_arr=_frozen([st.out[i].arr for i in recvs]),
        ))
        slot_of = new_slot_of
    # remap the final schedule-row table to slots
    sched_tbl = final_row_table(sched)
    final_rows = np.full((P, P), -1, dtype=np.int32)
    for c in range(P):
        for d in range(P):
            k = sched_tbl[c, d]
            if k >= 0:
                final_rows[c, d] = slot_of[k]
    return ExecPlan(P=P, kind=sched.kind, n_rows0=R0, n_slots=n_slots,
                    steps=tuple(steps), init_rows=initial_row_table(sched),
                    final_rows=_frozen(final_rows))


# ---------------------------------------------------------------------------
#  vectorized JAX executor with multi-bucket software pipelining
# ---------------------------------------------------------------------------

def _take(buf, idx: np.ndarray):
    """Static row gather; a slice for contiguous index ranges."""
    n = len(idx)
    if n and (idx == np.arange(idx[0], idx[0] + n)).all():
        if idx[0] == 0 and n == int(buf.shape[0]):
            return buf
        return buf[int(idx[0]):int(idx[0]) + n]
    return buf[idx]


def _pallas_combine(jobs, monoid: Monoid = None):
    """Fuse all (res, arr) pairwise combines of a tick into ONE Pallas
    ``combine_n`` call over the concatenated flat buffers.

    ``jobs`` is a list of (res_mat, arr_mat) with matching shapes; the
    K-way kernel (K=2 here) reads both operands once from HBM and writes
    the combine (``monoid.kind``: add / max / min -- all one VPU op per
    element over the same VMEM tiling), instead of one chained elementwise
    dispatch per bucket.  Interpret mode is used automatically off-TPU.

    Some shard_map replication checkers have no rule for ``pallas_call``
    (jax <= 0.4.x ``check_rep``); there the kernel cannot trace and we
    fall back to the identical-numerics elementwise op (same fp32
    pairwise combines).  Build the shard_map with ``check_vma=False``
    (see :func:`repro.compat.shard_map`) to route through the real
    kernel.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_combine import _BLOCK, combine_n

    from .monoid import SUM
    if monoid is None:
        monoid = SUM
    op = monoid.jax_op
    res_flat = jnp.concatenate([r.reshape(-1) for r, _ in jobs])
    arr_flat = jnp.concatenate([a.reshape(-1) for _, a in jobs])
    n = res_flat.shape[0]
    dt = res_flat.dtype
    # max/min never lose precision to the accumulator: skip the widening
    accum = jnp.float32 if (monoid.kind == "add"
                            and jnp.issubdtype(dt, jnp.inexact)) else dt
    block = min(_BLOCK, 128 * max(1, math.ceil(n / 128)))
    try:
        out = combine_n(jnp.stack([res_flat, arr_flat]), accum_dtype=accum,
                        interpret=jax.default_backend() != "tpu",
                        block=block, op=monoid.kind)
    except NotImplementedError:
        return [op(r, a) for r, a in jobs]
    outs, off = [], 0
    for r, _ in jobs:
        sz = int(np.prod(r.shape))
        outs.append(out[off:off + sz].reshape(r.shape))
        off += sz
    return outs


def execute(plan: ExecPlan, bucket_rows: Sequence[List], axis_name, *,
            combine: CombineLike = "auto",
            tag: Optional[str] = None) -> List[List]:
    """Replay ``plan`` over per-bucket slot-row lists inside shard_map.

    ``bucket_rows`` is a list of ``n_buckets`` row lists, each of length
    ``plan.n_slots`` holding this bucket's ``(u_b,)`` row per slot (None
    for not-yet-written slots); all buckets replay the same plan over
    disjoint slices of the message.  Slots are *aliases*: a kept row is
    untouched (zero copies -- on XLA CPU, where functional whole-buffer
    updates materialize, this is what makes the replay cheap), a combine
    rebinds the destination slot, a received row is a row view of the
    ppermute result.

    The tick loop software-pipelines the buckets: at tick ``t`` bucket
    ``j`` runs step ``t - j``, every active bucket's ``ppermute`` is
    issued before any bucket's combines, and all combines of the tick
    are batched into a single fused call on the Pallas path.  With one
    bucket this degenerates to the plain vectorized replay.

    ``combine`` is the *operator*, resolved by
    :func:`repro.core.monoid.resolve_combine`: a :class:`Monoid`, a
    monoid name ("sum" / "max" / "min" / "mean"), a binary callable, or
    one of the implementation spellings "auto" (sum; Pallas
    ``combine_n`` on TPU, plain elementwise elsewhere), "add" (sum via
    ``jnp.add``), "pallas" (sum via the kernel), "<op>:pallas".  The
    affine bookends of mean / premul_sum are the caller's job (they act
    on the whole message, not per step).

    ``tag`` is an optional caller-supplied label recorded on the
    ``execplan.execute`` trace span -- the backward-overlapped gradient
    sync (:func:`repro.parallel.api.dp_grad_allreduce`) tags each
    gradient bucket (e.g. ``"grad_bucket3"``) so per-bucket dispatches
    are identifiable in the trace timeline.
    """
    import jax

    monoid, impl = resolve_combine(combine)
    if impl == "auto":
        impl = "pallas" if (monoid.fuses_pallas
                            and jax.default_backend() == "tpu") else "op"
    if impl == "pallas" and not monoid.fuses_pallas:
        raise ValueError(f"monoid {monoid.name!r} has no fused Pallas "
                         f"kernel; use the elementwise path")
    bucket_rows = [list(rows) for rows in bucket_rows]
    B = len(bucket_rows)
    S = plan.n_steps
    # Trace-time span only: inside shard_map/jit this loop *builds* the
    # program, it does not run it, so the span measures staging cost.
    # Per-tick runtime timelines come from the blocking replay in
    # repro.obs.instrument, which follows the same tick_structure().
    ticks = tick_structure(plan, B)
    attrs = {} if tag is None else {"tag": tag}
    with obs_trace.span("execplan.execute", cat="trace", kind=plan.kind,
                        P=plan.P, n_steps=S, n_buckets=B,
                        n_ticks=len(ticks), **attrs):
        _execute_ticks(plan, bucket_rows, ticks, axis_name, monoid, impl)
    return bucket_rows


def _execute_ticks(plan: ExecPlan, bucket_rows: List[List], ticks,
                   axis_name, monoid: Monoid, impl: str) -> None:
    """Stage the tick loop in place over ``bucket_rows`` (see execute)."""
    import jax.numpy as jnp
    from jax import lax

    for active in ticks:
        # 1) issue phase: stage every active bucket's communication
        rx = {}
        for j, s in active:
            sp = plan.steps[s]
            if sp.n_tx:
                rows = bucket_rows[j]
                tx = jnp.stack([rows[i] for i in sp.tx_slots])
                rx[j] = lax.ppermute(tx, axis_name, perm=sp.perm)
        # 2) combine phase: all pairwise combines of this tick
        if impl == "pallas":
            jobs, owners = [], []
            for j, s in active:
                sp = plan.steps[s]
                if sp.n_adds:
                    rows = bucket_rows[j]
                    jobs.append((jnp.stack([rows[i] for i in sp.add_src]),
                                 _take(rx[j], sp.add_arr)))
                    owners.append((j, s))
            if jobs:        # ticks of recv-only steps have no combines
                for (j, s), summed in zip(owners,
                                          _pallas_combine(jobs, monoid)):
                    for k, dst in enumerate(plan.steps[s].add_dst):
                        bucket_rows[j][dst] = summed[k]
        else:
            op = monoid.jax_op
            for j, s in active:
                sp = plan.steps[s]
                rows = bucket_rows[j]
                # read every resident before rebinding any slot: a fresh
                # destination may reuse a slot another combine still reads
                sums = [op(rows[src], rx[j][arr])
                        for src, arr in zip(sp.add_src, sp.add_arr)]
                for dst, v in zip(sp.add_dst, sums):
                    rows[dst] = v
        # 3) land received rows in their freed slots
        for j, s in active:
            sp = plan.steps[s]
            rows = bucket_rows[j]
            for slot, arr in zip(sp.recv_slots, sp.recv_arr):
                rows[slot] = rx[j][arr]


# ---------------------------------------------------------------------------
#  pure-numpy reference runner (the lowering's own oracle)
# ---------------------------------------------------------------------------

def _np_chunks(vec: np.ndarray, P: int) -> np.ndarray:
    """(P, u_max) chunk buffer under the balanced ragged split: chunk c
    holds ``ragged_sizes(m, P)[c]`` real elements, zero-filled to the
    common physical width ``u_max = ceil(m / P)`` (the ppermute rows of
    an SPMD program must be uniform; only the *valid* prefix varies)."""
    m = vec.shape[0]
    sizes = ragged_sizes(m, P)
    offs = ragged_offsets(sizes)
    u = max(-(-m // P), 1)
    out = np.zeros((P, u), vec.dtype)
    for c in range(P):
        out[c, :sizes[c]] = vec[offs[c]:offs[c] + sizes[c]]
    return out


def simulate_plan(sched: Schedule, vectors: List[np.ndarray],
                  n_buckets: int = 1, op=np.add) -> List[np.ndarray]:
    """Replay the *lowered* plan tables over P explicit numpy processes.

    Mirrors :func:`execute` table-for-table (including the bucket split,
    the in-place slot updates, and the ragged zero-filled chunk tails),
    so bit-exact agreement with :func:`repro.core.simulator.simulate`
    proves the lowering correct independently of JAX.  ``op`` is the
    elementwise combine (any monoid's ``np_op``; default sum), applied
    to exactly the same (resident, arrival) pairs as the JAX executor.
    Handles every schedule kind and *any* message length -- uneven
    sizes use the balanced exact split of
    :func:`repro.core.schedule.ragged_sizes`:

    * ``generalized`` / ``ring``: full input vectors, full results;
    * ``reduce_scatter``: any-length inputs, device d returns its owned
      chunk zero-padded to the common physical width ``ceil(m / P)``;
    * ``all_gather`` / ``bruck_all_gather``: device d contributes chunk d
      (``vectors[d]``, lengths may differ by one), every device returns
      the exact concatenation.

    >>> import numpy as np
    >>> from repro.core.schedule import build_generalized
    >>> vecs = [np.full(7, d) for d in range(4)]        # 7 % 4 != 0
    >>> simulate_plan(build_generalized(4, 0), vecs)[0].tolist()
    [6, 6, 6, 6, 6, 6, 6]
    """
    plan = compile_plan(sched)
    P = plan.P
    assert len(vectors) == P
    gather_kinds = ("all_gather", "bruck_all_gather")

    if plan.kind in gather_kinds:
        chunk_sizes = tuple(v.shape[0] for v in vectors)
        w = max(max(chunk_sizes), 1)
        init = []
        for d in range(P):
            row = np.zeros((1, w), vectors[d].dtype)
            row[0, :chunk_sizes[d]] = vectors[d]
            init.append(row)
    else:
        m = vectors[0].shape[0]
        chunk_sizes = ragged_sizes(m, P)
        init = []
        for d in range(P):
            ch = _np_chunks(vectors[d], P)
            init.append(ch[plan.init_rows[:, d]])
    u = init[0].shape[1]
    n_buckets = max(1, min(n_buckets, u if u else 1))
    ub = -(-u // n_buckets)
    bufs = []
    for d in range(P):
        full = np.zeros((plan.n_slots, ub * n_buckets), init[d].dtype)
        full[:plan.n_rows0, :u] = init[d]
        bufs.append([full[:, j * ub:(j + 1) * ub].copy()
                     for j in range(n_buckets)])

    B, S = n_buckets, plan.n_steps
    for t in range(S + B - 1):
        active = [(j, t - j) for j in range(B) if 0 <= t - j < S]
        rx = {}
        for j, s in active:
            sp = plan.steps[s]
            if sp.n_tx:
                arr = [None] * P
                for src, dst in sp.perm:
                    arr[dst] = bufs[src][j][sp.tx_slots].copy()
                rx[j] = arr
        for j, s in active:
            sp = plan.steps[s]
            for d in range(P):
                if sp.n_adds:
                    bufs[d][j][sp.add_dst] = op(bufs[d][j][sp.add_src],
                                                rx[j][d][sp.add_arr])
                if len(sp.recv_slots):
                    bufs[d][j][sp.recv_slots] = rx[j][d][sp.recv_arr]

    state = [np.concatenate(bufs[d], axis=1)[:, :u] for d in range(P)]
    results = []
    for d in range(P):
        cols = plan.final_rows[:, d]
        if (cols >= 0).all():
            # ragged gather: chunk c contributes only its valid prefix
            results.append(np.concatenate(
                [state[d][cols[c]][:chunk_sizes[c]] for c in range(P)]))
        else:
            # reduce-scatter: only the owned chunk is materialized; it is
            # returned at the physical width (zero tail where ragged)
            c = int(np.nonzero(cols >= 0)[0][0])
            results.append(state[d][cols[c]])
    return results


# ---------------------------------------------------------------------------
#  permutation-group all-to-all over the same step tables
# ---------------------------------------------------------------------------
#  An all-to-all (device d holds P chunks x_d[0..P-1]; afterwards device d
#  holds y_d[c] = x_c[d]) is pure data movement under the cyclic group --
#  every transfer is a power of the generator t, so it compiles into the
#  exact ExecStep/ExecPlan tables the reductions use, just with no
#  combines.  Row e is the *displacement class* e: initially device d
#  stores x_d[(d+e) % P] there (the chunk destined for rank d+e), and the
#  device-dependence lives entirely in the same init/final placement
#  tables every other schedule already uses:
#
#  * direct  -- P-1 steps; step e applies t^e to row e, delivering every
#    displacement in one hop: u bytes per step, minimal total traffic
#    (the large-message regime);
#  * bruck   -- ceil(lg P) steps [Bruck & Ho '93]; step k applies t^(2^k)
#    to every row whose displacement has bit k set, so a block with
#    displacement e travels exactly the shifts of e's binary expansion
#    and accumulates e mod P.  Log-step latency at ~P/2 rows per step
#    (the small-message regime).
#
#  After the last step row e on device d holds x_{d-e}[d], i.e. result
#  chunk c sits in row (d - c) mod P -- the final gather's table.

A2A_KINDS = ("direct", "bruck")


@lru_cache(maxsize=None)
def compile_a2a_plan(P: int, kind: str = "direct") -> ExecPlan:
    """Lower a P-process all-to-all into cached ExecPlan tables.

    >>> plan = compile_a2a_plan(8, "bruck")
    >>> plan.n_steps, [st.n_tx for st in plan.steps]
    (3, [4, 4, 4])
    >>> compile_a2a_plan(8, "direct").n_steps
    7
    """
    if kind not in A2A_KINDS:
        raise ValueError(f"unknown all-to-all kind {kind!r} "
                         f"(expected one of {A2A_KINDS})")
    if P < 1:
        raise ShapeError("all-to-all needs P >= 1", expected=">= 1",
                         actual=P)
    d = np.arange(P)
    init_rows = (d[None, :] + np.arange(P)[:, None]) % P     # [e, d]
    final_rows = (d[None, :] - np.arange(P)[:, None]) % P    # [c, d]
    none = _frozen([])
    steps: List[ExecStep] = []

    def step(shift: int, rows: List[int]) -> ExecStep:
        return ExecStep(
            shift=shift,
            perm=tuple((int(x), int((x + shift) % P)) for x in range(P)),
            tx_slots=_frozen(rows), add_src=none, add_dst=none,
            add_arr=none, recv_slots=_frozen(rows),
            recv_arr=_frozen(list(range(len(rows)))))

    if kind == "direct":
        for e in range(1, P):
            steps.append(step(e, [e]))
    else:
        n = 1
        while n < P:
            rows = [e for e in range(1, P) if e & n]
            steps.append(step(n % P, rows))
            n <<= 1
    return ExecPlan(P=P, kind=f"all_to_all_{kind}", n_rows0=P, n_slots=P,
                    steps=tuple(steps), init_rows=_frozen(init_rows),
                    final_rows=_frozen(final_rows))


def simulate_a2a(vectors: List[np.ndarray],
                 kind: str = "direct") -> List[np.ndarray]:
    """Numpy oracle for the schedule-driven all-to-all: replay the plan
    tables over P explicit processes.  Result ``d`` is the concatenation
    of chunk ``d`` of every process's vector -- exactly
    ``lax.all_to_all`` on the equally-split flat buffers.

    >>> vecs = [np.arange(3) + 10 * d for d in range(3)]
    >>> [v.tolist() for v in simulate_a2a(vecs, "bruck")]
    [[0, 10, 20], [1, 11, 21], [2, 12, 22]]
    """
    P = len(vectors)
    m = vectors[0].shape[0]
    if m % P:
        raise ShapeError("all-to-all needs P | m",
                         expected=f"multiple of {P}", actual=m)
    plan = compile_a2a_plan(P, kind)
    u = m // P
    state = []
    for d in range(P):
        ch = vectors[d].reshape(P, u)
        state.append(ch[plan.init_rows[:, d]].copy())
    for sp in plan.steps:
        arr = [None] * P
        for src, dst in sp.perm:
            arr[dst] = state[src][sp.tx_slots].copy()
        for d in range(P):
            state[d][sp.recv_slots] = arr[d][sp.recv_arr]
    return [np.concatenate([state[d][plan.final_rows[c, d]]
                            for c in range(P)]) for d in range(P)]
