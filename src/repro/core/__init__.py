"""Core of the reproduction: the paper's generalized Allreduce.

Public API:
  group        -- abelian permutation groups (cyclic / hypercube / mixed-radix)
  schedule     -- symbolic schedule compiler + verification
  monoid       -- first-class combine operators (sum/max/min/mean/premul/custom)
  simulator    -- numpy oracle executing schedules process-by-process
  execplan     -- Schedule -> ExecPlan lowering + vectorized/pipelined replay
                  (incl. the permutation-group all-to-all plan tables)
  cost_model   -- alpha-beta-gamma model, the paper's closed forms
  autotune     -- per-message-size algorithm / step / bucket selection
  allreduce    -- JAX shard_map executors (ppermute programs)
"""
from .group import CyclicGroup, HypercubeGroup, MixedRadixGroup
from .schedule import (InvalidScheduleError, Schedule, ShapeError,
                       build_all_gather, build_generalized,
                       build_reduce_scatter, build_ring, max_r, n_steps_log,
                       ragged_offsets, ragged_sizes, ragged_step_units,
                       schedule_summary)
from .monoid import (MAX, MEAN, MIN, MONOIDS, SUM, Monoid, custom,
                     premul_sum, resolve_combine)
from .execplan import (ExecPlan, compile_a2a_plan, compile_plan,
                       simulate_a2a, simulate_plan)
from .cost_model import (Fabric, HOST_CPU, PAPER_10GE, TPU_V5E_ICI,
                         a2a_cost, choose_a2a, choose_n_buckets,
                         optimal_r_analytic, optimal_r_search,
                         pipelined_schedule_cost, ragged_choose_n_buckets,
                         ragged_pipelined_schedule_cost, ragged_schedule_cost,
                         schedule_cost, tau_best_sota, tau_bw_optimal,
                         tau_intermediate, tau_latency_optimal, tau_ring)
from .allreduce import (all_gather_flat, all_to_all_flat, allreduce_flat,
                        allreduce_tree, exact_chunks, hierarchical_allreduce,
                        hierarchical_allreduce_flat, psum_tree,
                        reduce_scatter_flat, tree_all_gather,
                        tree_reduce_scatter)
