"""Deterministic fault injection for the elastic runtime.

Faults are declared up front -- in code or via the ``REPRO_FAULTS`` env
var -- and fire at exact (kind, worker, step) coordinates, so a chaos
run is a *reproducible experiment*: the same spec produces the same
failure at the same point of the same training run every time.  The
chaos CI job and the recovery tests are built on this.

Spec syntax (``;``-separated clauses, each ``kind:key=value,...``)::

    kill:rank=1,step=3;delay:rank=2,step=4,us=5000;ckpt_torn:step=5

* ``kill``      -- worker ``rank`` exits hard (``os._exit``) at the
  start of training step ``step``, before sending anything: the
  coordinator sees a dead socket mid-barrier.
* ``delay``     -- worker ``rank`` sleeps ``us`` microseconds before
  its first send of step ``step``: a deterministic straggler, visible
  to the coordinator's arrival-skew telemetry.
* ``ckpt_torn`` -- the coordinator truncates a leaf file of the
  checkpoint committed *as* step ``step`` right after writing it: a
  torn-after-commit write, which only the content checksums of
  :mod:`repro.checkpoint.checkpoint` can catch.

``rank`` in a spec always means the worker's *original* id at launch:
recovery re-ranks survivors, and a fault that silently re-targeted a
different process after a resize would not be reproducible.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

KINDS = ("kill", "delay", "ckpt_torn")
_REQUIRED: Dict[str, Tuple[str, ...]] = {
    "kill": ("rank", "step"),
    "delay": ("rank", "step", "us"),
    "ckpt_torn": ("step",),
}


@dataclass(frozen=True)
class Fault:
    """One scheduled fault."""

    kind: str
    step: int
    rank: Optional[int] = None  # worker id at launch; None for ckpt_torn
    us: int = 0  # delay duration (kind == "delay")


def parse_faults(spec: str) -> Tuple[Fault, ...]:
    """Parse a ``REPRO_FAULTS`` spec string.

    >>> parse_faults("kill:rank=1,step=3;ckpt_torn:step=5")
    (Fault(kind='kill', step=3, rank=1, us=0), \
Fault(kind='ckpt_torn', step=5, rank=None, us=0))
    >>> parse_faults("delay:rank=0,step=2,us=7000")[0].us
    7000
    >>> parse_faults("")
    ()
    >>> parse_faults("explode:step=1")
    Traceback (most recent call last):
        ...
    ValueError: unknown fault kind 'explode' (expected one of kill, \
delay, ckpt_torn)
    >>> parse_faults("kill:step=3")
    Traceback (most recent call last):
        ...
    ValueError: fault 'kill' requires rank=... in clause 'kill:step=3'
    """
    out = []
    for clause in filter(None, (c.strip() for c in spec.split(";"))):
        kind, _, args = clause.partition(":")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r} "
                             f"(expected one of {', '.join(KINDS)})")
        kv = {}
        for item in filter(None, (a.strip() for a in args.split(","))):
            k, _, v = item.partition("=")
            if not _ or k.strip() not in ("rank", "step", "us"):
                raise ValueError(f"bad fault argument {item!r} "
                                 f"in clause {clause!r}")
            kv[k.strip()] = int(v)
        for req in _REQUIRED[kind]:
            if req not in kv:
                raise ValueError(f"fault {kind!r} requires {req}=... "
                                 f"in clause {clause!r}")
        out.append(Fault(kind=kind, step=kv["step"], rank=kv.get("rank"),
                         us=kv.get("us", 0)))
    return tuple(out)


class FaultPlan:
    """Queryable set of scheduled faults.

    Each fault fires at most once (``pop`` semantics), matching how the
    real failure it models happens once: a re-executed step after
    recovery must not re-kill the already-dead worker's successor.

    >>> plan = FaultPlan(parse_faults("kill:rank=1,step=3"))
    >>> plan.fire("kill", step=3, rank=0) is None
    True
    >>> plan.fire("kill", step=3, rank=1).kind
    'kill'
    >>> plan.fire("kill", step=3, rank=1) is None   # at most once
    True
    """

    def __init__(self, faults: Tuple[Fault, ...] = ()):
        self._pending = list(faults)

    @classmethod
    def from_env(cls, var: str = "REPRO_FAULTS") -> "FaultPlan":
        return cls(parse_faults(os.environ.get(var, "")))

    def fire(self, kind: str, step: int,
             rank: Optional[int] = None) -> Optional[Fault]:
        """Pop and return the matching pending fault, else ``None``."""
        for i, f in enumerate(self._pending):
            if f.kind == kind and f.step == step and \
                    (f.rank is None or f.rank == rank):
                return self._pending.pop(i)
        return None

    @property
    def pending(self) -> Tuple[Fault, ...]:
        return tuple(self._pending)
