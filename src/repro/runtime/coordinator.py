"""Multi-process coordinator: spawn, step-barrier, relay, recover.

The coordinator owns a mesh of single-rank worker subprocesses
(:mod:`repro.runtime.worker`) connected over TCP in a star topology and
drives data-parallel training through a per-step protocol:

1. **barrier** -- broadcast ``step`` to every worker (carrying a new
   schedule spec when the collective was re-chosen);
2. **relay**   -- for each compiled
   :class:`~repro.core.schedule.CommStep`, collect every rank's TX rows,
   route each payload to ``perm[src]`` under the step's shift
   permutation, and forward; the first collect of every step timestamps
   each rank's arrival (:class:`repro.obs.skew.ArrivalRecorder`), which
   is the live feed for skew-aware schedule selection;
3. **commit**  -- collect ``step_done`` from every rank, check the
   losses agree across the mesh to association-order tolerance (each
   rank reduces along a different combine tree, so only the last ulps
   may differ), record rank 0's as canonical, checkpoint on schedule.

**Failure handling.** A worker death surfaces as a dead socket (instant)
or as a barrier timeout, probed by ping/pong with configurable
retry/backoff (:class:`CoordinatorConfig`).  Recovery is the full arc
the generalized allreduce makes cheap: mark the dead rank, restore the
newest *valid* checkpoint (content-checksummed -- a torn post-commit
write is skipped and quarantined), re-rank the survivors ``0..P'-1``,
recompile the schedule for the survivor count ``P'`` -- any count,
including primes, with no padding or spares -- and resume.  Replayed
steps are deterministic, so a recovered run's losses are bit-identical
to a clean run launched at ``P'`` from the same checkpoint.

**Skew awareness.**  With ``sort_on_skew`` enabled, a step whose
measured arrival spread clears ``skew_threshold_us`` re-runs schedule
selection through :func:`repro.core.autotune.choose` with the live
deltas; under heavy skew the choice legitimately flips to a higher-``r``
(latency-leaning) schedule or to the arrival-sorted relabeling, and the
new spec ships with the next step barrier.
"""
from __future__ import annotations

import os
import select
import socket
import subprocess
import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.autotune import Choice, choose
from repro.core.cost_model import HOST_CPU
from repro.core.schedule import Schedule
from repro.obs import trace as obs_trace
from repro.obs.log import get_logger
from repro.obs.skew import ArrivalRecorder

from .faults import FaultPlan, parse_faults
from .protocol import ProtocolError, pack_rows, recv_msg, send_msg, unpack_rows
from .worker import build_schedule

_log = get_logger("repro.runtime.coordinator")


@dataclass
class CoordinatorConfig:
    P: int
    ckpt_dir: str
    dim: int = 32
    batch: int = 8
    lr: float = 0.1
    seed: int = 0
    ckpt_every: int = 5
    min_P: int = 2
    resume: bool = False  # restore the latest valid checkpoint at start
    # barrier/heartbeat: wait step_timeout_s, then ping and wait
    # step_timeout_s * backoff**attempt, up to `retries` pings, before
    # declaring the silent workers dead
    step_timeout_s: float = 30.0
    retries: int = 2
    backoff: float = 1.5
    # schedule selection: None -> autotune.choose on the gradient
    # message size; an explicit (kind, r[, order]) pins it (tests,
    # benchmarks).  A pinned sorted order that no longer fits the mesh
    # (recovery changed P) falls back to choose().
    schedule_kind: Optional[str] = None
    schedule_r: int = 0
    schedule_order: Optional[Tuple[int, ...]] = None
    sort_on_skew: bool = False
    skew_threshold_us: float = 1000.0
    faults: Optional[str] = None  # spec string; None -> REPRO_FAULTS env


@dataclass
class _Handle:
    wid: int  # launch id (never reused; faults key on it)
    rank: int  # current mesh rank (re-assigned on recovery)
    proc: subprocess.Popen
    sock: socket.socket
    alive: bool = True


class DeadWorker(Exception):
    """One or more workers died or stopped answering pings."""

    def __init__(self, wids: List[int]):
        super().__init__(f"dead workers: {wids}")
        self.wids = wids


@dataclass
class Recovery:
    """One completed recovery arc (surfaced in results / regression gate)."""

    failed_wids: Tuple[int, ...]
    at_step: int  # step being executed when death was detected
    restored_step: int  # step the surviving mesh resumed from
    new_P: int
    recovery_steps: int = field(init=False)  # re-executed steps

    def __post_init__(self):
        self.recovery_steps = self.at_step - self.restored_step


class Coordinator:
    """Drives a worker mesh; see the module docstring for the protocol."""

    def __init__(self, cfg: CoordinatorConfig):
        if cfg.P < 2:
            raise ValueError("coordinator needs P >= 2 workers")
        self.cfg = cfg
        spec = cfg.faults if cfg.faults is not None else \
            os.environ.get("REPRO_FAULTS", "")
        # the coordinator owns only the checkpoint-tearing faults;
        # kill/delay ship to the workers via their environment
        self.faults = FaultPlan(tuple(
            f for f in parse_faults(spec) if f.kind == "ckpt_torn"))
        self._worker_faults = spec
        self.workers: List[_Handle] = []
        self.records: List[dict] = []
        self.recoveries: List[Recovery] = []
        self.step = 0
        self.w = np.zeros(cfg.dim)
        self._listener: Optional[socket.socket] = None
        self._choice: Optional[Choice] = None
        self._resched: Optional[dict] = None  # spec to ship next barrier
        self._sched: Optional[Schedule] = None

    # ------------------------------------------------------------ schedule
    @property
    def _nbytes(self) -> int:
        return (self.cfg.dim + 1) * 8  # grad ++ loss, float64

    def _schedule_spec(self, P: int,
                       deltas_us: Optional[List[float]] = None) -> dict:
        cfg = self.cfg
        if cfg.schedule_kind is not None and deltas_us is None:
            if cfg.schedule_kind != "sorted":
                return {"kind": cfg.schedule_kind, "P": P,
                        "r": cfg.schedule_r}
            if cfg.schedule_order is not None \
                    and len(cfg.schedule_order) == P:
                return {"kind": "sorted", "P": P, "r": cfg.schedule_r,
                        "order": list(cfg.schedule_order)}
        ch = choose(P, self._nbytes, HOST_CPU, tune=False, itemsize=8,
                    arrival_deltas_us=deltas_us)
        spec = {"kind": ch.kind, "P": P, "r": ch.r}
        if ch.order is not None:
            spec["order"] = list(ch.order)
        self._choice = ch
        return spec

    # --------------------------------------------------------------- start
    def start(self) -> None:
        cfg = self.cfg
        if cfg.resume:
            try:
                from repro.checkpoint.checkpoint import restore
                step, out = restore(cfg.ckpt_dir,
                                    {"params": {"w": self.w}})
                self.step, self.w = step, out["params"]["w"]
            except FileNotFoundError:
                pass
        self._listener = socket.socket()
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(cfg.P)
        port = self._listener.getsockname()[1]
        import repro
        src = os.path.dirname(os.path.dirname(os.path.abspath(
            repro.__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env["REPRO_FAULTS"] = self._worker_faults
        env.setdefault("JAX_PLATFORMS", "cpu")
        procs = {}
        for wid in range(cfg.P):
            procs[wid] = subprocess.Popen(
                [sys.executable, "-m", "repro.runtime.worker",
                 "--port", str(port), "--id", str(wid)],
                env=env)
        deadline = time.monotonic() + cfg.step_timeout_s * (cfg.retries + 1)
        for _ in range(cfg.P):
            self._listener.settimeout(max(0.1, deadline - time.monotonic()))
            sock, _ = self._listener.accept()
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            hello, _ = recv_msg(sock)
            wid = int(hello["id"])
            self.workers.append(_Handle(wid=wid, rank=wid,
                                        proc=procs[wid], sock=sock))
        self.workers.sort(key=lambda h: h.wid)
        spec = self._schedule_spec(cfg.P)
        self._sched = build_schedule(spec)
        for h in self.workers:
            send_msg(h.sock, self._init_header(h.rank, cfg.P, spec),
                     pack_rows([self.w]))
        self._collect("ready")
        _log.info("mesh_up", P=cfg.P, port=port,
                  schedule=f"{spec['kind']},r={spec['r']}")

    def _init_header(self, rank: int, P: int, spec: dict,
                     reconfig: bool = False) -> dict:
        return {"type": "reconfig" if reconfig else "init",
                "rank": rank, "P": P, "step": self.step,
                "seed": self.cfg.seed, "dim": self.cfg.dim,
                "batch": self.cfg.batch, "lr": self.cfg.lr,
                "schedule": spec}

    # ------------------------------------------------------------- collect
    def _alive(self) -> List[_Handle]:
        return [h for h in self.workers if h.alive]

    def _collect(self, want: str, cstep: Optional[int] = None,
                 recorder: Optional[ArrivalRecorder] = None
                 ) -> Dict[int, Tuple[dict, bytes]]:
        """One frame of type ``want`` from every alive worker.

        Unexpected types are discarded (stale TX of an abandoned step,
        late pongs); a closed socket or an exhausted ping/retry budget
        raises :class:`DeadWorker` with the guilty launch ids.
        """
        cfg = self.cfg
        pending = {h.sock: h for h in self._alive()}
        out: Dict[int, Tuple[dict, bytes]] = {}
        dead: List[int] = []
        attempt = 0
        deadline = time.monotonic() + cfg.step_timeout_s
        while pending:
            wait = deadline - time.monotonic()
            if wait <= 0:
                if attempt >= cfg.retries:
                    raise DeadWorker(dead + [h.wid
                                             for h in pending.values()])
                attempt += 1
                for h in pending.values():
                    try:
                        send_msg(h.sock, {"type": "ping"})
                    except OSError:
                        pass
                deadline = (time.monotonic()
                            + cfg.step_timeout_s * cfg.backoff ** attempt)
                continue
            readable, _, _ = select.select(list(pending), [], [], wait)
            for sock in readable:
                h = pending[sock]
                try:
                    header, payload = recv_msg(sock)
                except (ProtocolError, OSError):
                    dead.append(h.wid)
                    del pending[sock]
                    continue
                t = header["type"]
                if t != want:
                    continue  # pong / stale frame of an abandoned step
                if cstep is not None and header.get("cstep") != cstep:
                    continue
                if recorder is not None:
                    recorder.record(h.rank)
                out[h.rank] = (header, payload)
                del pending[sock]
        if dead:
            raise DeadWorker(dead)
        return out

    # ---------------------------------------------------------------- step
    def _one_step(self) -> None:
        cfg = self.cfg
        s = self.step
        ship_ckpt = (s + 1) % cfg.ckpt_every == 0
        alive = self._alive()
        P = len(alive)
        header = {"type": "step", "step": s}
        if self._resched is not None:
            header["schedule"] = self._resched
            self._sched = build_schedule(self._resched)
            self._resched = None
        for h in alive:
            hd = dict(header)
            if ship_ckpt and h.rank == 0:
                hd["ship_params"] = True
            send_msg(h.sock, hd)
        rec = ArrivalRecorder()
        with obs_trace.span("coord.step", cat="runtime", step=s,
                            P=P) as sp:
            for i, st in enumerate(self._sched.steps):
                txs = self._collect("tx", cstep=i,
                                    recorder=rec if i == 0 else None)
                perm = self._sched.group.perm(st.shift)
                by_rank = {h.rank: h for h in alive}
                for src, (_, payload) in txs.items():
                    send_msg(by_rank[perm[src]].sock,
                             {"type": "rx", "step": s, "cstep": i},
                             payload)
            done = self._collect("step_done")
            losses = {r: float.fromhex(h["loss"])
                      for r, (h, _) in done.items()}
            # ranks reduce each chunk along different combine trees, so
            # cross-rank losses agree only to association order (last
            # ulps); rank 0 is canonical, gross disagreement is a bug
            loss = losses[0]
            spread = max(losses.values()) - min(losses.values())
            if spread > 1e-9 * max(1.0, abs(loss)):
                raise RuntimeError(
                    f"step {s}: loss disagreement across ranks: {losses}")
            stats = rec.stats()
            sp.set(loss=round(loss, 6), skew_us=stats.skew_us)
        obs_trace.get_tracer().counter("coord_arrival_skew_us",
                                       stats.skew_us, cat="runtime")
        if ship_ckpt:
            (w,) = unpack_rows(done[0][1])
            self.w = w
            self._checkpoint(s + 1, P)
        self.records.append({"step": s, "loss": loss, "P": P,
                             "skew_us": stats.skew_us,
                             "schedule": self._spec_label()})
        self.step = s + 1
        if cfg.sort_on_skew and stats.skew_us >= cfg.skew_threshold_us \
                and len(stats.deltas_us) == P:
            spec = self._schedule_spec(P, deltas_us=list(stats.deltas_us))
            if spec != self._current_spec(P):
                _log.info("skew_reschedule", step=s,
                          skew_us=stats.skew_us,
                          to=f"{spec['kind']},r={spec['r']}")
                self._resched = spec

    def _current_spec(self, P: int) -> dict:
        sch = self._sched
        spec = {"kind": sch.kind, "P": P, "r": sch.r}
        relabel = getattr(sch.group, "relabel", None)
        if relabel is not None:
            spec["order"] = list(relabel)
        return spec

    def _spec_label(self) -> str:
        spec = self._current_spec(self._sched.P)
        label = f"{spec['kind']},r={spec['r']}"
        if "order" in spec:
            label += ",order=" + "-".join(map(str, spec["order"]))
        return label

    # ----------------------------------------------------------- recovery
    def _checkpoint(self, step: int, P: int) -> None:
        from repro.checkpoint.checkpoint import save
        with obs_trace.span("coord.checkpoint", cat="runtime", step=step):
            d = save(self.cfg.ckpt_dir, step, {"params": {"w": self.w}},
                     meta={"P": P, "dim": self.cfg.dim,
                           "seed": self.cfg.seed})
        if self.faults.fire("ckpt_torn", step) is not None:
            for fn in sorted(os.listdir(d)):
                if fn.endswith(".npy"):
                    p = os.path.join(d, fn)
                    with open(p, "r+b") as f:
                        f.truncate(os.path.getsize(p) // 2)
                    _log.warn("fault_ckpt_torn", step=step, file=fn)
                    break

    def _mark_dead(self, wids: List[int]) -> None:
        for h in self.workers:
            if h.wid in wids and h.alive:
                h.alive = False
                try:
                    h.sock.close()
                except OSError:
                    pass
                h.proc.kill()
                h.proc.wait()
                obs_trace.get_tracer().instant(
                    "worker_dead", cat="runtime", wid=h.wid,
                    step=self.step)
                _log.warn("worker_dead", wid=h.wid, step=self.step)

    def _recover(self) -> None:
        """Restore-from-checkpoint + re-rank + recompile for P-1.

        May raise :class:`DeadWorker` again if another worker dies while
        being reconfigured; the run loop marks it and retries.
        """
        cfg = self.cfg
        at_step = self.step
        survivors = self._alive()
        if len(survivors) < cfg.min_P:
            raise RuntimeError(
                f"only {len(survivors)} workers left (min_P={cfg.min_P})")
        from repro.checkpoint.checkpoint import restore
        try:
            restored_step, out = restore(cfg.ckpt_dir,
                                         {"params": {"w": self.w}})
            self.w = out["params"]["w"]
        except FileNotFoundError:  # death before the first checkpoint
            restored_step = 0
            self.w = np.zeros(cfg.dim)
        new_P = len(survivors)
        with obs_trace.span("coord.recover", cat="runtime",
                            at_step=at_step, new_P=new_P,
                            restored_step=restored_step):
            self.step = restored_step
            spec = self._schedule_spec(new_P)
            self._sched = build_schedule(spec)
            self._resched = None
            for new_rank, h in enumerate(survivors):
                h.rank = new_rank
                send_msg(h.sock,
                         self._init_header(new_rank, new_P, spec,
                                           reconfig=True),
                         pack_rows([self.w]))
            self._collect("ready")
        rec = Recovery(failed_wids=tuple(h.wid for h in self.workers
                                         if not h.alive),
                       at_step=at_step, restored_step=restored_step,
                       new_P=new_P)
        self.recoveries.append(rec)
        _log.info("recovered", new_P=new_P, restored_step=restored_step,
                  recovery_steps=rec.recovery_steps)

    # ------------------------------------------------------------ run/stop
    def run(self, n_steps: int) -> List[dict]:
        """Train until ``self.step == n_steps``, recovering as needed."""
        while self.step < n_steps:
            try:
                self._one_step()
            except DeadWorker as e:
                self._mark_dead(e.wids)
                while True:
                    try:
                        self._recover()
                        break
                    except DeadWorker as e2:
                        self._mark_dead(e2.wids)
        return self.records

    def final_losses(self) -> Dict[int, float]:
        """Per-step loss, last execution wins (recovery re-runs steps)."""
        return {r["step"]: r["loss"] for r in self.records}

    def close(self) -> None:
        for h in self.workers:
            if h.alive:
                try:
                    send_msg(h.sock, {"type": "stop"})
                except OSError:
                    pass
        for h in self.workers:
            try:
                h.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                h.proc.kill()
                h.proc.wait()
            try:
                h.sock.close()
            except OSError:
                pass
        if self._listener is not None:
            self._listener.close()
            self._listener = None

    def __enter__(self) -> "Coordinator":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()
