"""One-rank data-parallel worker process for the multi-process runtime.

Launched by :class:`repro.runtime.coordinator.Coordinator` as
``python -m repro.runtime.worker --host H --port P --id K``.  The worker
is deliberately *numpy-only at runtime*: it replays the compiled
:class:`~repro.core.schedule.Schedule` row tables itself -- the same
symbolic steps the in-process simulator and the shard_map executor run
-- with the wire replaced by TCP frames relayed through the coordinator
(star topology: P sockets instead of P^2, and the coordinator gets to
timestamp every rank's arrival for skew telemetry).

Training is a deterministic least-squares problem: the batch for
``(seed, P, step, rank)`` is a pure function of those four integers and
every numpy op runs in a fixed order, so any two runs that agree on
them -- e.g. a recovered run and a clean run restored from the same
checkpoint at the same survivor count -- produce bit-identical rank-0
losses.  Across ranks the schedule reduces each chunk along different
combine trees, so (float addition being non-associative) rank states
agree only to the last ulps; the coordinator checks that spread against
a tight tolerance as a whole-pipeline integrity check and records rank
0 as canonical.

Fault injection (``REPRO_FAULTS``): ``kill`` exits hard before the
step's first send; ``delay`` sleeps before it.  Both key on the
worker's launch id, which survives re-ranking.
"""
from __future__ import annotations

import argparse
import os
import socket
import time
from typing import List, Optional

import numpy as np

from repro.core.execplan import final_row_table, initial_row_table
from repro.core.schedule import (Schedule, build_dual_root,
                                 build_generalized, build_ring,
                                 build_sorted_generalized,
                                 build_traff_rounds, ragged_offsets,
                                 ragged_sizes)

from .faults import FaultPlan
from .protocol import pack_rows, recv_msg, send_msg, unpack_rows


def build_schedule(spec: dict) -> Schedule:
    """Rebuild a schedule from its wire spec ``{kind, P, r, order?}``.

    Both sides compile from the same spec, so the coordinator's routing
    permutations and the worker's row ops always describe one schedule.

    >>> build_schedule({"kind": "generalized", "P": 5, "r": 1}).n_steps
    5
    >>> build_schedule({"kind": "sorted", "P": 4, "r": 0,
    ...                 "order": [2, 0, 3, 1]}).kind
    'sorted'
    """
    kind, P, r = spec["kind"], int(spec["P"]), int(spec.get("r", 0))
    if kind == "ring":
        return build_ring(P)
    if kind == "sorted":
        return build_sorted_generalized(P, r, tuple(spec["order"]))
    if kind == "traff_rounds":
        return build_traff_rounds(P)
    if kind == "dual_root":
        return build_dual_root(P)
    if kind != "generalized":
        raise ValueError(f"unknown schedule kind in wire spec: {kind!r}")
    return build_generalized(P, r)


def local_batch(seed: int, P: int, step: int, rank: int,
                dim: int, batch: int):
    """Deterministic per-rank batch: pure function of its coordinates."""
    rng = np.random.default_rng([seed, P, step, rank])
    w_star = np.random.default_rng([seed, 999]).standard_normal(dim)
    X = rng.standard_normal((batch, dim))
    return X, X @ w_star


def grad_and_loss(w: np.ndarray, X: np.ndarray, y: np.ndarray):
    resid = X @ w - y
    return X.T @ resid / len(y), 0.5 * float(resid @ resid) / len(y)


class _Reconfigure(Exception):
    """Raised out of a blocked receive when the coordinator reconfigures
    the mesh mid-step; carries the reconfig header + params payload."""

    def __init__(self, header, payload):
        super().__init__(header["type"])
        self.header, self.payload = header, payload


class _Stop(Exception):
    pass


class Worker:
    def __init__(self, sock: socket.socket, wid: int,
                 faults: Optional[FaultPlan] = None):
        self.sock = sock
        self.wid = wid  # launch id: stable across re-ranking, keys faults
        self.faults = faults if faults is not None else FaultPlan.from_env()
        self.rank = wid
        self.P = 0
        self.step = 0
        self.sched: Optional[Schedule] = None
        self.w: Optional[np.ndarray] = None

    # ---------------------------------------------------------- messaging
    def _next(self, *want: str):
        """Receive the next frame of an expected type.

        ``ping`` is answered transparently (the coordinator's liveness
        probe must work even while we block mid-collective); ``reconfig``
        and ``stop`` unwind whatever step is in flight.
        """
        while True:
            header, payload = recv_msg(self.sock)
            t = header["type"]
            if t == "ping":
                send_msg(self.sock, {"type": "pong", "id": self.wid})
                continue
            if t == "reconfig":
                raise _Reconfigure(header, payload)
            if t == "stop":
                raise _Stop()
            if t in want:
                return header, payload
            raise RuntimeError(f"worker {self.wid}: unexpected {t!r}, "
                               f"wanted {want}")

    # --------------------------------------------------------------- state
    def _apply_init(self, header: dict, payload: bytes) -> None:
        self.rank = int(header["rank"])
        self.P = int(header["P"])
        self.step = int(header["step"])
        self.seed = int(header["seed"])
        self.dim = int(header["dim"])
        self.batch = int(header["batch"])
        self.lr = float(header["lr"])
        self.sched = build_schedule(header["schedule"])
        (self.w,) = unpack_rows(payload)

    # ----------------------------------------------------------- training
    def _allreduce(self, vec: np.ndarray) -> np.ndarray:
        """Replay the schedule with TCP frames as the wire.

        Mirrors :func:`repro.core.simulator._replay` exactly, but holds
        only this rank's rows: per step, ship the TX rows to the
        coordinator (which routes them by the step's shift permutation)
        and build the new row state from residents + arrivals.
        """
        sched, d, P = self.sched, self.rank, self.P
        tbl = initial_row_table(sched)
        sizes = ragged_sizes(len(vec), P)
        offs = ragged_offsets(sizes)
        chunks = [vec[offs[c]:offs[c] + sizes[c]] for c in range(P)]
        state: List[np.ndarray] = [chunks[tbl[row, d]].copy()
                                   for row in range(len(sched.initial_slots))]
        for i, st in enumerate(sched.steps):
            send_msg(self.sock,
                     {"type": "tx", "step": self.step, "cstep": i,
                      "rank": d},
                     pack_rows([state[ri] for ri in st.tx_rows]))
            header, payload = self._next("rx")
            assert header["cstep"] == i, (header, i)
            arrivals = unpack_rows(payload)
            new_rows = []
            for o in st.out:
                if o.kind == "keep":
                    new_rows.append(state[o.res])
                elif o.kind == "recv":
                    new_rows.append(arrivals[o.arr])
                else:
                    new_rows.append(state[o.res] + arrivals[o.arr])
            state = new_rows
        ftbl = final_row_table(sched)
        return np.concatenate([state[ftbl[c, d]] for c in range(P)])

    def _run_step(self, header: dict) -> None:
        assert header["step"] == self.step, (header, self.step)
        if "schedule" in header:  # coordinator re-chose (e.g. skew-sorted)
            self.sched = build_schedule(header["schedule"])
        f = self.faults.fire("delay", self.step, self.wid)
        if f is not None:
            time.sleep(f.us * 1e-6)
        if self.faults.fire("kill", self.step, self.wid) is not None:
            os._exit(17)  # hard death: no goodbye frame, no flush
        X, y = local_batch(self.seed, self.P, self.step, self.rank,
                           self.dim, self.batch)
        g, loss = grad_and_loss(self.w, X, y)
        total = self._allreduce(np.concatenate([g, [loss]]))
        avg = total / self.P
        self.w = self.w - self.lr * avg[:-1]
        done = {"type": "step_done", "step": self.step, "rank": self.rank,
                "loss": float(avg[-1]).hex()}
        payload = pack_rows([self.w]) if header.get("ship_params") else b""
        send_msg(self.sock, done, payload)
        self.step += 1

    # ------------------------------------------------------------ mainloop
    def run(self) -> None:
        try:
            header, payload = self._next("init")
            self._apply_init(header, payload)
            send_msg(self.sock, {"type": "ready", "id": self.wid})
            while True:
                try:
                    header, _ = self._next("step")
                    self._run_step(header)
                except _Reconfigure as rc:
                    self._apply_init(rc.header, rc.payload)
                    send_msg(self.sock, {"type": "ready", "id": self.wid})
        except _Stop:
            pass


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--id", type=int, required=True)
    args = ap.parse_args(argv)
    sock = socket.create_connection((args.host, args.port))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    send_msg(sock, {"type": "hello", "id": args.id, "pid": os.getpid()})
    try:
        Worker(sock, args.id).run()
    finally:
        sock.close()


if __name__ == "__main__":
    main()
