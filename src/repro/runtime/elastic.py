"""Elastic scaling + fault tolerance.

The paper's algorithm is the enabler here: because the generalized
allreduce is optimal for *any* process count (the whole point of the
group-theoretic construction), losing a node never forces padding to a
power of two or falling back to Ring.  Downsizing dp 16 -> 15 just
recompiles with the cyclic group Z_15: still ceil(lg 15) = 4-step
reduce-scatter, zero protocol overhead.

``ElasticRunner`` wraps the training loop:

* straggler watch  -- per-step wall time EWMA; a step slower than
  ``straggler_factor`` x EWMA raises a StragglerAlert (on real clusters
  this triggers hot-spare swap; here it is logged and surfaced to tests).
* failure handling -- a device/node failure surfaces as an exception from
  the jitted step; the runner checkpoints are already on disk, so it
  rebuilds the mesh with the survivors and restores.
* resize           -- ``resize(new_mesh)`` recompiles the step bundle and
  reshards the (global) checkpointed state onto the new topology.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, restore
from repro.data.pipeline import DataConfig, synth_batch
from repro.launch.mesh import make_mesh, parallel_config_for
from repro.models.model import init_params
from repro.obs import trace as obs_trace
from repro.obs.log import get_logger
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_train_step

_log = get_logger("repro.runtime.elastic")


class StragglerAlert(RuntimeError):
    pass


@dataclass
class StragglerWatch:
    """Per-step wall-time EWMA with outlier-robust folding.

    A step slower than ``factor`` x the EWMA alerts.  The alerting
    step's time is folded into the baseline *clamped* to
    ``factor * ewma`` -- folding the raw outlier in (the old behavior)
    inflates the threshold so one slow step masks the next straggler,
    while excluding it entirely would make a genuine regime change
    alert forever.  Clamped folding keeps one-off spikes from moving
    the baseline yet still converges onto a persistent slowdown in a
    few steps.

    >>> w = StragglerWatch(factor=3.0, decay=0.9)
    >>> [w.observe(0.1) for _ in range(5)]
    [False, False, False, False, False]
    >>> w.observe(2.0)                  # 20x the baseline: alert
    True
    >>> w.observe(0.8)                  # next straggler is NOT masked
    True
    >>> sum(w.observe(1.0) for _ in range(30)) < 30  # regime change adapts
    True
    """

    factor: float = 3.0
    decay: float = 0.9
    warmup: int = 3  # observations before alerting can start
    value: Optional[float] = None  # current EWMA baseline (seconds)
    n: int = 0

    def observe(self, dt: float) -> bool:
        """Fold one step time into the baseline; True iff it alerts."""
        self.n += 1
        if self.value is None:
            self.value = dt
            return False
        alerted = self.n > self.warmup and dt > self.factor * self.value
        folded = min(dt, self.factor * self.value) if alerted else dt
        self.value = self.decay * self.value + (1 - self.decay) * folded
        return alerted


@dataclass
class ElasticConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    ewma: float = 0.9
    param_mode: str = "dp"
    grad_r: Optional[int] = None   # gradient-sync step-count override


class ElasticRunner:
    """Owns the (mesh, step-bundle, state) triple and can rebuild it."""

    def __init__(self, cfg, oc: OptConfig, ec: ElasticConfig, dc: DataConfig,
                 mesh_shape, axes=("data", "model"), devices=None, seed=0):
        self.cfg, self.oc, self.ec, self.dc = cfg, oc, ec, dc
        self.ckpt = AsyncCheckpointer(ec.ckpt_dir)
        self.watch = StragglerWatch(factor=ec.straggler_factor,
                                    decay=ec.ewma)
        self.alerts: list = []
        self.step = 0
        self._build(mesh_shape, axes, devices, seed, fresh=True)

    # ------------------------------------------------------------- build
    def _build(self, mesh_shape, axes, devices, seed, fresh: bool):
        self.mesh = make_mesh(mesh_shape, axes, devices)
        self.pc = parallel_config_for(self.mesh,
                                      param_mode=self.ec.param_mode,
                                      grad_r=self.ec.grad_r)
        self.bundle = make_train_step(self.cfg, self.pc, self.mesh, self.oc,
                                      donate=False)
        if fresh:
            self.params, _ = init_params(self.cfg, self.pc,
                                         jax.random.PRNGKey(seed))
            self.opt = init_opt_state(self.params, self.pc,
                                      self.bundle.specs)

    def resize(self, mesh_shape, axes=("data", "model"), devices=None):
        """Elastic resize: checkpoint -> rebuild mesh/step -> restore.

        Works for any new dp count (the generalized allreduce needs no
        power-of-two), including prime sizes.
        """
        with obs_trace.span("train.resize", cat="train",
                            mesh=list(mesh_shape)):
            self.ckpt.wait()
            params_host = jax.device_get(self.params)
            opt_host = jax.device_get(self.opt)
            self._build(mesh_shape, axes, devices, seed=0, fresh=False)
            self.params = params_host
            fresh_opt = init_opt_state(params_host, self.pc,
                                       self.bundle.specs)
            reset, restored = _merge_opt(opt_host, fresh_opt)
            self.opt = restored
            if reset:
                _log.info("resize_reset_opt", keys=",".join(reset))

    # -------------------------------------------------------------- run
    def run(self, n_steps: int):
        metrics_log = []
        tracer = obs_trace.get_tracer()
        for _ in range(n_steps):
            with obs_trace.span("train.step", cat="train",
                                step=self.step) as sp:
                batch = synth_batch(self.cfg, self.dc, self.step)
                t0 = time.perf_counter()
                self.params, self.opt, metrics = self.bundle.train_step(
                    self.params, self.opt, batch)
                loss = float(metrics["loss"])   # blocks; realistic timing
                dt = time.perf_counter() - t0
                sp.set(loss=loss, dt_us=round(dt * 1e6, 1))
            tracer.counter("train_step_us", round(dt * 1e6, 1),
                           cat="train")
            self._watch_straggler(dt)
            metrics_log.append({"step": self.step, "loss": loss,
                                "dt": dt})
            self.step += 1
            if self.step % self.ec.ckpt_every == 0:
                with obs_trace.span("train.checkpoint", cat="train",
                                    step=self.step):
                    self.ckpt.save(
                        self.step,
                        {"params": self.params, "opt": self.opt},
                        meta={"dp": self.pc.dp, "tp": self.pc.tp})
        return metrics_log

    @property
    def step_time_ewma(self) -> Optional[float]:
        return self.watch.value

    def _watch_straggler(self, dt: float):
        baseline = self.watch.value
        if self.watch.observe(dt):
            self.alerts.append((self.step, dt, baseline))
            _log.warn("straggler", step=self.step, dt_s=round(dt, 4),
                      ewma_s=round(baseline, 4),
                      factor=self.ec.straggler_factor)
            obs_trace.get_tracer().instant(
                "straggler", cat="train", step=self.step,
                dt_us=round(dt * 1e6, 1))

    # --------------------------------------------------------- recovery
    def restore_latest(self):
        self.ckpt.wait()
        like = {"params": jax.device_get(self.params),
                "opt": jax.device_get(self.opt)}
        step, out = restore(self.ec.ckpt_dir, like)
        self.params, self.opt = out["params"], out["opt"]
        self.step = step
        return step


def _merge_opt(old_opt, fresh_opt):
    """Keep moment buffers when their layout survived the resize; the
    zero1 flat buffers are dp-dependent and reset otherwise (Adam moments
    re-warm within ~1/(1-b2) steps)."""
    def compatible(a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        return (len(la) == len(lb)
                and all(np.shape(x) == np.shape(y) for x, y in zip(la, lb))
                and jax.tree.structure(a) == jax.tree.structure(b))

    merged, reset = {}, []
    for k, fresh in fresh_opt.items():
        old = old_opt.get(k)
        if old is not None and compatible(old, fresh):
            merged[k] = old
        else:
            merged[k] = fresh
            reset.append(k)
    return reset, merged
