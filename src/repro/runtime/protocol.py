"""Length-prefixed socket framing for the coordinator/worker runtime.

One frame = an 8-byte big-endian prefix (header length, payload length)
followed by a JSON header and an opaque binary payload.  The header
carries control fields (message type, step indices, ranks); the payload
carries row data packed by :func:`pack_rows` -- each row self-describing
(4-byte element count + little-endian float64 bytes), so a receiver
never needs out-of-band shape tables to deserialize a step's arrivals.

Everything here is stdlib + numpy: worker processes use it without
importing the JAX half of the package.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import List, Tuple

import numpy as np

_PREFIX = struct.Struct(">II")
_ROW = struct.Struct(">I")

# a frame whose declared sizes exceed this is treated as stream
# corruption, not an allocation request (64 MiB of float64 rows is far
# beyond anything the toy DP worker ships)
MAX_FRAME_BYTES = 64 << 20


class ProtocolError(ConnectionError):
    """Framing violation: truncated stream or absurd declared length."""


def send_msg(sock: socket.socket, header: dict, payload: bytes = b"") -> None:
    """Write one frame.  ``sendall`` so partial writes never tear it."""
    h = json.dumps(header, separators=(",", ":")).encode()
    sock.sendall(_PREFIX.pack(len(h), len(payload)) + h + payload)


def recv_msg(sock: socket.socket) -> Tuple[dict, bytes]:
    """Read one frame; raises :class:`ProtocolError` on EOF/corruption.

    >>> a, b = socket.socketpair()
    >>> send_msg(a, {"type": "ping", "step": 3})
    >>> hdr, payload = recv_msg(b)
    >>> (hdr["type"], hdr["step"], payload)
    ('ping', 3, b'')
    >>> a.close(); b.close()
    """
    raw = _recv_exact(sock, _PREFIX.size)
    hlen, plen = _PREFIX.unpack(raw)
    if hlen + plen > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame declares {hlen + plen} bytes")
    header = json.loads(_recv_exact(sock, hlen))
    payload = _recv_exact(sock, plen) if plen else b""
    return header, payload


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        got = sock.recv(n - len(buf))
        if not got:
            raise ProtocolError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += got
    return bytes(buf)


def pack_rows(rows: List[np.ndarray]) -> bytes:
    """Serialize float64 rows, each prefixed with its element count.

    >>> rows = [np.arange(3.0), np.array([7.5])]
    >>> [r.tolist() for r in unpack_rows(pack_rows(rows))]
    [[0.0, 1.0, 2.0], [7.5]]
    >>> unpack_rows(b"")
    []
    """
    parts = []
    for r in rows:
        a = np.ascontiguousarray(np.asarray(r, dtype="<f8"))
        parts.append(_ROW.pack(a.size) + a.tobytes())
    return b"".join(parts)


def unpack_rows(buf: bytes) -> List[np.ndarray]:
    """Inverse of :func:`pack_rows`."""
    rows, off = [], 0
    while off < len(buf):
        (n,) = _ROW.unpack_from(buf, off)
        off += _ROW.size
        end = off + n * 8
        if end > len(buf):
            raise ProtocolError(f"row declares {n} elems past buffer end")
        rows.append(np.frombuffer(buf, dtype="<f8", count=n, offset=off).copy())
        off = end
    return rows
