"""Fault-tolerant elastic runtime.

Two execution tiers live here:

* :mod:`repro.runtime.elastic` -- the in-process tier: one JAX process,
  ``ElasticRunner`` owning the (mesh, step-bundle, state) triple with
  straggler watch, checkpointing, and elastic ``resize``.
* :mod:`repro.runtime.coordinator` / :mod:`repro.runtime.worker` -- the
  multi-process tier: a coordinator process spawning one OS process per
  rank, relaying the compiled schedule's per-step messages over TCP
  (:mod:`repro.runtime.protocol`), detecting worker death through the
  heartbeat/step-barrier protocol, and recovering by restoring the last
  valid checkpoint and recompiling the collective for the survivor
  count -- any count, including primes, which is exactly what the
  generalized allreduce buys (a power-of-two-only schedule family would
  force spares or padding here).

Deterministic fault injection for both tiers is in
:mod:`repro.runtime.faults` (``REPRO_FAULTS`` env var).
"""

from .faults import Fault, FaultPlan, parse_faults

__all__ = ["Fault", "FaultPlan", "parse_faults"]
