"""Hierarchical collective schedules composed from per-level schedules.

The paper's mixed-radix groups (sections 5-9) factor over a product of
axes; this module exploits that factorization for *heterogeneous* fabrics.
Instead of one schedule over the flattened device index (every step gated
by the slowest level, see :func:`~repro.topology.fabric.bottleneck_fabric`),
a :class:`HierarchicalSchedule` composes:

1. **reduce-scatter** on each inner level, innermost (fastest) first --
   each pass shrinks the live message by that level's size, so the big
   messages ride the fast links;
2. the paper's **generalized allreduce** with tunable ``r`` on the outer
   (slowest) level, operating on a 1/inner_size-sized chunk;
3. **all-gather** back up the inner levels in reverse order.

This is the standard hierarchical decomposition of message-passing
systems (Traeff arXiv:2410.14234, Jocksch et al. arXiv:2006.13112) --
the generality the paper adds is that every level may have an awkward
(non-power-of-two) size and still gets a valid, verified schedule.

All compositions are verified end-to-end against the numpy oracle
(:func:`simulate_hierarchical` replays the actual per-level compiled
steps), and costed exactly from the per-level step traffic
(:func:`hierarchical_cost`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import List, Optional, Tuple

import numpy as np

from repro.core.cost_model import (choose_n_buckets,
                                   pipelined_schedule_cost, schedule_cost)
from repro.core.schedule import (Schedule, build_all_gather,
                                 build_generalized, build_reduce_scatter,
                                 build_ring, max_r)
from repro.core.simulator import (simulate, simulate_all_gather,
                                  simulate_reduce_scatter)

from .fabric import Topology, bottleneck_fabric


# ---------------------------------------------------------------------------
#  composition
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HierarchicalSchedule:
    """Per-level compiled schedules for one hierarchical allreduce.

    ``rs``/``ag`` are ordered in *execution* order: ``rs[i]`` runs over
    ``inner_levels[i]`` where ``inner_levels`` lists topology level
    indices innermost first; ``ag`` replays the same levels reversed.
    """

    topology: Topology
    r: int                           # outer-level generalized-allreduce r
    rs: Tuple[Schedule, ...]         # reduce-scatter per inner level
    ar: Schedule                     # outer allreduce
    ag: Tuple[Schedule, ...]         # all-gather per inner level (rev. order)

    @property
    def P(self) -> int:
        return self.topology.P

    @property
    def inner_levels(self) -> Tuple[int, ...]:
        """Topology level indices in reduce-scatter execution order."""
        return tuple(range(self.topology.n_levels - 1, 0, -1))

    @property
    def n_steps(self) -> int:
        return (sum(s.n_steps for s in self.rs) + self.ar.n_steps
                + sum(s.n_steps for s in self.ag))

    def summary(self) -> dict:
        return {
            "topology": self.topology.describe(),
            "P": self.P,
            "r": self.r,
            "steps": self.n_steps,
            "rs_steps": [s.n_steps for s in self.rs],
            "ar_steps": self.ar.n_steps,
            "ag_steps": [s.n_steps for s in self.ag],
        }


@lru_cache(maxsize=None)
def build_hierarchical(topo: Topology, r: int = 0) -> HierarchicalSchedule:
    """Compile the hierarchical allreduce for ``topo``.

    ``r`` tunes the outer-level generalized allreduce exactly as in the
    flat case: r=0 is bandwidth-optimal, r=max_r(outer) latency-optimal.
    Inner levels always run the canonical reduce-scatter / all-gather
    (the reduction phase / distribution phase of the paper's algorithm).
    """
    sizes = topo.sizes
    inner = tuple(range(topo.n_levels - 1, 0, -1))
    rs = tuple(build_reduce_scatter(sizes[i],
                                    group_kind=topo.levels[i].group_kind)
               for i in inner)
    ar = build_generalized(sizes[0], r,
                           group_kind=topo.levels[0].group_kind)
    ag = tuple(build_all_gather(sizes[i],
                                group_kind=topo.levels[i].group_kind)
               for i in reversed(inner))
    return HierarchicalSchedule(topology=topo, r=r, rs=rs, ar=ar, ag=ag)


# ---------------------------------------------------------------------------
#  numpy oracle: end-to-end verification
# ---------------------------------------------------------------------------

def _level_groups(sizes: Tuple[int, ...], level: int) -> np.ndarray:
    """(n_groups, sizes[level]) array of global ranks; each row is the set
    of ranks that differ only in the given level's coordinate, ordered by
    that coordinate."""
    ranks = np.arange(math.prod(sizes)).reshape(sizes)
    moved = np.moveaxis(ranks, level, -1)
    return moved.reshape(-1, sizes[level])


def simulate_hierarchical(hs: HierarchicalSchedule,
                          vectors: List[np.ndarray],
                          op=np.add) -> List[np.ndarray]:
    """Replay the composed per-level schedules over P explicit processes.

    Every phase runs the *actual compiled steps* of its level schedule
    via the core simulator, within each subgroup of ranks sharing all
    other level coordinates.  Returns P arrays, each the full reduction
    of all inputs -- the oracle for the JAX executor and the tests.
    """
    topo = hs.topology
    P = topo.P
    assert len(vectors) == P
    m = vectors[0].shape[0]
    inner_prod = topo.inner_size
    # pad so every inner reduce-scatter divides evenly
    mp = -(-m // inner_prod) * inner_prod
    state: List[np.ndarray] = []
    for v in vectors:
        if mp != m:
            v = np.concatenate([v, np.zeros((mp - m,) + v.shape[1:],
                                            v.dtype)])
        state.append(v.copy())

    # 1) reduce-scatter down the inner levels, innermost first
    for sched, level in zip(hs.rs, hs.inner_levels):
        for group in _level_groups(topo.sizes, level):
            chunks, owners = simulate_reduce_scatter(
                sched, [state[rk] for rk in group], op)
            for c, rk in enumerate(group):
                # canonical place-0 layout: member c owns chunk c
                assert owners[c] == c
                state[rk] = chunks[c]

    # 2) generalized allreduce across the outer level
    for group in _level_groups(topo.sizes, 0):
        results = simulate(hs.ar, [state[rk] for rk in group], op)
        for c, rk in enumerate(group):
            state[rk] = results[c]

    # 3) all-gather back up, reverse order
    for sched, level in zip(hs.ag, reversed(hs.inner_levels)):
        for group in _level_groups(topo.sizes, level):
            gathered = simulate_all_gather(sched,
                                           [state[rk] for rk in group])
            for c, rk in enumerate(group):
                state[rk] = gathered[c]

    return [v[:m] for v in state]


# ---------------------------------------------------------------------------
#  exact hierarchical cost
# ---------------------------------------------------------------------------

def hierarchical_cost(hs: HierarchicalSchedule, m: float) -> float:
    """Exact alpha-beta-gamma cost of a hierarchical schedule for an
    ``m``-byte message: the sum of per-level schedule-derived costs, each
    with its own fabric and the message size live at that phase."""
    topo = hs.topology
    t = 0.0
    msg = float(m)
    for sched, level in zip(hs.rs, hs.inner_levels):
        t += schedule_cost(sched, msg, topo.levels[level].fabric)
        msg /= topo.levels[level].size
    t += schedule_cost(hs.ar, msg, topo.outer.fabric)
    for sched, level in zip(hs.ag, reversed(hs.inner_levels)):
        msg *= topo.levels[level].size
        t += schedule_cost(sched, msg, topo.levels[level].fabric)
    return t


def flat_cost(topo: Topology, m: float, r: int = 0,
              kind: str = "generalized") -> float:
    """Cost of a flat schedule over the flattened device index, gated by
    the bottleneck fabric (see :func:`bottleneck_fabric`)."""
    f = bottleneck_fabric(topo)
    sched = build_ring(topo.P) if kind == "ring" else \
        build_generalized(topo.P, r)
    return schedule_cost(sched, m, f)


# ---------------------------------------------------------------------------
#  flat-vs-hierarchical autotuner
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CollectivePlan:
    """Autotuner verdict for one (topology, message size) pair.

    ``n_buckets`` is the pipelined bucket count of the ExecPlan executor
    for the allreduce phase (the whole message for flat plans, the
    outer-level allreduce for hierarchical ones).
    """

    kind: str          # "flat-generalized" | "flat-ring" | "hierarchical"
    r: int             # flat r, or outer-level r for hierarchical
    cost: float        # modeled seconds, or measured seconds when tuned
    n_buckets: int = 1
    source: str = "model"  # "model" | "measured"


def best_flat_plan(topo: Topology, nbytes: float,
                   allow_ring: bool = True,
                   itemsize: int = 1) -> CollectivePlan:
    """Cheapest *flat* plan (any r, optionally ring, any bucket count)
    over the flattened device index, costed on the bottleneck fabric (or
    the only fabric of a single-level topology).  Delegates to the core
    autotuner's model path, so messages whose *element count*
    (``nbytes // itemsize``) does not divide ``P`` are priced by the
    ragged true-byte cost -- one implementation, not two."""
    from repro.core.autotune import choose
    flat_fabric = topo.levels[0].fabric if topo.n_levels == 1 \
        else bottleneck_fabric(topo)
    ch = choose(topo.P, int(nbytes), flat_fabric, allow_ring,
                tune=False, itemsize=itemsize)
    kind = "flat-ring" if ch.kind == "ring" else "flat-generalized"
    return CollectivePlan(kind, ch.r, ch.cost, ch.n_buckets)


def best_hierarchical_plan(topo: Topology,
                           nbytes: float) -> Optional[CollectivePlan]:
    """Cheapest hierarchical plan (any outer r) over per-level fabrics;
    None for single-level topologies, where no composition exists.  The
    bucket count pipelines the outer-level allreduce, whose live message
    has shrunk by the inner reduce-scatters."""
    if topo.n_levels == 1:
        return None
    best: Optional[CollectivePlan] = None
    outer_bytes = nbytes / topo.inner_size
    for r in range(max_r(topo.outer.size) + 1):
        hs = build_hierarchical(topo, r)
        c = hierarchical_cost(hs, nbytes)
        b = choose_n_buckets(hs.ar, outer_bytes, topo.outer.fabric)
        if b > 1:
            c += (pipelined_schedule_cost(hs.ar, outer_bytes,
                                          topo.outer.fabric, b)
                  - schedule_cost(hs.ar, outer_bytes, topo.outer.fabric))
        if best is None or c < best.cost:
            best = CollectivePlan("hierarchical", r, c, b)
    return best


def choose_collective(topo: Topology, nbytes: int,
                      allow_ring: bool = True,
                      tune: Optional[bool] = None,
                      itemsize: int = 1) -> CollectivePlan:
    """Pick the cheapest plan: flat (any r, optionally ring) over the
    bottleneck fabric vs hierarchical (any outer r) over per-level
    fabrics.  Single-level topologies always resolve to a flat plan
    costed on their only fabric.

    With ``tune`` enabled (explicitly, or via ``REPRO_TUNING=1`` when
    ``tune=None``) the measured tuning table is consulted first.  A
    stored flat-allreduce measurement over ``topo.P`` devices is only a
    like-for-like answer on a *single-level* topology, so that is the
    case it covers; multi-level fabrics keep the per-level analytic
    comparison until hierarchical compositions are measured end-to-end
    (measurements of the flat executor say nothing about the per-level
    reduce-scatter / allreduce / all-gather pipeline).
    """
    if topo.P <= 1:
        return CollectivePlan("flat-generalized", 0, 0.0)
    from repro.core.autotune import _tune_default
    if (_tune_default() if tune is None else tune) and topo.n_levels == 1:
        from repro.tuning import policy
        measured = policy.lookup(topo.P, int(nbytes), allow_ring=allow_ring,
                                 itemsize=max(int(itemsize), 1))
        if measured is not None:
            kind = "flat-ring" if measured.kind == "ring" \
                else "flat-generalized"
            return CollectivePlan(kind, measured.r, measured.cost,
                                  measured.n_buckets, source="measured")
    return _choose_collective_model(topo, nbytes, allow_ring,
                                    max(int(itemsize), 1))


@lru_cache(maxsize=None)
def _choose_collective_model(topo: Topology, nbytes: int,
                             allow_ring: bool,
                             itemsize: int = 1) -> CollectivePlan:
    best = best_flat_plan(topo, nbytes, allow_ring, itemsize)
    hier = best_hierarchical_plan(topo, nbytes)
    if hier is not None and hier.cost < best.cost:
        best = hier
    return best


def schedules_for_plan(plan: CollectivePlan, topo: Topology):
    """Materialize the compiled schedule(s) a plan refers to: a flat
    :class:`Schedule` or a :class:`HierarchicalSchedule`."""
    if plan.kind == "hierarchical":
        return build_hierarchical(topo, plan.r)
    if plan.kind == "flat-ring":
        return build_ring(topo.P)
    return build_generalized(topo.P, plan.r)
