"""Multi-level fabric descriptions for heterogeneous networks.

The flat :class:`~repro.core.cost_model.Fabric` models one homogeneous
point-to-point network.  Real deployments are hierarchical: a TPU multi-pod
job sees ~1 us ICI hops inside a pod and ~10 us DCN hops between pods; a
GPU cluster sees NVLink inside a node and InfiniBand across nodes.  A
:class:`Topology` names each level of that hierarchy and attaches the
per-level alpha/beta/gamma parameters, so the schedule compiler can be
applied *per level* (see :mod:`repro.topology.hierarchical`) instead of
pretending the whole machine is one ring.

Levels are ordered **outermost (slowest) first**, matching how mesh axes
are written: ``("pod", "data")`` has the DCN level at index 0 and the ICI
level at index 1.  Global rank <-> level coordinates use the mixed-radix
convention with the innermost level fastest-varying -- exactly the
flattened index JAX uses for a collective over the axis tuple
``("pod", "data")``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence, Tuple

from repro.core.cost_model import Fabric, TPU_V5E_ICI

# ---------------------------------------------------------------------------
#  per-level fabric constants
# ---------------------------------------------------------------------------

# Inter-pod data-center network: ~10 us latency, ~25 GB/s per host pair;
# combines still run at HBM speed on chip.
TPU_DCN = Fabric(alpha=1e-5, beta=1.0 / 25e9, gamma=3.0 / 819e9,
                 name="tpu-dcn")

# H100-class NVLink island: ~2 us launch latency, ~450 GB/s per GPU,
# combine speed bounded by HBM3 (~3.35 TB/s, 3 bytes per combined byte).
GPU_NVLINK = Fabric(alpha=2e-6, beta=1.0 / 450e9, gamma=3.0 / 3350e9,
                    name="gpu-nvlink")

# 400 Gb/s InfiniBand NIC per node: ~5 us latency, ~50 GB/s.
GPU_IB = Fabric(alpha=5e-6, beta=1.0 / 50e9, gamma=3.0 / 3350e9,
                name="gpu-ib")


# ---------------------------------------------------------------------------
#  Topology
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Level:
    """One level of the fabric hierarchy.

    group_kind selects the permutation group used when compiling schedules
    at this level ("cyclic" works for any size; "hypercube" needs 2^k).
    """

    name: str
    size: int
    fabric: Fabric
    group_kind: str = "cyclic"

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"level {self.name!r}: size must be >= 1")


@dataclass(frozen=True)
class Topology:
    """A product of fabric levels, outermost (slowest) first."""

    levels: Tuple[Level, ...]
    name: str = "topology"

    def __post_init__(self):
        if not self.levels:
            raise ValueError("Topology needs at least one level")

    # ---- shape -----------------------------------------------------------
    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(lv.size for lv in self.levels)

    @property
    def P(self) -> int:
        """Total number of devices."""
        return math.prod(self.sizes)

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def outer(self) -> Level:
        return self.levels[0]

    @property
    def inner(self) -> Tuple[Level, ...]:
        return self.levels[1:]

    @property
    def inner_size(self) -> int:
        return math.prod(lv.size for lv in self.inner) if self.inner else 1

    # ---- rank <-> coordinate maps ---------------------------------------
    def coords(self, rank: int) -> Tuple[int, ...]:
        """Mixed-radix digits of ``rank`` (innermost level fastest)."""
        out = []
        for s in reversed(self.sizes):
            out.append(rank % s)
            rank //= s
        return tuple(reversed(out))

    def rank(self, coords: Sequence[int]) -> int:
        x = 0
        for c, s in zip(coords, self.sizes):
            x = x * s + c
        return x

    def describe(self) -> str:
        return " > ".join(f"{lv.name}[{lv.size}]@{lv.fabric.name}"
                          for lv in self.levels)

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"Topology({self.describe()})"


def bottleneck_fabric(topo: Topology) -> Fabric:
    """The fabric a *flat* schedule over the flattened device index pays.

    Every cyclic shift on the flattened index moves some pair of ranks
    across every level boundary (and shifts that are multiples of the
    inner size move *all* pairs across the outer level), and SPMD steps
    complete only when the slowest transfer lands -- so each step of a
    flat schedule is gated by the worst per-level latency and bandwidth.
    """
    return Fabric(alpha=max(lv.fabric.alpha for lv in topo.levels),
                  beta=max(lv.fabric.beta for lv in topo.levels),
                  gamma=max(lv.fabric.gamma for lv in topo.levels),
                  name=f"bottleneck({topo.name})")


# ---------------------------------------------------------------------------
#  presets
# ---------------------------------------------------------------------------

def v5e_pod(chips: int = 256) -> Topology:
    """Single TPU v5e pod: one homogeneous ICI level."""
    return Topology((Level("ici", chips, TPU_V5E_ICI),),
                    name=f"v5e-{chips}")


def v5e_multipod(pods: int = 2, chips_per_pod: int = 256) -> Topology:
    """Multi-pod v5e: DCN between pods, ICI inside each pod."""
    return Topology((Level("pod", pods, TPU_DCN),
                     Level("ici", chips_per_pod, TPU_V5E_ICI)),
                    name=f"v5e-{pods}x{chips_per_pod}")


def gpu_cluster(nodes: int, gpus_per_node: int = 8) -> Topology:
    """N-node GPU cluster: InfiniBand between nodes, NVLink inside."""
    return Topology((Level("node", nodes, GPU_IB),
                     Level("nvlink", gpus_per_node, GPU_NVLINK)),
                    name=f"gpu-{nodes}x{gpus_per_node}")


# the production multi-pod deployment of ROADMAP.md / launch/mesh.py
MULTI_POD_2X256 = v5e_multipod(2, 256)
