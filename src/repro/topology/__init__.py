"""Topology-aware hierarchical collectives.

Public API:
  fabric       -- Level / Topology descriptions of multi-level fabrics
                  (ICI + DCN, NVLink + IB) plus deployment presets
  hierarchical -- HierarchicalSchedule composition of per-level compiled
                  schedules, numpy-oracle verification, exact per-level
                  costs, and the flat-vs-hierarchical autotuner
"""
from .fabric import (GPU_IB, GPU_NVLINK, Level, MULTI_POD_2X256, TPU_DCN,
                     Topology, bottleneck_fabric, gpu_cluster, v5e_multipod,
                     v5e_pod)
from .hierarchical import (CollectivePlan, HierarchicalSchedule,
                           best_flat_plan, best_hierarchical_plan,
                           build_hierarchical, choose_collective, flat_cost,
                           hierarchical_cost, schedules_for_plan,
                           simulate_hierarchical)
