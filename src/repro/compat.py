"""Version-compatibility layer over the JAX APIs the repo drives.

The repo targets current JAX (``jax.shard_map`` with ``check_vma``,
explicit mesh axis types).  Older runtimes (<= 0.4.x) ship the same
machinery as ``jax.experimental.shard_map`` (with ``check_rep``) and have
no ``jax.sharding.AxisType``; this shim keeps every call site on one
spelling instead of scattering try/except through the codebase.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` on current JAX, the experimental fallback on old
    JAX (where ``check_vma`` was spelled ``check_rep``).

    ``check_vma=None`` keeps each JAX version's own default (the
    replication checker stays ON where available); pass False only to
    opt out explicitly.
    """
    kw = {} if check_vma is None else {"check_vma": check_vma}
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if check_vma is not None:
        kw = {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def axis_size(axis_name) -> int:
    """Static size of one named mesh axis inside shard_map tracing.

    ``lax.axis_size`` on current JAX; on old JAX the axis env exposes the
    same static size via ``jax.core.axis_frame``.
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax.core as core
    return core.axis_frame(axis_name)


def tree_flatten_with_path(tree):
    """``jax.tree.flatten_with_path`` / old ``jax.tree_util`` spelling."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def default_axis_types(n: int) -> Optional[Tuple]:
    """(AxisType.Auto,) * n where supported, None (= don't pass the kwarg)
    on JAX versions without explicit mesh axis types."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return None
    return (AxisType.Auto,) * n
