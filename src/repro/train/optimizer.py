"""AdamW with three distributed layouts over the DP axes.

* ``dp``    -- optimizer state replicated; gradients synchronized with the
              paper's generalized allreduce (autotuned step count r).
* ``zero1`` -- optimizer state sharded 1/dp as one flat buffer; gradients
              go through the *reduction phase only* (reduce-scatter,
              ceil(log P) steps for any P), the updated parameter chunks
              come back through the *distribution phase* (all-gather).
              The paper's two phases map 1:1 onto ZeRO-1's two collectives.
* ``fsdp``  -- parameters themselves sharded; gradient reduce-scatter falls
              out of the VJP of the forward all-gather (ZeRO-3).

All modes share the same AdamW math.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.allreduce import all_gather_flat, exact_chunks
from repro.core.schedule import ShapeError, ragged_sizes
from repro.parallel.api import ParallelConfig


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(oc: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps)
                    / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def _adam_math(g, m, v, p, oc: OptConfig, lr, bc1, bc2):
    m = oc.b1 * m + (1 - oc.b1) * g
    v = oc.b2 * v + (1 - oc.b2) * g * g
    mh = m / bc1
    vh = v / bc2
    upd = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p
    return p - lr * upd, m, v


# ---------------------------------------------------------------------------
#  tree <-> flat-shard plumbing (zero1)
# ---------------------------------------------------------------------------

def _flat_size(params) -> int:
    return sum(int(jnp.size(leaf)) for leaf in jax.tree.leaves(params))


def _padded_chunk(n: int, dp: int) -> int:
    return -(-n // dp)


def flatten_params(params):
    leaves = jax.tree.leaves(params)
    return jnp.concatenate([leaf.reshape(-1).astype(jnp.float32)
                            for leaf in leaves])


def unflatten_like(flat, params):
    leaves, treedef = jax.tree.flatten(params)
    out, off = [], 0
    for leaf in leaves:
        n = int(jnp.size(leaf))
        out.append(flat[off:off + n].reshape(leaf.shape)
                   .astype(leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
#  optimizer states
# ---------------------------------------------------------------------------

def local_flat_size(params, pc: ParallelConfig, specs) -> int:
    """Device-local flat parameter count: TP-sharded dims divided by tp.

    The zero1 flat buffers live *inside* shard_map where every leaf is
    already its TP shard, so all bookkeeping uses local sizes.
    """
    n = 0
    for leaf, spec in zip(jax.tree.leaves(params), jax.tree.leaves(specs)):
        sz = int(np.prod(leaf.shape)) if leaf.shape else 1
        if spec.tp_dim is not None and pc.tp > 1:
            sz //= pc.tp
        n += sz
    return n


def init_opt_state(params, pc: ParallelConfig, specs=None,
                   mode: Optional[str] = None):
    mode = mode or pc.param_mode
    if mode in ("dp", "fsdp"):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }
    # zero1: flat moment buffers; GLOBAL shape (dp * ceil(N_local/dp),) --
    # each device sees its (ceil(N_local/dp),) slice via P(dp_axes).
    assert specs is not None, "zero1 needs the ParamSpec tree"
    n = local_flat_size(params, pc, specs)
    u = _padded_chunk(n, pc.dp)
    return {
        "m": jnp.zeros((pc.dp * u,), jnp.float32),
        "v": jnp.zeros((pc.dp * u,), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
#  updates (run inside shard_map)
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads, oc: OptConfig, sq_psum_axes=None):
    """Global-norm gradient clipping.

    ``sq_psum_axes``: axes to psum the squared norm over when the grads
    are sharded (zero1 flat shards over DP).  For fsdp mode clipping is
    intentionally not applied (the mixed sharded/replicated layout would
    need a per-leaf axis map; documented limitation).
    """
    if oc.grad_clip is None:
        return grads
    sumsq = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                for leaf in jax.tree.leaves(grads))
    if sq_psum_axes:
        sumsq = lax.psum(sumsq, sq_psum_axes)
    norm = jnp.sqrt(sumsq)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def apply_updates_dp(params, grads, opt_state, oc: OptConfig,
                     pc: ParallelConfig):
    """Replicated AdamW (modes dp / fsdp: grads already laid out like
    params)."""
    step = opt_state["step"] + 1
    lr = lr_at(oc, step)
    bc1 = 1 - oc.b1 ** step.astype(jnp.float32)
    bc2 = 1 - oc.b2 ** step.astype(jnp.float32)

    def upd(i):
        def f(p, g, m, v):
            p32 = p.astype(jnp.float32)
            out = _adam_math(g.astype(jnp.float32), m, v, p32, oc, lr,
                             bc1, bc2)
            return out[i].astype(p.dtype) if i == 0 else out[i]
        return f

    # three passes over the tree; XLA CSEs the shared math
    new_params = jax.tree.map(upd(0), params, grads,
                              opt_state["m"], opt_state["v"])
    new_m = jax.tree.map(upd(1), params, grads,
                         opt_state["m"], opt_state["v"])
    new_v = jax.tree.map(upd(2), params, grads,
                         opt_state["m"], opt_state["v"])
    return new_params, {"m": new_m, "v": new_v, "step": step}


def apply_updates_zero1(params, grad_shard, opt_state, oc: OptConfig,
                        pc: ParallelConfig):
    """ZeRO-1: AdamW on this device's flat parameter chunk, then the
    distribution phase (all-gather) rebuilds the full parameters.

    The flat size need not divide ``dp``: the gradient shard arriving
    from :func:`repro.core.allreduce.tree_reduce_scatter` is the exact
    ragged chunk of the balanced split (zero-filled to the common
    ``ceil(n / dp)`` width), the matching parameter chunk is sliced with
    the same geometry, and the all-gather back is an exact allgatherv --
    no element is ever updated twice and no padding survives.

    Checkpoint note: this changed the zero1 chunk boundaries for
    non-divisible flat sizes from ``[d*u, (d+1)*u)`` (trailing-pad) to
    the balanced split.  The global moment-buffer *shape* is unchanged,
    so an old checkpoint restores cleanly only for ``dp | n_params``;
    resuming an old non-divisible zero1 run re-warms the (bounded)
    moment mismatch near chunk boundaries rather than erroring --
    acceptable for this repo's short-lived runs, flagged here for
    anyone carrying long-lived checkpoints across this change.
    """
    step = opt_state["step"] + 1
    lr = lr_at(oc, step)
    bc1 = 1 - oc.b1 ** step.astype(jnp.float32)
    bc2 = 1 - oc.b2 ** step.astype(jnp.float32)

    flat = flatten_params(params)
    n = flat.shape[0]
    u = _padded_chunk(n, pc.dp)
    if pc.dp > 1 and grad_shard.shape[0] != u:
        raise ShapeError("zero1 gradient shard width != ceil(n_params/dp)",
                         expected=u, actual=grad_shard.shape[0])
    if pc.dp > 1:
        chunks, _ = exact_chunks(flat, pc.dp)      # (dp, u) ragged rows
        d = lax.axis_index(pc.dp_axis_name)
        my = lax.dynamic_index_in_dim(chunks, d, keepdims=False)
    else:
        my = flat
    p2, m2, v2 = _adam_math(grad_shard, opt_state["m"], opt_state["v"],
                            my, oc, lr, bc1, bc2)
    if pc.dp > 1:
        full = all_gather_flat(p2, pc.dp_axis_name,
                               sizes=ragged_sizes(n, pc.dp))
    else:
        full = p2[:n]
    new_params = unflatten_like(full, params)
    return new_params, {"m": m2, "v": v2, "step": step}
