"""Train / serve step builders: the shard_map programs.

This is where the paper's collective meets the training loop:

* ``dp`` mode    -- per-bucket generalized allreduce of the gradients over
                    the DP axes, step count r autotuned from the fabric
                    parameters via the paper's eq (37) / exact search.
* ``zero1`` mode -- reduction phase only (= any-P reduce-scatter in
                    ceil(lg P) steps); the distribution phase re-broadcasts
                    updated parameters inside the optimizer.
* ``fsdp`` mode  -- parameters sharded over DP; the forward all-gather's
                    VJP reduce-scatters gradients automatically; leftover
                    DP-replicated leaves still sync through the paper's
                    allreduce.

Gradients of TP-replicated parameters (norms, replicated KV, routers,
q/k of mLSTM, all of sLSTM) are partial under sequence-parallelism and get
an exact ``psum`` over the TP axis first.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.allreduce import tree_reduce_scatter
from repro.core.cost_model import Fabric, TPU_V5E_ICI
from repro.models.config import ModelConfig
from repro.models.model import (decode_step, init_caches, init_paged_caches,
                                loss_and_metrics, paged_decode_step,
                                param_shapes)
from repro.parallel.api import (ParallelConfig, ParamSpec,
                                attach_overlap_sync, bucketed_grad_sync,
                                dp_grad_allreduce, reverse_layer_buckets)
from repro.train.optimizer import (OptConfig, apply_updates_dp,
                                   apply_updates_zero1, clip_by_global_norm,
                                   init_opt_state)


# ---------------------------------------------------------------------------
#  PartitionSpec derivation
# ---------------------------------------------------------------------------

def pspec_for(spec: ParamSpec, ndim: int, pc: ParallelConfig) -> P:
    dims: list = [None] * ndim
    if spec.tp_dim is not None and pc.tp > 1:
        dims[spec.tp_dim] = pc.tp_axis
    if spec.fsdp_dim is not None and pc.param_mode == "fsdp" and pc.dp > 1:
        dims[spec.fsdp_dim] = pc.dp_axes if len(pc.dp_axes) > 1 \
            else pc.dp_axes[0]
    return P(*dims)


def param_pspecs(params_shapes, specs, pc: ParallelConfig):
    return jax.tree.map(
        lambda sd, sp: pspec_for(sp, len(sd.shape), pc), params_shapes, specs)


def batch_pspecs(batch_shapes, pc: ParallelConfig):
    dp = pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0]
    return jax.tree.map(
        lambda sd: P(*([dp] + [None] * (len(sd.shape) - 1))), batch_shapes)


def opt_pspecs(opt_shapes, param_specs_tree, pc: ParallelConfig):
    if pc.param_mode in ("dp", "fsdp"):
        mv = param_pspecs(opt_shapes["m"], param_specs_tree, pc)
        return {"m": mv, "v": jax.tree.map(lambda x: x, mv),
                "step": P()}
    dp = pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0]
    return {"m": P(dp), "v": P(dp), "step": P()}


# ---------------------------------------------------------------------------
#  gradient synchronization
# ---------------------------------------------------------------------------

def sync_grads_tp(grads, specs, pc: ParallelConfig):
    """Exact psum over TP for TP-replicated leaves."""
    if pc.tp == 1:
        return grads

    def f(g, s):
        if s.tp_replicated:
            return lax.psum(g, pc.tp_axis)
        return g

    return jax.tree.map(f, grads, specs)


def sync_grads_dp(grads, specs, pc: ParallelConfig,
                  fabric: Fabric = TPU_V5E_ICI):
    """DP-axis sync per param_mode.  Returns grads in the layout the
    optimizer expects (tree for dp/fsdp, flat shard for zero1)."""
    if pc.param_mode == "zero1":
        shard, _ = tree_reduce_scatter(grads, pc.dp_axis_name, mean=True)
        return shard
    if pc.param_mode == "fsdp":
        if pc.dp == 1:
            return grads
        # fsdp-sharded leaves were already reduce-scattered by the VJP of
        # the forward all-gather but carry a sum over DP -> divide.
        # dp-replicated leaves still need a full allreduce (mean).
        flat, treedef = jax.tree.flatten(grads)
        # align the specs to the *grads* treedef: flatten_up_to raises on
        # any structural mismatch, where zip-by-position over two
        # independent flattenings would silently pair grad leaves with
        # the wrong ParamSpec (sharded leaves interleave with replicated
        # ones in tree order, so a skew here re-scatters the sync)
        sflat = treedef.flatten_up_to(specs)
        flat = [g / pc.dp if s.fsdp_dim is not None else g
                for g, s in zip(flat, sflat)]
        repl_idx = [i for i, s in enumerate(sflat) if s.fsdp_dim is None]
        if repl_idx:
            synced = dp_grad_allreduce([flat[i] for i in repl_idx], pc,
                                       mean=True, fabric=fabric)
            for i, v in zip(repl_idx, synced):
                flat[i] = v
        return jax.tree.unflatten(treedef, flat)
    # pure dp: the paper's generalized allreduce over the whole tree
    # (hierarchical per-level composition when pc.topology spans levels)
    if pc.dp == 1:
        return grads
    return dp_grad_allreduce(grads, pc, mean=True, fabric=fabric)


def replicate_scalar(x, pc: ParallelConfig, mesh_axes):
    """Make a scalar provably replicated for out_specs=P()."""
    return lax.pmean(x, mesh_axes)


# ---------------------------------------------------------------------------
#  backward-overlapped gradient sync: layer derivation + bucketing
# ---------------------------------------------------------------------------

def _leaf_layers(params_shapes):
    """Per-leaf layer index of the params tree, in tree-flatten order.

    The backward pass differentiates the model back-to-front, so the
    leaves whose gradients complete *first* are the deepest layers.
    Layer indices (higher = completes earlier in backward):

    * ``embed``        -> 0                (its grad completes last)
    * ``prefix[i]``    -> 1 + i
    * ``cycles``       -> 1 + n_prefix    (the stacked scan's backward
      emits every cycle's gradient at once, so the whole stack is one
      band -- this is the "scan-carried" arm of the dispatch design:
      scan-stacked archs get a single band-sized dispatch point)
    * ``final_norm`` / ``head`` -> 2 + n_prefix  (complete first)

    Dict flattening is alphabetical, NOT layer order, hence the
    path-based derivation.  The return aligns leaf-for-leaf with
    ``jax.tree.leaves(params_shapes)``.
    """
    import jax.tree_util as jtu
    n_prefix = len(params_shapes.get("prefix", []))
    flat, _ = jtu.tree_flatten_with_path(params_shapes)
    layers = []
    for path, _leaf in flat:
        top = getattr(path[0], "key", None)
        if top == "embed":
            layers.append(0)
        elif top == "prefix":
            layers.append(1 + int(path[1].idx))
        elif top == "cycles":
            layers.append(1 + n_prefix)
        else:                       # final_norm, head
            layers.append(2 + n_prefix)
    return layers


def overlap_buckets_for(params_shapes, pc: ParallelConfig):
    """Reverse-layer gradient buckets for this params tree, or ``None``
    when the overlapped path is off (no ``overlap_bucket_bytes``, pure
    DP only -- fsdp/zero1 reshape gradient flow themselves)."""
    if (pc.overlap_bucket_bytes is None or pc.param_mode != "dp"
            or pc.dp <= 1):
        return None
    leaves = jax.tree.leaves(params_shapes)
    layers = _leaf_layers(params_shapes)
    sizes = [int(sd.size) * jnp.dtype(sd.dtype).itemsize for sd in leaves]
    return reverse_layer_buckets(layers, sizes, pc.overlap_bucket_bytes)


# ---------------------------------------------------------------------------
#  step builders
# ---------------------------------------------------------------------------

@dataclass
class StepBundle:
    train_step: Any
    in_shardings: Any
    out_shardings: Any
    params_shapes: Any
    opt_shapes: Any
    specs: Any
    pc: ParallelConfig


def make_train_step(cfg: ModelConfig, pc: ParallelConfig, mesh: Mesh,
                    oc: OptConfig, *, attn_impl: str = "xla",
                    fabric: Fabric = TPU_V5E_ICI,
                    donate: bool = True,
                    microbatches: int = 1) -> StepBundle:
    """``microbatches > 1``: split the local batch and accumulate
    gradients over a scan -- activation footprint (incl. the per-layer
    residual stacks) scales with 1/microbatches while gradient sync and
    the optimizer run once per step (standard grad accumulation).

    When ``pc.overlap_bucket_bytes`` is set (pure-DP only), gradient
    sync runs per reverse-layer bucket instead of over one post-backward
    flat tensor; ``pc.overlap_dispatch`` picks the dispatch point:
    ``"backward"`` (default) attaches ``custom_vjp`` markers so each
    bucket's allreduce starts the moment its layer band's backward
    completes, ``"post"`` runs the identical per-bucket collectives
    after the backward (the bit-exact A/B control), ``"skip"`` elides DP
    sync (benchmark compute-baseline only).  Gradient accumulation
    (``microbatches > 1``) syncs once per step, so the backward-marker
    arm falls back to the post-backward bucketed sync there.
    """
    if pc.overlap_dispatch not in ("backward", "post", "skip"):
        raise ValueError(f"overlap_dispatch={pc.overlap_dispatch!r} "
                         "(expected backward | post | skip)")
    params_shapes, specs = param_shapes(cfg, pc)
    opt_shapes = jax.eval_shape(
        partial(init_opt_state, pc=pc, specs=specs), params_shapes)
    mesh_axes = tuple(mesh.axis_names)
    buckets = overlap_buckets_for(params_shapes, pc)
    overlap_bwd = (buckets is not None and microbatches == 1
                   and pc.overlap_dispatch == "backward")

    def grad_of(params, batch):
        def local_loss(p):
            if overlap_bwd:
                # identity forward; each bucket's VJP dispatches its
                # dp_grad_allreduce as its backward completes
                p = attach_overlap_sync(p, buckets, pc, fabric=fabric)
            return loss_and_metrics(p, specs, batch, cfg, pc,
                                    attn_impl=attn_impl)
        return jax.value_and_grad(local_loss, has_aux=True)(params)

    def step_fn(params, opt_state, batch):
        if microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, b):
                (loss, (total, count, aux)), g = grad_of(params, b)
                tot_c, cnt_c, aux_c, g_c = carry
                g_c = jax.tree.map(jnp.add, g_c, g)
                return (tot_c + total, cnt_c + count, aux_c + aux,
                        g_c), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (total, count, aux, grads), _ = lax.scan(
                acc_body,
                (jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0), g0),
                mb)
            # each microbatch loss is a mean over its own tokens: the
            # accumulated grad is a sum of per-microbatch means
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            aux = aux / microbatches
        else:
            # the per-device loss is discarded: metrics recompute the
            # global mean from the psum'd (total, count) below
            (_loss, (total, count, aux)), grads = grad_of(params, batch)
        grads = sync_grads_tp(grads, specs, pc)
        if buckets is not None:
            if overlap_bwd or pc.overlap_dispatch == "skip":
                # backward: the markers already synced every bucket
                # in-backward; skip: benchmark compute baseline, grads
                # deliberately left unsynced
                pass
            else:
                grads = bucketed_grad_sync(grads, buckets, pc,
                                           fabric=fabric)
        else:
            grads = sync_grads_dp(grads, specs, pc, fabric)
        if pc.param_mode == "dp":
            grads = clip_by_global_norm(grads, oc)
        elif pc.param_mode == "zero1" and pc.dp > 1:
            grads = clip_by_global_norm(grads, oc,
                                        sq_psum_axes=pc.dp_axis_name)
        if pc.param_mode == "zero1":
            new_params, new_opt = apply_updates_zero1(
                params, grads, opt_state, oc, pc)
        else:
            new_params, new_opt = apply_updates_dp(
                params, grads, opt_state, oc, pc)
        dp_axes = pc.dp_axis_name
        total_g = lax.psum(total, dp_axes) if pc.dp > 1 else total
        count_g = lax.psum(count.astype(jnp.float32), dp_axes) \
            if pc.dp > 1 else count.astype(jnp.float32)
        metrics = {
            "loss": replicate_scalar(total_g / jnp.maximum(count_g, 1.0),
                                     pc, mesh_axes),
            "aux_loss": replicate_scalar(aux, pc, mesh_axes),
            "tokens": replicate_scalar(count_g, pc, mesh_axes),
        }
        return new_params, new_opt, metrics

    p_specs = param_pspecs(params_shapes, specs, pc)
    o_specs = opt_pspecs(opt_shapes, specs, pc)
    batch_shapes = input_shapes(cfg, shape_kind="train", seq_len=8,
                                global_batch=pc.dp)  # structure only
    b_specs = batch_pspecs(batch_shapes, pc)

    shard_fn = shard_map(
        step_fn, mesh=mesh,
        in_specs=(p_specs, o_specs, b_specs),
        out_specs=(p_specs, o_specs,
                   {"loss": P(), "aux_loss": P(), "tokens": P()}),
        check_vma=False)
    jitted = jax.jit(shard_fn,
                     donate_argnums=(0, 1) if donate else ())
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs),
             jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs),
             jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs))
    return StepBundle(jitted, in_sh, None, params_shapes, opt_shapes,
                      specs, pc)


def cache_pspecs(cfg: ModelConfig, pc: ParallelConfig,
                 seq_shard: bool = False):
    """PartitionSpecs matching init_caches' structure: batch dim sharded
    over DP; with ``seq_shard`` the KV caches' sequence dim additionally
    shards over the TP axis (flash-decoding layout); ``pos``/state
    scalars P().

    With dp == 1 (e.g. long_500k's global batch of 1) everything is
    replicated across the data axes."""
    from repro.models.attention import KVCache
    dp = None if pc.dp <= 1 else (
        pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0])
    tp = pc.tp_axis if (seq_shard and pc.tp > 1) else None
    shapes = jax.eval_shape(
        lambda: init_caches(cfg, pc, 1, max(8 * max(pc.tp, 1), 8),
                            rolling=False, seq_shard=seq_shard))

    def spec_of(stacked, sd, kv_seq: bool):
        nd = len(sd.shape)
        if nd == 0:
            return P()
        lead = 2 if stacked else 0
        if stacked and nd <= 2:        # stacked pos (n_cycles, cnt)
            return P(*([None] * nd))
        dims = [None] * nd
        dims[lead] = dp                # batch dim
        if kv_seq and nd >= lead + 3:
            dims[lead + 2] = tp        # (B, H, L, hd): shard L
        return P(*dims)

    def tree_specs(tree, stacked):
        def walk(node):
            if isinstance(node, KVCache):
                return KVCache(
                    spec_of(stacked, node.k, True),
                    spec_of(stacked, node.v, True),
                    spec_of(stacked, node.pos, False))
            if isinstance(node, (dict,)):
                return {k: walk(v) for k, v in node.items()}
            if isinstance(node, (list,)):
                return [walk(v) for v in node]
            if isinstance(node, tuple) and hasattr(node, "_fields"):
                return type(node)(*[spec_of(stacked, f, False)
                                    for f in node])
            return spec_of(stacked, node, False)
        return walk(tree)

    return {"prefix": tree_specs(shapes["prefix"], False),
            "cycles": tree_specs(shapes["cycles"], True)}


@dataclass
class ServeBundle:
    serve_step: Any
    p_specs: Any
    c_specs: Any
    specs: Any
    params_shapes: Any


def make_serve_step(cfg: ModelConfig, pc: ParallelConfig, mesh: Mesh, *,
                    rolling: bool = False, seq_shard: bool = False,
                    attn_impl: str = "xla") -> ServeBundle:
    """One decode (or chunked-prefill) step against stacked caches.

    ``seq_shard``: TP-sequence-sharded KV caches (flash-decoding LSE
    merge) for replicated-KV archs -- decode (S_new == 1) only."""
    params_shapes, specs = param_shapes(cfg, pc)

    def step_fn(params, tokens, caches, pos0):
        logits, new_caches = decode_step(
            params, specs, tokens, caches, pos0, cfg, pc, rolling=rolling,
            seq_shard=seq_shard, attn_impl=attn_impl)
        return logits, new_caches

    p_specs = param_pspecs(params_shapes, specs, pc)
    c_specs = cache_pspecs(cfg, pc, seq_shard=seq_shard)
    dp = None if pc.dp <= 1 else (
        pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0])
    shard_fn = shard_map(
        step_fn, mesh=mesh,
        in_specs=(p_specs, P(dp, None), c_specs, P()),
        out_specs=(P(dp, None, None), c_specs),
        check_vma=False)
    jitted = jax.jit(shard_fn, donate_argnums=(2,))
    return ServeBundle(jitted, p_specs, c_specs, specs, params_shapes)


def paged_cache_pspecs(cfg: ModelConfig, pc: ParallelConfig):
    """PartitionSpecs matching init_paged_caches' structure.

    KV pools shard their ``n_blocks`` dim over DP (each DP shard serves
    its own requests out of its own blocks; block-table entries are
    shard-local physical indices), recurrent states shard their batch
    dim -- conveniently the same rule: the leading non-stacked dim."""
    dp = None if pc.dp <= 1 else (
        pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0])
    shapes = jax.eval_shape(
        lambda: init_paged_caches(cfg, pc, 1, 2 * max(pc.dp, 1), 8))

    def spec_of(stacked, sd):
        nd = len(sd.shape)
        lead = 2 if stacked else 0
        if nd <= lead:
            return P(*([None] * nd))
        dims = [None] * nd
        dims[lead] = dp
        return P(*dims)

    return {
        "prefix": jax.tree.map(lambda sd: spec_of(False, sd),
                               shapes["prefix"]),
        "cycles": jax.tree.map(lambda sd: spec_of(True, sd),
                               shapes["cycles"]),
    }


def make_paged_serve_step(cfg: ModelConfig, pc: ParallelConfig, mesh: Mesh,
                          *, attn_impl: str = "xla") -> ServeBundle:
    """One continuous-batching tick against paged caches.

    The program/state separation follows ``make_serve_step``: this
    builds the jitted shard_map *program* once; all mutable serving
    state (the cache pytree, the host-side block tables / lengths inside
    :class:`~repro.models.attention.PageCtx`) flows through as
    arguments, so one compiled step serves every admission pattern.
    Token shape ``(B, S)`` recompiles only per distinct S -- the engine
    keeps S in {1, prefill_chunk}."""
    from repro.models.attention import PageCtx
    params_shapes, specs = param_shapes(cfg, pc)

    def step_fn(params, tokens, caches, ctx):
        return paged_decode_step(params, specs, tokens, caches, ctx,
                                 cfg, pc, attn_impl=attn_impl)

    p_specs = param_pspecs(params_shapes, specs, pc)
    c_specs = paged_cache_pspecs(cfg, pc)
    dp = None if pc.dp <= 1 else (
        pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0])
    ctx_specs = PageCtx(block_table=P(dp, None), lengths=P(dp),
                        n_new=P(dp), reset=P(dp))
    shard_fn = shard_map(
        step_fn, mesh=mesh,
        in_specs=(p_specs, P(dp, None), c_specs, ctx_specs),
        out_specs=(P(dp, None, None), c_specs),
        check_vma=False)
    jitted = jax.jit(shard_fn, donate_argnums=(2,))
    return ServeBundle(jitted, p_specs, c_specs, specs, params_shapes)


def input_shapes(cfg: ModelConfig, *, shape_kind: str, seq_len: int,
                 global_batch: int, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
    weak-type-correct, shardable, no allocation)."""
    B, S = global_batch, seq_len
    if cfg.frontend == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.frontend == "vision" and shape_kind == "train":
        s_text = max(S - cfg.n_patches, 8)
        return {
            "tokens": jax.ShapeDtypeStruct((B, s_text), jnp.int32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, s_text), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
