"""Mesh construction for single-pod and multi-pod deployments.

Production target: TPU v5e pods of 256 chips (16x16).  The single-pod
mesh is ("data", "model") = (16, 16); the multi-pod mesh adds a leading
"pod" axis: (2, 16, 16) = 512 chips.  Data parallelism runs over
("pod", "data") hierarchically: the ParallelConfig carries a
two-level :class:`repro.topology.Topology` (DCN across pods, ICI
inside), so gradient sync composes per-level schedules -- reduce-scatter
on ICI, the generalized allreduce on DCN, all-gather on ICI -- instead
of flattening (pod, data) into one cyclic group whose every shift is
gated by a DCN hop.

All functions build meshes lazily so importing this module never touches
JAX device state (required by the dry-run's XLA_FLAGS bootstrap).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def _axis_kw(n: int) -> dict:
    from repro.compat import default_axis_types
    at = default_axis_types(n)
    return {} if at is None else {"axis_types": at}


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None):
    """General mesh helper (smoke tests, elastic re-meshing)."""
    import jax
    from jax.sharding import Mesh
    if devices is not None:
        arr = np.asarray(devices).reshape(tuple(shape))
        return Mesh(arr, tuple(axes), **_axis_kw(len(axes)))
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(axes)))


def parallel_config_for(mesh, *, param_mode: str = "fsdp",
                        grad_r=None, collective_impl: str = "xla",
                        topology=None, tuning: bool = False):
    """Derive the static ParallelConfig from a mesh.

    ``topology`` overrides the fabric hierarchy attached for gradient
    sync (e.g. ``repro.topology.gpu_cluster(...)``); by default a mesh
    with a "pod" axis gets the v5e multi-pod preset (DCN + ICI) sized to
    the mesh.  On hierarchical meshes the autotuner reads per-level
    alpha/beta/gamma from this topology -- not from the flat ``fabric``
    argument of the train-step builder, which only governs single-level
    DP meshes.

    ``tuning=True`` opts gradient-sync schedule choice into the measured
    tuning table (:mod:`repro.tuning`) populated by
    ``python benchmarks/run.py tune``; without a compatible measurement
    the analytic model still decides, so the flag is always safe.
    """
    from repro.parallel.api import ParallelConfig
    from repro.topology.fabric import v5e_multipod
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    if "pod" in names:
        dp_axes: Tuple[str, ...] = ("pod", "data")
        dp = sizes["pod"] * sizes["data"]
        if topology is None:
            topology = v5e_multipod(pods=sizes["pod"],
                                    chips_per_pod=sizes["data"])
    else:
        dp_axes = ("data",)
        dp = sizes["data"]
    tp = sizes.get("model", 1)
    return ParallelConfig(dp_axes=dp_axes, dp=dp, tp=tp,
                          param_mode=param_mode, grad_r=grad_r,
                          collective_impl=collective_impl,
                          topology=topology, tuning=tuning)
