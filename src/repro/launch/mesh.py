"""Mesh construction for single-pod and multi-pod deployments.

Production target: TPU v5e pods of 256 chips (16x16).  The single-pod
mesh is ("data", "model") = (16, 16); the multi-pod mesh adds a leading
"pod" axis: (2, 16, 16) = 512 chips.  Data parallelism runs over
("pod", "data") hierarchically -- the generalized-allreduce group for
gradient sync is the cyclic group over the flattened (pod, data) index,
whose powers map onto ICI ring shifts within a pod and DCN hops across
pods.

All functions build meshes lazily so importing this module never touches
JAX device state (required by the dry-run's XLA_FLAGS bootstrap).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import AxisType
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None):
    """General mesh helper (smoke tests, elastic re-meshing)."""
    import jax
    from jax.sharding import AxisType, Mesh
    if devices is not None:
        arr = np.asarray(devices).reshape(tuple(shape))
        return Mesh(arr, tuple(axes),
                    axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def parallel_config_for(mesh, *, param_mode: str = "fsdp",
                        grad_r=None, collective_impl: str = "xla"):
    """Derive the static ParallelConfig from a mesh."""
    from repro.parallel.api import ParallelConfig
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    if "pod" in names:
        dp_axes: Tuple[str, ...] = ("pod", "data")
        dp = sizes["pod"] * sizes["data"]
    else:
        dp_axes = ("data",)
        dp = sizes["data"]
    tp = sizes.get("model", 1)
    return ParallelConfig(dp_axes=dp_axes, dp=dp, tp=tp,
                          param_mode=param_mode, grad_r=grad_r,
                          collective_impl=collective_impl)
