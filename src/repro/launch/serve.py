"""Serving launcher: continuous-batching generation with the production
engine (paged KV cache, per-slot prefill/decode, streaming).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --reduced \\
      --requests 6 --max-new 8
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma_2b \\
      --reduced --mesh 2x4
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV cache block size (tokens)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    ap.add_argument("--decode-collectives", default="plan",
                    choices=("plan", "xla"),
                    help="TP decode psum/gather: ExecPlan schedules "
                         "picked by autotune.choose() (default) or "
                         "XLA natives")
    ap.add_argument("--tuning", action="store_true",
                    help="consult the measured tuning table "
                         "(populate with `python benchmarks/run.py tune`)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, get_reduced
    from repro.launch.mesh import make_mesh, parallel_config_for
    from repro.models.model import init_params
    from repro.serve.engine import Engine, Request

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if not cfg.is_decoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model")[-len(dims):]
                     if len(dims) <= 2 else ("pod", "data", "model"))
    pc = parallel_config_for(mesh, param_mode="dp", tuning=args.tuning)
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))
    eng = Engine(cfg, pc, mesh, params, batch_slots=args.batch_slots,
                 max_len=args.max_len, prefill_chunk=args.prefill_chunk,
                 block_size=args.block_size,
                 temperature=args.temperature,
                 decode_collectives=args.decode_collectives)
    stream = (lambda r, t: print(f"[serve] req {r.uid} += {t}")) \
        if args.stream else None
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 16)))
                    .astype(np.int32), max_new_tokens=args.max_new,
                    stream=stream)
            for _ in range(args.requests)]
    eng.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"[serve] req {i}: {len(r.prompt)} prompt -> {r.out_tokens}")
    for op, nbytes, choice in eng.decode_choices:
        print(f"[serve] decode {op}: {nbytes}B -> {choice.kind}(r="
              f"{choice.r}) source={choice.source}")


if __name__ == "__main__":
    main()
