"""Production training launcher.

Selects an assigned architecture by ``--arch``, builds the mesh, and runs
the elastic training loop (checkpointing, straggler watch).  On this
container it is exercised with reduced configs / virtual devices; on a
TPU pod slice the same entrypoint runs per host under the usual
`JAX distributed` initialization (see --coordinator).

Examples:
  # reduced config, single host
  PYTHONPATH=src python -m repro.launch.train --arch granite_8b \\
      --reduced --mesh 1x1 --steps 20

  # 8 virtual devices, zero1 layout
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch mixtral_8x7b \\
      --reduced --mesh 4x2 --param-mode zero1 --steps 20

  # production pod (on real hardware)
  python -m repro.launch.train --arch command_r_plus_104b \\
      --mesh 16x16 --param-mode fsdp --seq 4096 --global-batch 256 \\
      --coordinator $COORD:8476 --num-processes 64 --process-id $ID
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test sized config")
    ap.add_argument("--mesh", default="1x1", help="DPxTP or PODxDPxTP")
    ap.add_argument("--param-mode", default="fsdp",
                    choices=["dp", "zero1", "fsdp"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-r", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    # multi-host bring-up (real clusters)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()

    import jax
    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    from repro.configs import get_config, get_reduced
    from repro.data.pipeline import DataConfig
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    from repro.train.optimizer import OptConfig

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps)
    ec = ElasticConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       param_mode=args.param_mode, grad_r=args.grad_r)
    dc = DataConfig(seq_len=args.seq, global_batch=args.global_batch)
    runner = ElasticRunner(cfg, oc, ec, dc, dims, axes=axes)
    n = sum(x.size for x in jax.tree.leaves(runner.params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, mesh={args.mesh}, "
          f"mode={args.param_mode}")
    logs = runner.run(args.steps)
    print(f"[train] done: loss {logs[0]['loss']:.4f} -> "
          f"{logs[-1]['loss']:.4f} over {args.steps} steps")
    runner.ckpt.wait()


if __name__ == "__main__":
    main()
