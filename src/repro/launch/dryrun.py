import os
# 512 placeholder devices for the production meshes; LICM disabled because
# XLA:CPU otherwise hoists a fp32 convert of entire residual stacks out of
# the backward loop, inflating reported temp memory 2x (CPU-only artifact;
# the TPU backend keeps the stacks bf16).
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell this lowers and
compiles the real step function (train_step or serve_step) against
ShapeDtypeStruct stand-ins on the production mesh -- (16, 16) single-pod
and (2, 16, 16) multi-pod -- then records:

  * ``compiled.memory_analysis()``  -- proves the cell fits per-device HBM
  * ``compiled.cost_analysis()``    -- HLO FLOPs / bytes for the roofline
  * collective ops parsed from the compiled HLO text (type, tensor bytes,
    and whether they sit inside the layer-scan loop body, whose trip
    count multiplies their traffic)

Results land in results/dryrun/<cell>.json; benchmarks/roofline.py turns
them into the EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --arch granite_8b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
"""
import argparse
import json
import re
import sys
import time
import traceback


def _cell_plan(arch: str, shape_name: str):
    """Static description of what to lower for a cell (incl. skip rules)."""
    from repro.configs import get_config
    from repro.models.config import SHAPES, shape_applicable
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    return cfg, shape, ok, why


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun", *,
             param_mode: str = "fsdp", collective_impl: str = "xla",
             attn_impl: str = "chunked", tag: str = "",
             mesh_shape=None, microbatches: int = 1,
             no_remat: bool = False, cache_seq_shard: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_production_mesh, parallel_config_for
    from repro.models.config import SHAPES
    from repro.models.model import init_caches
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.step import (input_shapes, make_serve_step,
                                  make_train_step)

    cfg, shape, ok, why = _cell_plan(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    rec = {"cell": cell, "arch": arch, "shape": shape_name,
           "mesh": mesh_name, "param_mode": param_mode,
           "collective_impl": collective_impl, "status": "skipped",
           "skip_reason": why}
    os.makedirs(out_dir, exist_ok=True)
    if not ok:
        _dump(out_dir, cell, rec)
        print(f"[dryrun] SKIP {cell}: {why}")
        return rec

    t0 = time.perf_counter()
    if mesh_shape is not None:
        from repro.launch.mesh import make_mesh
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, names)
        cell = f"{arch}__{shape_name}__{mesh_shape}" + (
            f"__{tag}" if tag else "")
        rec["cell"] = rec["mesh"] = mesh_shape
        rec["cell"] = cell
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    pc = parallel_config_for(mesh, param_mode=param_mode,
                             collective_impl=collective_impl)
    if no_remat:
        from dataclasses import replace as _rp
        pc = _rp(pc, remat=False)
    try:
        if shape.kind == "train" or (shape.kind == "prefill"
                                     and not cfg.is_decoder):
            if shape.kind == "train":
                seq, gb = shape.seq_len, shape.global_batch
            else:                      # encoder "prefill" = full encode
                seq, gb = shape.seq_len, shape.global_batch
            if gb % pc.dp:
                raise ValueError(f"global batch {gb} % dp {pc.dp}")
            bundle = make_train_step(
                cfg, pc, mesh,
                OptConfig(warmup_steps=10, total_steps=1000),
                attn_impl=attn_impl, donate=False,
                microbatches=microbatches)
            batch = input_shapes(cfg, shape_kind="train", seq_len=seq,
                                 global_batch=gb)
            lowered = bundle.train_step.lower(
                bundle.params_shapes, bundle.opt_shapes, batch)
        else:
            # decode / prefill: serve_step against (rolling) caches
            gb = shape.global_batch
            shard_batch = gb % pc.dp == 0
            rolling = (shape.name == "long_500k"
                       and cfg.window is not None)
            spc = pc if shard_batch else _replace_dp1(pc)
            bundle = make_serve_step(cfg, spc, mesh, rolling=rolling,
                                     seq_shard=cache_seq_shard,
                                     attn_impl=attn_impl)
            s_new = 1 if shape.kind == "decode" else shape.seq_len
            cache_len = shape.seq_len
            caches = jax.eval_shape(
                lambda: init_caches(cfg, spc, gb, cache_len,
                                    rolling=rolling,
                                    seq_shard=cache_seq_shard))
            toks = jax.ShapeDtypeStruct((gb, s_new), jnp.int32)
            pos0 = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = bundle.serve_step.lower(
                bundle.params_shapes, toks, caches, pos0)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1))
        rec["memory"] = _memory(compiled)
        rec["cost"] = _cost(compiled)
        rec["collectives"] = _collectives(compiled)
        n_cyc = cfg.n_cycles
        rec["n_scan_trips"] = n_cyc
        print(f"[dryrun] OK   {cell}  lower={t_lower:.0f}s "
              f"compile={t_compile:.0f}s "
              f"mem/dev={rec['memory'].get('argument_size_gb', '?')}+"
              f"{rec['memory'].get('temp_size_gb', '?')}GB "
              f"flops={rec['cost'].get('flops', 0):.3g}")
    except Exception as e:  # noqa: BLE001 -- record the failure verbatim
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] FAIL {cell}: {type(e).__name__}: {e}")
    _dump(out_dir, cell, rec)
    return rec


def _replace_dp1(pc):
    """long_500k (global batch 1): batch replicated over the data axes."""
    from dataclasses import replace
    return replace(pc, dp=1, dp_axes=("data",))


def _memory(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        if "argument_size_in_bytes" in out:
            out["argument_size_gb"] = round(
                out["argument_size_in_bytes"] / 2**30, 2)
        if "temp_size_in_bytes" in out:
            out["temp_size_gb"] = round(out["temp_size_in_bytes"] / 2**30, 2)
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


def _cost(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in (ca or {}).items():
            if k in ("flops", "bytes accessed", "transcendentals",
                     "optimal_seconds") or k.startswith("bytes accessed"):
                out[k] = float(v)
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "f64": 8, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def _collectives(compiled) -> dict:
    """Parse collective ops from the compiled HLO text.

    Ops inside ``while`` loop bodies (the layer scan) are tagged so the
    roofline can multiply them by the scan trip count.  Detection: HLO
    prints each computation as a block ``body.N { ... }`` referenced by a
    while op -- we mark ops whose enclosing computation name contains
    "body" or "scan".
    """
    out = {"ops": [], "error": None}
    try:
        txt = compiled.as_text()
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
        return out
    current_comp = ""
    for line in txt.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("{" in stripped):
            head = stripped.split("{")[0].strip().rstrip(" (")
            if head and not head.startswith(("ROOT", "%")):
                current_comp = head.split()[0] if head.split() else ""
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if "-done(" in line:
            continue  # counted at -start
        nelem = 1
        if dims:
            for d in dims.split(","):
                if d:
                    nelem *= int(d)
        nbytes = nelem * _DTYPE_BYTES.get(dtype, 4)
        in_loop = ("body" in current_comp.lower()
                   or "scan" in current_comp.lower()
                   or "while" in current_comp.lower())
        out["ops"].append({"kind": kind, "bytes": nbytes,
                           "dtype": dtype, "in_loop": bool(in_loop)})
    # aggregate
    agg = {}
    for op in out["ops"]:
        key = (op["kind"], op["in_loop"])
        agg.setdefault(key, [0, 0])
        agg[key][0] += 1
        agg[key][1] += op["bytes"]
    out["summary"] = [
        {"kind": k, "in_loop": il, "count": c, "bytes": b}
        for (k, il), (c, b) in sorted(agg.items())]
    del out["ops"]  # keep the json small
    return out


def _dump(out_dir, cell, rec):
    with open(os.path.join(out_dir, f"{cell}.json"), "w") as f:
        json.dump(rec, f, indent=1)


ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=ALL_SHAPES + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--param-mode", default="fsdp")
    ap.add_argument("--collective-impl", default="xla",
                    choices=["xla", "group"])
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 64x4 (data x model)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--cache-seq-shard", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else ALL_SHAPES
    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.singlepod_only and args.mesh_shape is None:
        meshes.append(True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               param_mode=args.param_mode,
                               collective_impl=args.collective_impl,
                               attn_impl=args.attn_impl, tag=args.tag,
                               mesh_shape=args.mesh_shape,
                               microbatches=args.microbatches,
                               no_remat=args.no_remat,
                               cache_seq_shard=args.cache_seq_shard)
                if rec["status"] == "error":
                    n_fail += 1
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
