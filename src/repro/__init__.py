"""repro: production JAX framework around the generalized Allreduce
(Kolmakov & Zhang, 2020)."""
__version__ = "1.0.0"
