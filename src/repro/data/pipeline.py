"""Deterministic synthetic data pipeline.

Production layout: every *host* materializes only its shard of the global
batch (``host_slice``), keyed by (seed, step) so any host can regenerate
any step -- which is what makes checkpoint-restart and elastic re-sharding
exact: after a restart with a different dp size, step ``k`` still yields
the same global batch, just cut differently.

A background prefetch thread keeps ``prefetch`` batches ahead of the
training loop (the CPU-side analog of an input pipeline overlapping the
device step).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    # fraction of ignored (padding) labels, to exercise masked-CE paths
    pad_fraction: float = 0.0


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_batch(cfg: ModelConfig, dc: DataConfig, step: int,
                host_slice: Optional[Tuple[int, int]] = None) -> Dict:
    """Generate the (host slice of the) global batch for ``step``.

    LM batches model a next-token corpus: labels are the inputs shifted
    left.  Audio batches are frame embeddings + frame labels; vision
    batches are patch embeddings + text tokens.
    """
    rng = _rng_for(dc.seed, step)
    B, S = dc.global_batch, dc.seq_len
    lo, hi = host_slice if host_slice is not None else (0, B)

    if cfg.frontend == "audio":
        emb = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
        lab = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        return {"embeds": emb[lo:hi], "labels": lab[lo:hi]}

    if cfg.frontend == "vision":
        s_text = max(S - cfg.n_patches, 8)
        pe = rng.standard_normal((B, cfg.n_patches, cfg.d_model)) \
            .astype(np.float32)
        toks = rng.integers(0, cfg.vocab, (B, s_text + 1)).astype(np.int32)
        return {"tokens": toks[lo:hi, :-1],
                "patch_embeds": pe[lo:hi],
                "labels": toks[lo:hi, 1:].copy()}

    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    labels = toks[:, 1:].copy()
    if dc.pad_fraction > 0:
        mask = rng.random((B, S)) < dc.pad_fraction
        labels[mask] = -1
    return {"tokens": toks[lo:hi, :-1], "labels": labels[lo:hi]}


class DataLoader:
    """Prefetching iterator over synth_batch steps."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig, *,
                 start_step: int = 0,
                 host_slice: Optional[Tuple[int, int]] = None,
                 prefetch: int = 2):
        self.cfg, self.dc = cfg, dc
        self.step = start_step
        self.host_slice = host_slice
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.dc, s, self.host_slice)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        s, batch = self._q.get()
        self.step = s + 1
        return s, batch

    def close(self):
        self._stop.set()
