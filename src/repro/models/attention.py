"""GQA/MQA/SWA attention with Megatron-style TP sharding + KV caches.

Sharding: query heads are sharded over the TP axis.  KV projections are
sharded over KV heads when n_kv_heads >= tp; otherwise (GQA groups wider
than one device, or MQA) the KV projection is *replicated* and each device
dynamically slices the KV head(s) its query heads attend to.  Replicated
KV grads are exact under a TP psum because each device's grad carries only
its own query heads' contribution (disjoint slices of the true gradient).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops
from repro.models.layers import COMPUTE_DTYPE, dense, rope
from repro.parallel.api import ParallelConfig, tp_rank


class KVCache(NamedTuple):
    """KV cache; ``rolling`` (ring-buffer mode) is passed statically to the
    apply functions rather than stored, so the cache stacks cleanly as a
    scan-able pytree."""

    k: jnp.ndarray          # (B, Hkv_local, S_max_or_window, hd)
    v: jnp.ndarray
    pos: jnp.ndarray        # scalar int32: tokens already in cache


class PagedKV(NamedTuple):
    """Blockwise (paged) KV cache: one shared physical pool per layer.

    ``k``/``v`` are ``(n_blocks, Hkv_local, block_size, hd)`` pools.
    Which physical block backs logical block ``j`` of batch slot ``b``
    lives OUTSIDE the cache, in the per-step :class:`PageCtx` block
    table (host-managed by :class:`repro.serve.kv.KVBlockManager`).
    Physical block 0 is the *garbage block*: unallocated table entries
    and padding-token writes land there and are never read back (per-row
    ``kv_valid`` masks everything past each slot's written length).

    No ``pos`` scalar: continuous batching needs per-row positions,
    which the engine tracks host-side and passes via ``PageCtx``.
    """

    k: jnp.ndarray
    v: jnp.ndarray


class PageCtx(NamedTuple):
    """Per-step paged-decode context (all leaves are arrays, so the ctx
    crosses ``shard_map`` as an ordinary pytree).

    block_table: (B, nb_max) int32 -- physical block of each logical
                 block per slot (0 = garbage block for unallocated).
    lengths:     (B,) int32 -- tokens already in each slot's cache.
    n_new:       (B,) int32 -- valid new tokens this step per slot
                 (0 = row inactive this tick; tokens past ``n_new`` are
                 right-padding whose cache writes are dropped).
    reset:       (B,) bool -- slots freshly admitted this step whose
                 recurrent state must restart from the initial state.
    """

    block_table: jnp.ndarray
    lengths: jnp.ndarray
    n_new: jnp.ndarray
    reset: jnp.ndarray


def attn_replicated(cfg, pc: ParallelConfig) -> bool:
    """True when the query-head count does not divide TP (e.g.
    recurrentgemma's 10 heads on a 16-way model axis).  Attention then
    computes all heads on every TP device and the block boundary *slices*
    the sequence-parallel shard instead of reducing -- the same rule as
    sLSTM.  Wasteful but exact; the natural production mesh for such small
    models is DP-dominant anyway (documented in DESIGN.md)."""
    return pc.tp > 1 and cfg.n_heads % pc.tp != 0


def local_kv_heads(cfg, pc: ParallelConfig) -> int:
    if attn_replicated(cfg, pc):
        return cfg.n_kv_heads
    return max(cfg.n_kv_heads // pc.tp, 1)


def local_q_heads(cfg, pc: ParallelConfig) -> int:
    if attn_replicated(cfg, pc):
        return cfg.n_heads
    assert cfg.n_heads % pc.tp == 0, (cfg.name, cfg.n_heads, pc.tp)
    return cfg.n_heads // pc.tp


def kv_replicated(cfg, pc: ParallelConfig) -> bool:
    return cfg.n_kv_heads < pc.tp and not attn_replicated(cfg, pc)


def _slice_kv(kv, cfg, pc: ParallelConfig):
    """From a replicated (B, S, Hkv*hd) projection, slice the single KV
    head this device's query heads map to."""
    hd = cfg.hd
    B, S = kv.shape[:2]
    kv = kv.reshape(B, S, cfg.n_kv_heads, hd)
    dev_per_kv = pc.tp // cfg.n_kv_heads
    h = tp_rank(pc) // dev_per_kv
    kv = lax.dynamic_slice_in_dim(kv, h, 1, axis=2)
    return kv  # (B, S, 1, hd)


def qkv_project(p, xg, cfg, pc: ParallelConfig):
    """xg (B, S, d) full-seq -> q (B, Hl, S, hd), k/v (B, Hkv_l, S, hd)."""
    B, S, _ = xg.shape
    hd = cfg.hd
    hl = local_q_heads(cfg, pc)
    q = dense(xg, p["wq"]).reshape(B, S, hl, hd).swapaxes(1, 2)
    k = dense(xg, p["wk"])
    v = dense(xg, p["wv"])
    if kv_replicated(cfg, pc) and pc.tp > 1:
        k = _slice_kv(k, cfg, pc).swapaxes(1, 2)
        v = _slice_kv(v, cfg, pc).swapaxes(1, 2)
    else:
        hkl = local_kv_heads(cfg, pc)
        k = k.reshape(B, S, hkl, hd).swapaxes(1, 2)
        v = v.reshape(B, S, hkl, hd).swapaxes(1, 2)
    return q, k, v


def attention_block(p, xg, cfg, pc: ParallelConfig, *,
                    window: Optional[int], positions: jnp.ndarray,
                    cache: Optional[KVCache] = None,
                    rolling: bool = False, seq_shard: bool = False,
                    paged: Optional[PageCtx] = None,
                    attn_impl: str = "xla"
                    ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Temporal mixing via attention.

    xg: (B, S, d) gathered full sequence (S=1 for decode).
    Returns (B, S, d) **partial over TP** output (caller reduces), and the
    updated cache (decode path).
    """
    B, S, _ = xg.shape
    if isinstance(cache, PagedKV):
        assert paged is not None, "PagedKV caches need a PageCtx"
        q, k, v = qkv_project(p, xg, cfg, pc)
        q, k = rope(q, k, positions, theta=cfg.rope_theta)   # (B, S) pos
        cache = paged_cache_update(cache, k, v, paged)
        k_view, v_view = paged_view(cache, paged.block_table)
        o = kops.attention(
            q, k_view, v_view,
            causal=cfg.causal,
            window=window,
            kv_valid=paged.lengths + paged.n_new,            # per row
            q_positions=positions,                           # (B, S)
            impl=attn_impl)
        o = o.swapaxes(1, 2).reshape(B, S, -1)
        out = jax.lax.dot_general(
            o, p["wo"].astype(o.dtype), (((2,), (0,)), ((), ())),
            preferred_element_type=o.dtype)
        return out, cache
    if cache is not None and seq_shard:
        o_full, cache = seq_shard_decode(p, xg, cfg, pc,
                                         positions=positions, cache=cache,
                                         attn_impl=attn_impl)
        # slice this device's query heads for the sharded out-projection
        span = local_q_heads(cfg, pc) * cfg.hd
        o = lax.dynamic_slice_in_dim(o_full, tp_rank(pc) * span, span, 2)
        out = jax.lax.dot_general(
            o, p["wo"].astype(o.dtype), (((2,), (0,)), ((), ())),
            preferred_element_type=o.dtype)
        return out, cache
    q, k, v = qkv_project(p, xg, cfg, pc)
    q, k = rope(q, k, positions, theta=cfg.rope_theta)

    if cache is None:
        o = kops.attention(q, k, v, causal=cfg.causal, window=window,
                           impl=attn_impl)
    else:
        if rolling:
            assert S == 1, "rolling (windowed) caches support decode only"
        k, v, cache, kv_valid = _cache_update(cache, k, v, window,
                                              rolling=rolling)
        o = kops.attention(
            q, k, v,
            # prefill into a cache still needs causality among new tokens
            causal=cfg.causal and S > 1,
            # rolling buffers hold only in-window keys by construction
            window=None if rolling else window,
            kv_valid=kv_valid,
            q_positions=None if rolling else positions.reshape(-1),
            impl=attn_impl)
    o = o.swapaxes(1, 2).reshape(B, S, -1)           # (B, S, Hl*hd)
    out = jax.lax.dot_general(
        o, p["wo"].astype(o.dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=o.dtype)
    return out, cache


def _cache_update(cache: KVCache, k_new, v_new, window, *, rolling: bool):
    """Insert the new token(s) into the cache; return full K/V to attend
    over plus the traced valid length."""
    B, H, S_new, hd = k_new.shape
    if rolling:
        W = cache.k.shape[2]
        slot = cache.pos % W
        k = lax.dynamic_update_slice(cache.k, k_new, (0, 0, slot, 0))
        v = lax.dynamic_update_slice(cache.v, v_new, (0, 0, slot, 0))
        new = KVCache(k, v, cache.pos + S_new)
        valid = jnp.minimum(cache.pos + S_new, W)
        return k, v, new, valid
    k = lax.dynamic_update_slice(cache.k, k_new, (0, 0, cache.pos, 0))
    v = lax.dynamic_update_slice(cache.v, v_new, (0, 0, cache.pos, 0))
    new = KVCache(k, v, cache.pos + S_new)
    return k, v, new, cache.pos + S_new


def _pool_heads(cfg, pc: ParallelConfig) -> int:
    """KV-head count of one device's cache pool (same rule as the dense
    :func:`init_cache` without seq-sharding)."""
    if attn_replicated(cfg, pc):
        return cfg.n_kv_heads
    if kv_replicated(cfg, pc) and pc.tp > 1:
        return 1
    return local_kv_heads(cfg, pc)


def init_paged_pool(cfg, pc: ParallelConfig, n_blocks: int,
                    block_size: int, dtype=COMPUTE_DTYPE) -> PagedKV:
    """One layer's physical KV block pool (block 0 = garbage block)."""
    shape = (n_blocks, _pool_heads(cfg, pc), block_size, cfg.hd)
    return PagedKV(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def paged_cache_update(cache: PagedKV, k_new, v_new, ctx: PageCtx):
    """Scatter the new token(s) of every slot into the shared pool.

    Token ``t`` of row ``b`` lands at logical position ``lengths[b] +
    t``, i.e. physical ``(block_table[b, pos // bs], :, pos % bs)``.
    Padding tokens (``t >= n_new[b]``) are routed to an out-of-range
    block index and dropped by the scatter -- they neither advance any
    slot nor scribble on another slot's blocks.
    """
    B, H, S, hd = k_new.shape
    nb, _, bs, _ = cache.k.shape
    pos = ctx.lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    valid = jnp.arange(S)[None, :] < ctx.n_new[:, None]          # (B, S)
    logical = jnp.clip(pos // bs, 0, ctx.block_table.shape[1] - 1)
    blk = jnp.take_along_axis(ctx.block_table, logical, axis=1)  # (B, S)
    blk = jnp.where(valid, blk, nb)          # OOB sentinel: dropped
    off = pos % bs
    kk = jnp.swapaxes(k_new, 1, 2).astype(cache.k.dtype)   # (B, S, H, hd)
    vv = jnp.swapaxes(v_new, 1, 2).astype(cache.v.dtype)
    k = cache.k.at[blk, :, off].set(kk, mode="drop")
    v = cache.v.at[blk, :, off].set(vv, mode="drop")
    return PagedKV(k, v)


def paged_view(cache: PagedKV, block_table):
    """Gather each slot's logical cache view from the pool.

    Returns ``(B, H, nb_max * bs, hd)`` K/V where row ``b``'s sequence
    axis is its own logical positions (garbage past ``kv_valid``).
    """
    B, nbm = block_table.shape
    _, H, bs, hd = cache.k.shape
    kv = []
    for pool in (cache.k, cache.v):
        view = pool[block_table]                  # (B, nbm, H, bs, hd)
        view = jnp.moveaxis(view, 2, 1).reshape(B, H, nbm * bs, hd)
        kv.append(view)
    return kv[0], kv[1]


def init_cache(cfg, pc: ParallelConfig, batch_local: int, max_len: int,
               *, rolling_window: Optional[int] = None,
               seq_shard: bool = False, dtype=COMPUTE_DTYPE) -> KVCache:
    if attn_replicated(cfg, pc):
        H = cfg.n_kv_heads
    elif kv_replicated(cfg, pc) and pc.tp > 1:
        H = 1 if not seq_shard else cfg.n_kv_heads
    else:
        H = local_kv_heads(cfg, pc)
    L = rolling_window if rolling_window else max_len
    if seq_shard:
        assert pc.tp > 1 and rolling_window is None
        assert L % pc.tp == 0
        # KV heads stay whole (replicated-KV archs); the SEQUENCE dim of
        # the (GLOBAL) cache shards over TP via the in_specs -- inside
        # shard_map each device sees its L/tp slice (flash-decoding).
        H = cfg.n_kv_heads
    shape = (batch_local, H, L, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.int32(0))


def seq_shard_decode(p, xg, cfg, pc: ParallelConfig, *,
                     positions, cache: KVCache, attn_impl: str = "xla"):
    """Decode attention against a TP-sequence-sharded KV cache.

    Motivation: MQA/low-kv-head archs cannot shard the cache over heads,
    so a 32k x batch-128 cache replicates ~11 GB per device.  Here device
    r owns cache slots [r*Ls, (r+1)*Ls); each device scores *all* query
    heads (q gathered over TP -- trivial at S_new=1) against its slice and
    the partial outputs merge with a log-sum-exp-weighted psum
    (flash-decoding across the model axis).  Cache memory drops by tp.

    Returns ((B, 1, Hq*hd) full-head attention output replicated over TP,
    new cache).  The caller slices its local heads for the out-projection.
    """
    from jax import lax as _lax
    B, S, _ = xg.shape
    assert S == 1, "seq-sharded caches are a decode-path feature"
    hd = cfg.hd
    hl = local_q_heads(cfg, pc)
    q = dense(xg, p["wq"]).reshape(B, S, hl, hd).swapaxes(1, 2)
    # KV projections are replicated for these archs: keep ALL kv heads
    k_new = dense(xg, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd) \
        .swapaxes(1, 2)
    v_new = dense(xg, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd) \
        .swapaxes(1, 2)
    # gather all query heads (tiny at S_new=1)
    if pc.tp > 1:
        q = _lax.all_gather(q, pc.tp_axis, axis=1, tiled=True)
    q, k_new = rope(q, k_new, positions, theta=cfg.rope_theta)

    Ls = cache.k.shape[2]
    r = tp_rank(pc)
    pos = cache.pos
    local_slot = pos - r * Ls
    owner = (local_slot >= 0) & (local_slot < Ls)
    ins = jnp.clip(local_slot, 0, Ls - 1)
    k_upd = _lax.dynamic_update_slice(cache.k, k_new, (0, 0, ins, 0))
    v_upd = _lax.dynamic_update_slice(cache.v, v_new, (0, 0, ins, 0))
    k_c = jnp.where(owner, k_upd, cache.k)
    v_c = jnp.where(owner, v_upd, cache.v)
    new_cache = KVCache(k_c, v_c, pos + 1)

    valid_local = jnp.clip(pos + 1 - r * Ls, 0, Ls)
    o, lse = kops.attention(q, k_c, v_c, causal=False, window=None,
                            kv_valid=valid_local, impl=attn_impl,
                            return_lse=True)
    # LSE merge across the TP slices
    m = _lax.pmax(lse, pc.tp_axis)                        # (B, Hq, 1)
    w = jnp.exp(lse - jnp.where(jnp.isfinite(m), m, 0.0))
    w = jnp.where(jnp.isfinite(lse), w, 0.0)
    num = _lax.psum(o.astype(jnp.float32) * w[..., None], pc.tp_axis)
    den = _lax.psum(w, pc.tp_axis)
    o = (num / jnp.maximum(den, 1e-30)[..., None]).astype(xg.dtype)
    o = o.swapaxes(1, 2).reshape(B, S, -1)                # (B, 1, Hq*hd)
    return o, new_cache
