"""Shared layers: norms, embeddings, MLPs, vocab-parallel cross-entropy.

Everything here runs *inside* shard_map (manual SPMD).  Parameter arrays
are the device-local shards; the companion ``ParamSpec`` tree (built in
:mod:`repro.models.model`) records which global dim each shard came from.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops
from repro.parallel.api import (ParallelConfig, seq_all_gather,
                                seq_reduce_scatter, tp_psum, tp_rank)

COMPUTE_DTYPE = jnp.bfloat16


def norm_apply(p, x, *, kind: str = "rmsnorm", eps: float = 1e-5,
               impl: str = "xla"):
    if kind == "rmsnorm":
        return kops.norm(x, p["w"], eps=eps, impl=impl)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * p["w"] + p["b"]).astype(x.dtype)


def dense(x, w):
    """Local matmul in compute dtype.

    Output stays in the compute dtype (bf16): the MXU accumulates fp32
    internally for bf16 operands, and a fp32 output tensor would double
    both the live-buffer footprint and the bytes of any TP partial-sum
    reduce that follows."""
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype)


# ---------------------------------------------------------------------------
#  vocab-parallel embedding
# ---------------------------------------------------------------------------

def embed_tokens(p, tokens, cfg, pc: ParallelConfig, *, sp: bool):
    """tokens (B, S) replicated -> activations.

    The embedding table is sharded over the vocab dim on the TP axis;
    each device embeds only tokens inside its shard, then the partial
    activations are summed and (with SP) scattered over the sequence.
    Output: (B, S/tp, d) if sp else (B, S, d).
    """
    # cast the (V/tp, d) table once; gathering from the fp32 master would
    # materialize a fp32 (B, S, d) tensor
    table = p["w"].astype(COMPUTE_DTYPE)             # (V/tp, d) local
    vshard = table.shape[0]
    if vshard == cfg.vocab:
        # replicated table (vocab % tp != 0): full values, slice for SP
        out = jnp.take(table, tokens, axis=0)        # (B, S, d)
        if sp and pc.tp > 1:
            n = out.shape[1] // pc.tp
            out = lax.dynamic_slice_in_dim(out, tp_rank(pc) * n, n, 1)
        return out
    r = tp_rank(pc)
    lo = r * vshard
    idx = tokens - lo
    inside = (idx >= 0) & (idx < vshard)
    idx = jnp.clip(idx, 0, vshard - 1)
    out = jnp.take(table, idx, axis=0)               # (B, S, d) bf16
    out = jnp.where(inside[..., None], out, jnp.zeros((), COMPUTE_DTYPE))
    if pc.tp == 1:
        return out
    if sp:
        return seq_reduce_scatter(out, pc, axis=1)
    return tp_psum(out, pc)


def lm_head_logits(p, x, cfg, pc: ParallelConfig):
    """x (B, S, d) full-seq -> vocab-shard logits (B, S, V/tp) in fp32."""
    return jax.lax.dot_general(
        x, p["w"].astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def vocab_parallel_ce(p_head, x, labels, cfg, pc: ParallelConfig, *,
                      chunk: int = 512, sp: bool = False):
    """Chunked vocab-parallel cross entropy.

    x       (B, S, d) full sequence -- or, with ``sp=True``, the
            sequence-parallel shard (B, S/tp, d): each chunk is then
            all-gathered over TP *inside* the loop, so the full (B, S, d)
            hidden state never materializes (saves ~1.6 GB/device on the
            104B config) and the gather overlaps the head matmuls.
    labels  (B, S) int32 (always global); -1 = ignore
    Returns (sum_loss, n_valid) -- psum over DP by the caller for a
    global mean.

    Never materializes (B, S, V): only a (B, chunk, V/tp) logits shard
    exists per step; max/logsumexp/label-pick reduce over TP with psums.
    """
    B = x.shape[0]
    d = x.shape[-1]
    S = labels.shape[1]
    vshard = p_head["w"].shape[1]
    if vshard == cfg.vocab and pc.tp > 1 and sp:
        # replicated head (vocab % tp != 0): partition over the SEQUENCE
        # instead -- each device scores its own seq shard against the full
        # vocab, partial sums reduce over TP (grads of the replicated head
        # stay exact under the TP psum).
        r_ = tp_rank(pc)
        s_local = x.shape[1]
        lab = lax.dynamic_slice_in_dim(
            labels.reshape(B, pc.tp, s_local), r_, 1, 1)[:, 0]
        total, count = vocab_parallel_ce(
            p_head, x, lab, cfg,
            ParallelConfig(dp_axes=pc.dp_axes, dp=pc.dp, tp=1),
            chunk=chunk, sp=False)
        total = lax.psum(total, pc.tp_axis)
        count = lax.psum(count, pc.tp_axis)
        return total, count
    r = tp_rank(pc)
    lo = r * vshard
    if sp and pc.tp > 1:
        s_local = x.shape[1]
        lchunk = max(chunk // pc.tp, 1)
        n_chunks = -(-s_local // lchunk)
        pad = n_chunks * lchunk - s_local
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        xs = x.reshape(B, n_chunks, lchunk, d).swapaxes(0, 1)
        # global labels arranged device-major to match all_gather order,
        # padded per-device then chunked
        lab = labels.reshape(B, pc.tp, s_local)
        if pad:
            lab = jnp.pad(lab, ((0, 0), (0, 0), (0, pad)),
                          constant_values=-1)
        lab = lab.reshape(B, pc.tp, n_chunks, lchunk)
        ls = lab.transpose(2, 0, 1, 3).reshape(n_chunks, B,
                                               pc.tp * lchunk)
    else:
        n_chunks = -(-S // chunk)
        pad = n_chunks * chunk - S
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)),
                             constant_values=-1)
        xs = x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)   # (C,B,c,d)
        ls = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        xc, lc = inp
        if sp and pc.tp > 1:
            xc = seq_all_gather(xc, pc, axis=1)                # (B, c, d)
        logits = lm_head_logits(p_head, xc, cfg, pc)           # (B, c, V/tp) f32
        # numerical stabilizer: mathematically gradient-free (cancels in
        # lse - picked), so stop_gradient keeps pmax out of the VJP.
        m = jnp.max(lax.stop_gradient(logits), axis=-1)
        if pc.tp > 1:
            m = lax.pmax(m, pc.tp_axis)
        m = lax.stop_gradient(m)
        z = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        z = tp_psum(z, pc)
        lse = m + jnp.log(z)
        li = lc - lo
        inside = (li >= 0) & (li < vshard)
        li = jnp.clip(li, 0, vshard - 1)
        picked = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        picked = tp_psum(jnp.where(inside, picked, 0.0), pc)
        valid = lc >= 0
        loss = jnp.where(valid, lse - picked, 0.0)
        s, n = carry
        return (s + jnp.sum(loss), n + jnp.sum(valid)), None

    # remat each chunk: the backward recomputes the (B, chunk, V/tp)
    # logits tile instead of stacking one per chunk (saves ~4 GB on the
    # 256k-vocab configs)
    body = jax.checkpoint(body, prevent_cse=False)
    (total, count), _ = lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                 (xs, ls))
    return total, count


# ---------------------------------------------------------------------------
#  MLPs
# ---------------------------------------------------------------------------

def mlp_apply(p, x, cfg, pc: ParallelConfig, *, act: Optional[str] = None):
    """Gated/plain MLP with d_ff sharded over TP.

    x (B, S, d) full-seq; returns (B, S, d) *partial* sums over TP --
    the caller reduce-scatters / psums at the block boundary.
    """
    act = act or cfg.act
    if act in ("swiglu", "geglu"):
        g = dense(x, p["w1"])
        u = dense(x, p["w3"])
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    else:
        h = jax.nn.gelu(dense(x, p["w1"]))
    return jax.lax.dot_general(
        h, p["w2"].astype(h.dtype), (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=h.dtype)


# ---------------------------------------------------------------------------
#  rotary embeddings
# ---------------------------------------------------------------------------

def rope(q, k, positions, *, theta: float):
    """q,k: (B, H, S, D); positions (S,) or (B, S) absolute indices."""
    D = q.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
        ang = ang[None, None]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, None]                                             # (B,1,S,half)
    # angles in fp32 (large theta), but the applied sin/cos drop to the
    # compute dtype: a bf16*f32 promotion here would send fp32 cotangents
    # back through the QKV projections (3 GB transients on the 104B cfg)
    sin = jnp.sin(ang).astype(q.dtype)
    cos = jnp.cos(ang).astype(q.dtype)

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        return jnp.concatenate([x1 * cos - x2 * sin,
                                x2 * cos + x1 * sin], axis=-1).astype(x.dtype)

    return rot(q), rot(k)
