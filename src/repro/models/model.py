"""Model assembly: parameter init (+ partition specs), forward passes.

The model is one function family usable three ways:

* ``loss_and_metrics``  -- training forward (full seq, SP residuals)
* ``prefill``           -- fill KV caches / recurrent states from a prompt
* ``decode_step``       -- one-token step against the caches

All run inside ``jax.shard_map`` (manual mode).  Layers are grouped into
the config's block *cycle* and scanned with stacked parameters, so compile
time and HLO size are O(cycle) not O(n_layers); remat wraps the cycle
body.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import moe as moe_lib
from repro.models import recurrent as rec
from repro.models.attention import (PageCtx, attention_block,
                                    attn_replicated, init_cache,
                                    init_paged_pool, kv_replicated)
from repro.models.config import ModelConfig
from repro.models.layers import (COMPUTE_DTYPE, embed_tokens, mlp_apply,
                                 norm_apply, vocab_parallel_ce)
from repro.parallel.api import (ParallelConfig, ParamSpec, choose_fsdp_dim,
                                fsdp_gather_tree, seq_all_gather,
                                seq_reduce_scatter, tp_decode_all_gather,
                                tp_decode_psum, tp_psum, tp_rank)

PARAM_DTYPE = jnp.float32      # master copy; cast to bf16 at use


# ===========================================================================
#  parameter initialization (GLOBAL shapes) + partition specs
# ===========================================================================

class _Init:
    """Accumulates (params, specs) trees with matching structure.

    ``abstract=True`` builds ShapeDtypeStruct leaves instead of arrays --
    used by the multi-pod dry-run, which must never allocate."""

    def __init__(self, cfg: ModelConfig, pc: ParallelConfig, rng,
                 abstract: bool = False):
        self.cfg, self.pc = cfg, pc
        self.rng = rng
        self.abstract = abstract

    def take(self):
        if self.abstract:
            return None
        self.rng, r = jax.random.split(self.rng)
        return r

    def _spec(self, shape, tp_dim, stacked):
        return ParamSpec(tp_dim=tp_dim,
                         fsdp_dim=choose_fsdp_dim(shape, self.pc.dp,
                                                  avoid=tp_dim)
                         if self.pc.param_mode == "fsdp" else None,
                         stacked=stacked)

    def w(self, shape, tp_dim=None, scale=None, stacked=False):
        spec = self._spec(shape, tp_dim, stacked)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, PARAM_DTYPE), spec
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = scale if scale is not None else fan_in ** -0.5
        arr = (jax.random.normal(self.take(), shape, PARAM_DTYPE) * scale)
        return arr, spec

    def zeros(self, shape, tp_dim=None, stacked=False):
        spec = self._spec(shape, tp_dim, stacked)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, PARAM_DTYPE), spec
        return jnp.zeros(shape, PARAM_DTYPE), spec

    def ones(self, shape, tp_dim=None, stacked=False):
        arr, spec = self.zeros(shape, tp_dim, stacked)
        if self.abstract:
            return arr, spec
        return arr + 1.0, spec


def _norm_init(ii: _Init):
    cfg = ii.cfg
    p, s = {}, {}
    p["w"], s["w"] = ii.ones((cfg.d_model,))
    if cfg.norm == "layernorm":
        p["b"], s["b"] = ii.zeros((cfg.d_model,))
    return p, s


def _mlp_init(ii: _Init, d_ff: int):
    cfg = ii.cfg
    p, s = {}, {}
    d = cfg.d_model
    if cfg.act in ("swiglu", "geglu"):
        p["w1"], s["w1"] = ii.w((d, d_ff), tp_dim=1)
        p["w3"], s["w3"] = ii.w((d, d_ff), tp_dim=1)
    else:
        p["w1"], s["w1"] = ii.w((d, d_ff), tp_dim=1)
    p["w2"], s["w2"] = ii.w((d_ff, d), tp_dim=0)
    return p, s


def _attn_init(ii: _Init):
    cfg, pc = ii.cfg, ii.pc
    d = cfg.d_model
    p, s = {}, {}
    repl = attn_replicated(cfg, pc)
    p["wq"], s["wq"] = ii.w((d, cfg.q_dim), tp_dim=None if repl else 1)
    kv_tp = None if (repl or kv_replicated(cfg, pc)) else 1
    p["wk"], s["wk"] = ii.w((d, cfg.kv_dim), tp_dim=kv_tp)
    p["wv"], s["wv"] = ii.w((d, cfg.kv_dim), tp_dim=kv_tp)
    p["wo"], s["wo"] = ii.w((cfg.q_dim, d), tp_dim=None if repl else 0,
                            scale=(cfg.q_dim ** -0.5) / math.sqrt(
                                2 * cfg.n_layers))
    return p, s


def _moe_init(ii: _Init):
    cfg = ii.cfg
    m = cfg.moe
    d = cfg.d_model
    p, s = {"router": {}, "experts": {}}, {"router": {}, "experts": {}}
    p["router"]["w"], s["router"]["w"] = ii.w((d, m.n_experts), tp_dim=None)
    E = m.n_experts
    p["experts"]["w1"], s["experts"]["w1"] = ii.w((E, d, m.d_expert), tp_dim=2)
    p["experts"]["w3"], s["experts"]["w3"] = ii.w((E, d, m.d_expert), tp_dim=2)
    p["experts"]["w2"], s["experts"]["w2"] = ii.w((E, m.d_expert, d), tp_dim=1)
    if m.n_shared:
        p["shared"], s["shared"] = _mlp_init(ii, m.d_shared)
    return p, s


def _rglru_init(ii: _Init):
    cfg = ii.cfg
    d = cfg.d_model
    w = cfg.rnn_width or d
    p, s = {}, {}
    for name in ("w_gate", "w_x", "w_rg", "w_ig"):
        p[name], s[name] = ii.w((d, w), tp_dim=1)
    p["conv_w"], s["conv_w"] = ii.w((cfg.conv_width, w), tp_dim=1,
                                    scale=cfg.conv_width ** -0.5)
    p["conv_b"], s["conv_b"] = ii.zeros((w,), tp_dim=0)
    # Lambda init so a = sigma(L)^c spreads over (0.9, 0.999)
    if ii.abstract:
        p["a_log"] = jax.ShapeDtypeStruct((w,), PARAM_DTYPE)
    else:
        lam = jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / rec._C_RGLRU))
        p["a_log"] = lam.astype(PARAM_DTYPE)
    s["a_log"] = ParamSpec(tp_dim=0, fsdp_dim=None)
    p["w_out"], s["w_out"] = ii.w((w, d), tp_dim=0)
    return p, s


def _mlstm_init(ii: _Init):
    cfg = ii.cfg
    d = cfg.d_model
    w = int(d * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    p, s = {}, {}
    p["w_q"], s["w_q"] = ii.w((d, w), tp_dim=None)      # replicated (see DESIGN)
    p["w_k"], s["w_k"] = ii.w((d, w), tp_dim=None)
    p["w_v"], s["w_v"] = ii.w((d, w), tp_dim=1)
    p["w_g"], s["w_g"] = ii.w((d, w), tp_dim=1)
    p["w_i"], s["w_i"] = ii.w((d, H), tp_dim=None, scale=0.02)
    p["w_f"], s["w_f"] = ii.w((d, H), tp_dim=None, scale=0.02)
    p["w_out"], s["w_out"] = ii.w((w, d), tp_dim=0)
    return p, s


def _slstm_init(ii: _Init):
    cfg = ii.cfg
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    p, s = {}, {}
    for name in ("w_z", "w_i", "w_f", "w_o"):
        p[name], s[name] = ii.w((d, d), tp_dim=None)
    for name in ("r_z", "r_i", "r_f", "r_o"):
        p[name], s[name] = ii.w((H, hd, hd), tp_dim=None, scale=hd ** -0.5)
    p["w_out"], s["w_out"] = ii.w((d, d), tp_dim=None)
    return p, s


def _block_has_mlp(cfg: ModelConfig, kind: str) -> bool:
    return kind in ("attn", "local_attn", "rglru") and (
        cfg.d_ff > 0 or cfg.moe is not None)


def _block_init(ii: _Init, kind: str, *, moe_layer: bool, d_ff_dense: int = 0):
    cfg = ii.cfg
    p, s = {}, {}
    p["ln1"], s["ln1"] = _norm_init(ii)
    if kind in ("attn", "local_attn"):
        p["attn"], s["attn"] = _attn_init(ii)
    elif kind == "rglru":
        p["rnn"], s["rnn"] = _rglru_init(ii)
    elif kind == "mlstm":
        p["mix"], s["mix"] = _mlstm_init(ii)
    elif kind == "slstm":
        p["mix"], s["mix"] = _slstm_init(ii)
    else:
        raise ValueError(kind)
    if _block_has_mlp(cfg, kind):
        p["ln2"], s["ln2"] = _norm_init(ii)
        if moe_layer and cfg.moe is not None:
            p["mlp"], s["mlp"] = _moe_init(ii)
        else:
            p["mlp"], s["mlp"] = _mlp_init(ii, d_ff_dense or cfg.d_ff)
    return p, s


def init_params(cfg: ModelConfig, pc: ParallelConfig, rng, *,
                abstract: bool = False) -> Tuple[Dict, Dict]:
    """Build GLOBAL parameters + the matching ParamSpec tree."""
    ii = _Init(cfg, pc, rng, abstract=abstract)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    # vocab-parallel embedding/head only when the vocab divides TP
    # (hubert's 504 classes stay replicated; CE then partitions over the
    # sequence instead -- see vocab_parallel_ce)
    v_tp = cfg.vocab % pc.tp == 0
    p["embed"], s["embed"] = {}, {}
    p["embed"]["w"], s["embed"]["w"] = ii.w(
        (cfg.vocab, cfg.d_model), tp_dim=0 if v_tp else None, scale=1.0)
    if not cfg.tie_embeddings:
        p["head"], s["head"] = {}, {}
        p["head"]["w"], s["head"]["w"] = ii.w(
            (cfg.d_model, cfg.vocab), tp_dim=1 if v_tp else None)
    p["final_norm"], s["final_norm"] = _norm_init(ii)

    # prefix (unscanned) layers -- DeepSeek-MoE's leading dense layer,
    # recurrentgemma's two leading recurrent blocks
    pfx = cfg.prefix_kinds
    p["prefix"], s["prefix"] = [], []
    for i, kind in enumerate(pfx):
        bp, bs = _block_init(
            ii, kind, moe_layer=False,
            d_ff_dense=cfg.moe.d_first_dense if cfg.moe else 0)
        p["prefix"].append(bp)
        s["prefix"].append(bs)

    # scanned cycles; consecutive identical kinds stack into group scans
    n_cyc_layers = cfg.n_layers - len(pfx)
    cyc = cfg.cycle
    assert n_cyc_layers % len(cyc) == 0, (cfg.name, n_cyc_layers, cyc)
    n_cycles = n_cyc_layers // len(cyc)
    groups = cfg.cycle_groups

    def one_block_of(kind):
        def f(r):
            sub = _Init(cfg, pc, r, abstract=abstract)
            return _block_init(sub, kind, moe_layer=True)
        return f

    cyc_p, cyc_s = {}, {}
    for gi, (kind, cnt) in enumerate(groups):
        bf = one_block_of(kind)
        if abstract:
            bp, bs = bf(None)
            stacked = jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct(
                    (n_cycles, cnt) + sd.shape, sd.dtype), bp)
        else:
            rngs = jax.random.split(ii.take(), n_cycles * cnt)
            rngs = rngs.reshape((n_cycles, cnt) + rngs.shape[1:])
            stacked = jax.vmap(jax.vmap(lambda r: bf(r)[0]))(rngs)
            _, bs = bf(ii.take())
        bs = jax.tree.map(
            lambda sp: ParamSpec(
                tp_dim=None if sp.tp_dim is None else sp.tp_dim + 2,
                fsdp_dim=None if sp.fsdp_dim is None else sp.fsdp_dim + 2,
                stacked=2),
            bs)
        cyc_p[f"g{gi}"], cyc_s[f"g{gi}"] = stacked, bs
    p["cycles"], s["cycles"] = cyc_p, cyc_s
    return p, s


def param_shapes(cfg: ModelConfig, pc: ParallelConfig):
    """ShapeDtypeStruct tree (no allocation) + specs -- for the dry-run."""
    return init_params(cfg, pc, None, abstract=True)


# ===========================================================================
#  forward
# ===========================================================================

def _shard_slice(x, pc: ParallelConfig, axis: int = 1):
    """Take this TP rank's sequence shard of a replicated full value."""
    if pc.tp == 1:
        return x
    n = x.shape[axis] // pc.tp
    return lax.dynamic_slice_in_dim(x, tp_rank(pc) * n, n, axis)


def _row_mask(mask, ndim):
    """(B,) bool -> (B, 1, ..., 1) broadcastable over an ndim array."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def _fresh_state(kind: str, cfg: ModelConfig, pc: ParallelConfig, B: int):
    if kind == "rglru":
        return rec.init_rglru_state(cfg, pc, B)
    if kind == "mlstm":
        return rec.init_mlstm_state(cfg, pc, B)
    if kind == "slstm":
        return rec.init_slstm_state(cfg, pc, B)
    raise ValueError(kind)


def block_apply(kind: str, p, x, cfg: ModelConfig, pc: ParallelConfig, *,
                sp: bool, positions, cache=None, rolling: bool = False,
                seq_shard: bool = False, paged=None,
                moe_layer: bool, attn_impl: str = "xla"):
    """One residual block.  x: (B, S/tp, d) if sp else (B, S, d)."""
    aux = jnp.float32(0.0)
    h = norm_apply(p["ln1"], x, kind=cfg.norm, eps=cfg.norm_eps)
    hg = seq_all_gather(h, pc) if sp else h

    window = cfg.window if (kind == "local_attn" or cfg.window) else None
    new_cache = cache
    recurrent = kind in ("rglru", "mlstm", "slstm")
    if paged is not None and recurrent and cache is not None:
        # continuous batching: a freshly admitted slot restarts its
        # recurrent state; a row with no valid tokens this tick must
        # keep its state frozen (its input is padding).  Rows with
        # 0 < n_new < S are the engine's responsibility to avoid for
        # recurrent archs (aligned chunking -- see serve/engine.py).
        B = hg.shape[0]
        fresh = _fresh_state(kind, cfg, pc, B)
        cache = jax.tree.map(
            lambda old, f: jnp.where(_row_mask(paged.reset, old.ndim),
                                     f, old), cache, fresh)
    if kind in ("attn", "local_attn"):
        mix, new_cache = attention_block(
            p["attn"], hg, cfg, pc, window=window, positions=positions,
            cache=cache, rolling=rolling, seq_shard=seq_shard,
            paged=paged, attn_impl=attn_impl)
    elif kind == "rglru":
        mix, new_cache = rec.rglru_block(p["rnn"], hg, cfg, pc, state=cache)
    elif kind == "mlstm":
        mix, new_cache = rec.mlstm_block(p["mix"], hg, cfg, pc, state=cache)
    elif kind == "slstm":
        mix, new_cache = rec.slstm_block(p["mix"], hg, cfg, pc, state=cache)
    else:
        raise ValueError(kind)
    if paged is not None and recurrent and cache is not None:
        active = paged.n_new > 0
        new_cache = jax.tree.map(
            lambda old, new: jnp.where(_row_mask(active, new.ndim),
                                       new, old), cache, new_cache)

    # decode-path psums route through the autotuned ExecPlan collectives
    # when the serving ParallelConfig asks for them
    _psum = tp_decode_psum if paged is not None else tp_psum

    full_value = (kind == "slstm"
                  or (kind in ("attn", "local_attn")
                      and attn_replicated(cfg, pc)))
    if full_value:
        # replicated full value: slice the SP shard instead of reducing
        out = _shard_slice(mix, pc) if sp else mix
    else:
        out = seq_reduce_scatter(mix, pc) if sp else _psum(mix, pc)

    if cfg.parallel_residual and _block_has_mlp(cfg, kind):
        if moe_layer and cfg.moe is not None:
            mo, aux = moe_lib.moe_apply(p["mlp"], hg, cfg, pc)
        else:
            mo = mlp_apply(p["mlp"], hg, cfg, pc)
        mo = seq_reduce_scatter(mo, pc) if sp else _psum(mo, pc)
        return x + out + mo, new_cache, aux

    x = x + out
    if _block_has_mlp(cfg, kind):
        h2 = norm_apply(p["ln2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        hg2 = seq_all_gather(h2, pc) if sp else h2
        if moe_layer and cfg.moe is not None:
            mo, aux = moe_lib.moe_apply(p["mlp"], hg2, cfg, pc)
        else:
            mo = mlp_apply(p["mlp"], hg2, cfg, pc)
        x = x + (seq_reduce_scatter(mo, pc) if sp else _psum(mo, pc))
    return x, new_cache, aux


def _embed_inputs(params, batch, cfg: ModelConfig, pc: ParallelConfig):
    """Return the FULL-sequence activations (B, S, d) in compute dtype."""
    if cfg.frontend == "audio":
        return batch["embeds"].astype(COMPUTE_DTYPE)
    emb = embed_tokens(params["embed"], batch["tokens"], cfg, pc, sp=False)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        emb = jnp.concatenate(
            [batch["patch_embeds"].astype(COMPUTE_DTYPE), emb], axis=1)
    return emb


def forward(params, specs, batch, cfg: ModelConfig, pc: ParallelConfig, *,
            sp: bool, caches=None, pos0=None, rolling: bool = False,
            seq_shard: bool = False, paged: PageCtx = None,
            attn_impl: str = "xla"):
    """Shared trunk.  Returns (hidden_full (B,S,d), new_caches, aux)."""
    if cfg.frontend is None:
        # vocab-parallel embed scatters straight to the SP shard: the full
        # (B, S, d) activations never materialize on one device
        x = embed_tokens(params["embed"], batch["tokens"], cfg, pc, sp=sp)
        S = batch["tokens"].shape[1]
    else:
        x_full = _embed_inputs(params, batch, cfg, pc)
        S = x_full.shape[1]
        x = _shard_slice(x_full, pc) if sp else x_full
    if paged is not None:
        # continuous batching: every row sits at its own sequence offset
        positions = (paged.lengths[:, None]
                     + jnp.arange(S, dtype=jnp.int32)[None, :])   # (B, S)
    elif pos0 is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    else:
        positions = pos0 + jnp.arange(S, dtype=jnp.int32)

    new_prefix_caches = []
    for i, bp in enumerate(params["prefix"]):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, _ = block_apply(cfg.block_kind(i), bp, x, cfg, pc, sp=sp,
                               positions=positions, cache=c, rolling=rolling,
                               seq_shard=seq_shard, paged=paged,
                               moe_layer=False, attn_impl=attn_impl)
        new_prefix_caches.append(nc)

    groups = cfg.cycle_groups
    cyc_specs = specs["cycles"]

    def one_block(kind, gi):
        def f(bp, xc, c):
            # per-block FSDP gather: only this block's parameters are
            # materialized at a time (VJP = ZeRO-3 reduce-scatter)
            bp = fsdp_gather_tree(bp, cyc_specs[f"g{gi}"], pc, sliced=True)
            return block_apply(kind, bp, xc, cfg, pc, sp=sp,
                               positions=positions, cache=c,
                               rolling=rolling, seq_shard=seq_shard,
                               paged=paged, moe_layer=True,
                               attn_impl=attn_impl)
        if pc.remat:
            # per-BLOCK remat: the scans then save only each block's input
            # residual (B, S/tp, d); one block's internals are
            # rematerialized at a time during the backward sweep.
            f = jax.checkpoint(
                f, prevent_cse=True,
                policy=jax.checkpoint_policies.nothing_saveable)
        return f

    block_fns = {gi: one_block(kind, gi)
                 for gi, (kind, _) in enumerate(groups)}

    def cycle_body(carry, xs):
        xc, aux = carry
        if caches is not None:
            cyc_params, cyc_caches = xs
        else:
            cyc_params, cyc_caches = xs, None
        new_caches_c = {}
        for gi, (kind, cnt) in enumerate(groups):
            gp = cyc_params[f"g{gi}"]                     # (cnt, ...)
            gc = cyc_caches[f"g{gi}"] if cyc_caches is not None else None

            if cnt == 1:
                # no inner scan: a length-1 scan would checkpoint the
                # residual stream a second time (one stack per nesting)
                bp = jax.tree.map(lambda a: a[0], gp)
                bc = jax.tree.map(lambda a: a[0], gc) if gc is not None \
                    else None
                xc, nc, a = block_fns[gi](bp, xc, bc)
                aux = aux + a
                new_caches_c[f"g{gi}"] = (
                    jax.tree.map(lambda a_: a_[None], nc)
                    if gc is not None else None)
                continue

            def group_body(carry2, xs2, gi=gi, gc=gc):
                xcc, aux2 = carry2
                if gc is not None:
                    bp, bc = xs2
                else:
                    bp, bc = xs2, None
                xcc, nc, a = block_fns[gi](bp, xcc, bc)
                return (xcc, aux2 + a), nc

            xs2 = (gp, gc) if gc is not None else gp
            (xc, aux), new_gc = lax.scan(group_body, (xc, aux), xs2)
            new_caches_c[f"g{gi}"] = new_gc
        out = new_caches_c if caches is not None else None
        return (xc, aux), out

    xs = (params["cycles"], caches["cycles"]) if caches is not None \
        else params["cycles"]
    (x, aux), cyc_out = lax.scan(cycle_body, (x, jnp.float32(0.0)), xs)

    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    # NOTE: with sp=True the returned hidden state is the SP shard
    # (B, S/tp, d); the CE path gathers it chunk-by-chunk.
    new_caches = None
    if caches is not None:
        new_caches = {"prefix": new_prefix_caches, "cycles": cyc_out}
    return x, new_caches, aux


# ---------------------------------------------------------------- training
def loss_and_metrics(params, specs, batch, cfg: ModelConfig,
                     pc: ParallelConfig, *, attn_impl: str = "xla"):
    """Next-token (or masked-frame) CE loss.  Returns (loss_mean_local,
    (sum, count, aux)); the caller averages over DP."""
    # gather fsdp-sharded non-scanned params once
    top = {k: v for k, v in params.items() if k != "cycles"}
    top_specs = {k: v for k, v in specs.items() if k != "cycles"}
    top = fsdp_gather_tree(top, top_specs, pc)
    params = dict(top, cycles=params["cycles"])

    hidden, _, aux = forward(params, specs, batch, cfg, pc, sp=True,
                             attn_impl=attn_impl)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        npatch = batch["patch_embeds"].shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], npatch), -1, labels.dtype), labels],
            axis=1)
    head = params["head"] if not cfg.tie_embeddings else {
        "w": params["embed"]["w"].T}
    total, count = vocab_parallel_ce(head, hidden, labels, cfg, pc, sp=True)
    loss = total / jnp.maximum(count, 1) + aux
    return loss, (total, count, aux)


# ---------------------------------------------------------------- serving
def init_caches(cfg: ModelConfig, pc: ParallelConfig, batch_local: int,
                max_len: int, *, rolling: bool = False,
                seq_shard: bool = False):
    """Build the stacked cache pytree matching the scan structure."""
    def cache_for(kind):
        if kind in ("attn", "local_attn"):
            rw = cfg.window if (rolling and cfg.window) else None
            return init_cache(cfg, pc, batch_local, max_len,
                              rolling_window=rw, seq_shard=seq_shard)
        if kind == "rglru":
            return rec.init_rglru_state(cfg, pc, batch_local)
        if kind == "mlstm":
            return rec.init_mlstm_state(cfg, pc, batch_local)
        if kind == "slstm":
            return rec.init_slstm_state(cfg, pc, batch_local)
        raise ValueError(kind)

    n_prefix = len(cfg.prefix_kinds)
    prefix = [cache_for(cfg.block_kind(i)) for i in range(n_prefix)]
    n_cycles = (cfg.n_layers - n_prefix) // len(cfg.cycle)
    cycles = {}
    for gi, (kind, cnt) in enumerate(cfg.cycle_groups):
        one = cache_for(kind)
        cycles[f"g{gi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_cycles, cnt) + a.shape).copy(), one)
    return {"prefix": prefix, "cycles": cycles}


def init_paged_caches(cfg: ModelConfig, pc: ParallelConfig,
                      batch_local: int, n_blocks: int, block_size: int):
    """Stacked cache pytree for continuous batching: attention layers get
    a paged KV pool (``n_blocks`` fixed-size blocks indexed per-row via
    the block table in :class:`PageCtx`; block 0 is the shared garbage
    block backing unallocated table entries), recurrent layers keep
    their dense per-slot states.  One pool per layer -- the scan
    broadcast below stacks (n_cycles, cnt) independent pools -- while
    all layers share a single block-table geometry."""
    def cache_for(kind):
        if kind in ("attn", "local_attn"):
            return init_paged_pool(cfg, pc, n_blocks, block_size)
        if kind == "rglru":
            return rec.init_rglru_state(cfg, pc, batch_local)
        if kind == "mlstm":
            return rec.init_mlstm_state(cfg, pc, batch_local)
        if kind == "slstm":
            return rec.init_slstm_state(cfg, pc, batch_local)
        raise ValueError(kind)

    n_prefix = len(cfg.prefix_kinds)
    prefix = [cache_for(cfg.block_kind(i)) for i in range(n_prefix)]
    n_cycles = (cfg.n_layers - n_prefix) // len(cfg.cycle)
    cycles = {}
    for gi, (kind, cnt) in enumerate(cfg.cycle_groups):
        one = cache_for(kind)
        cycles[f"g{gi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_cycles, cnt) + a.shape).copy(), one)
    return {"prefix": prefix, "cycles": cycles}


def paged_decode_step(params, specs, tokens, caches, paged: PageCtx,
                      cfg: ModelConfig, pc: ParallelConfig, *,
                      attn_impl: str = "xla"):
    """One continuous-batching tick: tokens (B, S) where row b carries
    ``paged.n_new[b]`` valid new tokens (decode rows S_new=1, prefill
    rows up to the chunk, idle rows 0).  Returns (logits (B, 1, V) at
    each row's LAST valid position, new caches).

    Unlike :func:`decode_step` there is no shared ``pos0``: positions,
    KV writes and attention masks are all per-row via ``paged``; the
    final vocab gather runs on the decode-path collectives
    (:func:`repro.parallel.api.tp_decode_all_gather`)."""
    top = {k: v for k, v in params.items() if k != "cycles"}
    top_specs = {k: v for k, v in specs.items() if k != "cycles"}
    top = fsdp_gather_tree(top, top_specs, pc)
    params = dict(top, cycles=params["cycles"])

    hidden, new_caches, _ = forward(params, specs, {"tokens": tokens}, cfg,
                                    pc, sp=False, caches=caches, paged=paged,
                                    attn_impl=attn_impl)
    # row b's next-token logits live at its last valid position
    last = jnp.clip(paged.n_new - 1, 0, hidden.shape[1] - 1)
    hidden = jnp.take_along_axis(hidden, last[:, None, None], axis=1)
    head = params["head"] if not cfg.tie_embeddings else {
        "w": params["embed"]["w"].T}
    logits = jax.lax.dot_general(
        hidden, head["w"].astype(hidden.dtype),
        (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (B, 1, V/tp)
    if pc.tp > 1 and logits.shape[-1] != cfg.vocab:
        logits = tp_decode_all_gather(logits, pc, axis=2)
    return logits, new_caches


def decode_step(params, specs, tokens, caches, pos0, cfg: ModelConfig,
                pc: ParallelConfig, *, rolling: bool = False,
                seq_shard: bool = False,
                attn_impl: str = "xla", logits_len: int = 1):
    """tokens (B, S_new) -> (logits (B, min(S_new, logits_len), V),
    new caches).

    S_new == 1 for decode; larger for (chunked) prefill, where only the
    tail ``logits_len`` positions are scored -- scoring all 32k prefill
    positions against a 256k vocab would materialize a 67 GB logits
    tensor nobody reads.
    """
    top = {k: v for k, v in params.items() if k != "cycles"}
    top_specs = {k: v for k, v in specs.items() if k != "cycles"}
    top = fsdp_gather_tree(top, top_specs, pc)
    params = dict(top, cycles=params["cycles"])

    batch = {"tokens": tokens}
    hidden, new_caches, _ = forward(params, specs, batch, cfg, pc, sp=False,
                                    caches=caches, pos0=pos0,
                                    rolling=rolling, seq_shard=seq_shard,
                                    attn_impl=attn_impl)
    head = params["head"] if not cfg.tie_embeddings else {
        "w": params["embed"]["w"].T}
    if hidden.shape[1] > logits_len:
        hidden = hidden[:, -logits_len:, :]
    logits = jax.lax.dot_general(
        hidden, head["w"].astype(hidden.dtype),
        (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (B, L, V/tp)
    if pc.tp > 1 and logits.shape[-1] != cfg.vocab:
        logits = lax.all_gather(logits, pc.tp_axis, axis=2, tiled=True)
    return logits, new_caches
