"""Mixture-of-Experts FFN with capacity-based routing.

Parallelization: experts' ffn widths are sharded over the TP axis exactly
like a dense MLP (mixtral: 14336/16 = 896 per device; deepseek-moe:
1408/16 = 88).  Tokens are already gathered to the full sequence at the
block boundary (sequence-parallel residual), so routing is computed
redundantly-but-identically on every TP device and the expert outputs are
partial sums that the block boundary reduce-scatters -- the exact same
collective pattern as a dense block.

A token-dropping all-to-all expert-parallel dispatch (GShard style) is
available behind ``ParallelConfig.moe_dispatch``: tokens stay sharded
over the DP axis, experts are partitioned into ``dp`` groups, and two
all-to-alls move each rank's expert queues to the group owner and the
expert outputs back (``_experts_apply_ep``).  The exchange itself runs
either through stock ``lax.all_to_all`` ("gshard" -- the oracle) or
through the permutation-group schedule tables of
:func:`repro.core.allreduce.all_to_all_flat` ("schedule"); the two are
bit-identical because an all-to-all is a pure permutation.  The default
("tp") keeps the TP-sharded form, which for the expert counts in the
assigned pool (8/64 with tp=16) needs no extra collectives at all,
which the dry-run roofline confirms (see DESIGN.md §MoE).

Routing follows the standard top-k + capacity recipe: per expert a queue
of C = ceil(T * k / E * capacity_factor) slots; overflowing tokens drop
(their residual passes through).  Aux losses: load-balance + router
z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.allreduce import all_to_all_flat
from repro.models.layers import dense
from repro.parallel.api import ParallelConfig


def capacity(tokens: int, cfg_moe) -> int:
    c = math.ceil(tokens * cfg_moe.top_k / cfg_moe.n_experts
                  * cfg_moe.capacity_factor)
    return max(8, -(-c // 8) * 8)  # pad to 8 for TPU-friendly shapes


def route(p_router, x, cfg_moe):
    """x (T, d) -> top-k experts, probs and aux losses.

    Returns (expert_idx (T,k), probs (T,k), aux_loss scalar).
    """
    logits = jax.lax.dot_general(
        x, p_router["w"].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, cfg_moe.top_k)   # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance loss (Switch/GShard): E * sum_e f_e * m_e
    E = cfg_moe.n_experts
    me = jnp.mean(probs, axis=0)                                  # (E,)
    fe = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    lb = E * jnp.sum(fe * me)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = cfg_moe.aux_loss_weight * lb + cfg_moe.z_loss_weight * z
    return top_e, top_p, aux


def dispatch_indices(top_e, cfg_moe, T: int):
    """Compute (E, C) token indices (T = sentinel for empty slots) and the
    (T, k) in-queue positions, without materializing (T, k, E) one-hots."""
    E = cfg_moe.n_experts
    k = cfg_moe.top_k
    C = capacity(T, cfg_moe)
    counts = jnp.zeros((E,), jnp.int32)
    slot_pos = []
    for j in range(k):
        oh = jax.nn.one_hot(top_e[:, j], E, dtype=jnp.int32)       # (T, E)
        pos_in_slot = jnp.cumsum(oh, axis=0) - oh                  # (T, E)
        pos = jnp.sum(oh * pos_in_slot, axis=-1) + counts[top_e[:, j]]
        slot_pos.append(pos)
        counts = counts + jnp.sum(oh, axis=0)
    pos = jnp.stack(slot_pos, axis=1)                              # (T, k)
    keep = pos < C
    # scatter token ids into the expert queues
    tok = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], pos.shape)
    eq = jnp.full((E, C), T, dtype=jnp.int32)                      # sentinel T
    e_flat = top_e.reshape(-1)
    p_flat = jnp.where(keep, pos, C).reshape(-1)   # C = out of bounds -> drop
    eq = eq.at[e_flat, p_flat].set(tok.reshape(-1), mode="drop")
    return eq, pos, keep


def experts_apply(p, xq, cfg, act: str):
    """xq (E, C, d) -> (E, C, d) partial over TP (w2 rows sharded).

    Expert weights are stacked: w1/w3 (E, d, ff/tp), w2 (E, ff/tp, d).
    """
    def one(x_e, w1, w3, w2):
        g = dense(x_e, w1)
        u = dense(x_e, w3)
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
        return jax.lax.dot_general(
            h, w2.astype(h.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=h.dtype)
    return jax.vmap(one)(xq, p["w1"], p["w3"], p["w2"])


def ep_group_size(pc: ParallelConfig, n_experts: int) -> int:
    """Expert-parallel group size of the all-to-all dispatch (1 = the
    dispatch is disabled and every rank applies every expert locally).

    The dispatch activates when ``pc.moe_dispatch`` asks for it, the DP
    axis is a single named axis with more than one rank, and the expert
    count splits evenly across the ranks."""
    if pc.moe_dispatch not in ("gshard", "schedule"):
        return 1
    if pc.dp <= 1 or len(pc.dp_axes) != 1:
        return 1
    return pc.dp if n_experts % pc.dp == 0 else 1


def _experts_apply_ep(pe, xq, cfg, pc: ParallelConfig, ep: int):
    """Expert-parallel experts: all-to-all dispatch + local apply + return.

    ``xq`` (E, C, d) holds this rank's queues for *all* experts; rank
    ``s`` owns expert group ``s`` (experts ``s*E/ep .. (s+1)*E/ep-1``).
    Exchange 1 sends each group's queues to its owner (after it, entry
    ``s`` of the received (ep, E/ep, C, d) block is rank ``s``'s queues
    for *my* group); the owner applies its expert slice to every rank's
    tokens at once; exchange 2 is the inverse permutation, so the
    returned (E, C, d) buffer is laid out exactly like the local path's
    -- the combine below never knows which rank ran the experts.

    With ``pc.moe_dispatch == "schedule"`` both exchanges run the
    compiled permutation-group step tables
    (:func:`repro.core.allreduce.all_to_all_flat`, Bruck or direct by
    message size); "gshard" runs stock ``lax.all_to_all``.  Both are
    pure permutations of identical blocks, hence bit-identical.
    """
    axis = pc.dp_axes[0]
    E, C, d = xq.shape
    El = E // ep

    def exchange(buf):
        # buf (ep, El, C, d), entry s destined for rank s; returns the
        # same shape with entry s = the block received from rank s
        if pc.moe_dispatch == "schedule":
            return all_to_all_flat(buf.reshape(-1), axis).reshape(buf.shape)
        return lax.all_to_all(buf, axis, split_axis=0, concat_axis=0)

    recv = exchange(xq.reshape(ep, El, C, d))            # [s] = s's queues
    rk = lax.axis_index(axis)
    loc = {k: lax.dynamic_slice_in_dim(v, rk * El, El, 0)
           for k, v in pe.items()}
    xq_l = jnp.moveaxis(recv, 0, 1).reshape(El, ep * C, d)
    yq_l = experts_apply(loc, xq_l, cfg, cfg.act)        # (El, ep*C, d)
    back = jnp.moveaxis(yq_l.reshape(El, ep, C, d), 1, 0)
    return exchange(back).reshape(E, C, d)


_MOE_TOKEN_CHUNK = 8192


def moe_apply(p, xg, cfg, pc: ParallelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xg (B, S, d) full-seq -> ((B, S, d) partial-over-TP, aux_loss).

    Tokens are processed in chunks of ~8k (scanned, rematted): the
    (E, C, d) dispatch buffers for a 64-expert layer at 64k tokens would
    otherwise hold multiple GB live across the backward pass.  Capacity is
    per-chunk, which also bounds worst-case token dropping locality.
    """
    B, S, d = xg.shape
    T = B * S
    x = xg.reshape(T, d)
    if T > _MOE_TOKEN_CHUNK:
        nc = -(-T // _MOE_TOKEN_CHUNK)
        pad = nc * _MOE_TOKEN_CHUNK - T
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])
        xs = x.reshape(nc, -1, d)

        def body(aux_c, xc):
            yc, a = _moe_tokens(p, xc, cfg, pc)
            return aux_c + a / nc, yc

        aux, ys = lax.scan(jax.checkpoint(body, prevent_cse=False),
                           jnp.float32(0.0), xs)
        out = ys.reshape(-1, d)[:T]
        return out.reshape(B, S, d), aux
    out, aux = _moe_tokens(p, x, cfg, pc)
    return out.reshape(B, S, d), aux


def _moe_tokens(p, x, cfg, pc: ParallelConfig):
    """Route + dispatch + experts + combine for a flat (T, d) token set."""
    m = cfg.moe
    T, d = x.shape
    top_e, top_p, aux = route(p["router"], x, m)
    eq, pos, keep = dispatch_indices(top_e, m, T)

    xpad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])        # sentinel row
    xq = jnp.take(xpad, eq, axis=0)                                # (E, C, d)
    ep = ep_group_size(pc, m.n_experts)
    if ep > 1:
        yq = _experts_apply_ep(p["experts"], xq, cfg, pc, ep)      # (E, C, d)
    else:
        yq = experts_apply(p["experts"], xq, cfg, cfg.act)         # (E, C, d)

    # combine: token t gets sum_j prob_j * yq[e_j, pos_j]
    C = yq.shape[1]
    ypad = jnp.concatenate([yq.reshape(-1, d),
                            jnp.zeros((1, d), yq.dtype)])
    flat_idx = jnp.where(keep, top_e * C + jnp.clip(pos, 0, C - 1),
                         ypad.shape[0] - 1)                        # (T, k)
    gathered = jnp.take(ypad, flat_idx.reshape(-1), axis=0)
    gathered = gathered.reshape(T, m.top_k, d)
    out = jnp.sum(gathered * top_p[..., None].astype(gathered.dtype), axis=1)

    if m.n_shared:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(p["shared"], x, cfg, pc).reshape(T, d)
    return out, aux
