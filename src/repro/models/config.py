"""Model architecture configuration.

One frozen dataclass describes every architecture in the assigned pool:
dense decoders, GQA/MQA, sliding-window attention, MoE (coarse + fine
grained), recurrent-hybrid (RG-LRU), xLSTM, encoder-only audio and
VLM-backbone models.  Per-arch instances live in :mod:`repro.configs`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # ffn width per routed expert
    n_shared: int = 0             # always-on shared experts (DeepSeek-MoE)
    d_shared: int = 0             # ffn width of the fused shared expert
    capacity_factor: float = 1.25
    first_dense: int = 0          # leading dense layers (DeepSeek-MoE: 1)
    d_first_dense: int = 0        # ffn width of those dense layers
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encoder | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    cycle: Tuple[str, ...] = ("attn",)   # block kinds, cycled over layers
    prefix: Tuple[str, ...] = ()         # unscanned leading blocks
    window: Optional[int] = None          # sliding-window size (SWA / local)
    moe: Optional[MoEConfig] = None
    act: str = "swiglu"           # swiglu | geglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-5
    causal: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    parallel_residual: bool = False       # Cohere-style parallel attn+mlp
    frontend: Optional[str] = None        # None | "audio" | "vision"
    n_patches: int = 256          # vision stub: patch embeddings per image
    # recurrent-block hyper-params
    rnn_width: int = 0            # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4           # temporal conv in the recurrent block
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    notes: str = ""

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def prefix_kinds(self) -> Tuple[str, ...]:
        """Unscanned leading blocks: explicit ``prefix`` or, for MoE
        configs, the leading dense layers (DeepSeek's first_dense)."""
        if self.prefix:
            return self.prefix
        if self.moe is not None and self.moe.first_dense:
            return tuple(self.cycle[i % len(self.cycle)]
                         for i in range(self.moe.first_dense))
        return ()

    def block_kind(self, layer: int) -> str:
        npfx = len(self.prefix_kinds)
        if layer < npfx:
            return self.prefix_kinds[layer]
        return self.cycle[(layer - npfx) % len(self.cycle)]

    @property
    def blocks(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))

    @property
    def n_cycles(self) -> int:
        n = self.n_layers - len(self.prefix_kinds)
        assert n % len(self.cycle) == 0, (
            f"{self.name}: {n} cycled layers not a multiple of "
            f"cycle {self.cycle}")
        return n // len(self.cycle)

    @property
    def cycle_groups(self) -> Tuple[Tuple[str, int], ...]:
        """Run-length-encoded cycle: consecutive identical block kinds are
        executed as an inner scan over stacked parameters, so XLA
        allocates each kind's working buffers once per group instead of
        once per block (decisive for xLSTM's 7x mLSTM cycle)."""
        groups = []
        for k in self.cycle:
            if groups and groups[-1][0] == k:
                groups[-1][1] += 1
            else:
                groups.append([k, 1])
        return tuple((k, c) for k, c in groups)

    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """True if decode-state size is O(window + rnn_state), i.e. the
        arch can serve 500k-token contexts (SWA / recurrent / local-attn)."""
        kinds = set(self.blocks)
        if kinds & {"rglru", "mlstm", "slstm"}:
            full_attn = ("attn" in kinds and self.window is None)
            return not full_attn
        return self.window is not None

    # rough parameter count (embedding + blocks), for sanity checks
    def param_count(self) -> int:
        d = self.d_model
        n = 0
        n += self.vocab * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab * d                  # lm head
        for kind in self.blocks:
            n += 2 * d                           # 2 norms
            if kind in ("attn", "local_attn"):
                n += d * self.q_dim + self.q_dim * d + 2 * d * self.kv_dim
            elif kind == "rglru":
                w = self.rnn_width or d
                n += 2 * d * w + w * d           # in x2, out
                n += self.conv_width * w + 3 * w # conv + gates-ish
            elif kind in ("mlstm",):
                w = int(d * self.mlstm_proj_factor)
                n += 2 * d * w + w * d + 3 * w * (w // max(self.n_heads, 1))
            elif kind == "slstm":
                w = int(d * self.slstm_proj_factor)
                n += 4 * d * d + 2 * d * w
            if kind in ("attn", "local_attn"):
                n += self._mlp_params()
        return n

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            per = (3 if self.act in ("swiglu", "geglu") else 2)
            n = m.n_experts * per * d * m.d_expert + d * m.n_experts
            if m.n_shared:
                n += per * d * m.d_shared
            return n
        per = 3 if self.act in ("swiglu", "geglu") else 2
        return per * d * self.d_ff


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules from the assignment (documented in DESIGN.md)."""
    if not cfg.is_decoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch; 500k decode needs "
                       "sub-quadratic attention")
    return True, ""
