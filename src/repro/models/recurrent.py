"""Recurrent temporal mixers: RG-LRU (RecurrentGemma/Griffin) and
xLSTM blocks (mLSTM chunkwise, sLSTM scan).

TP sharding strategies (see DESIGN.md §Arch-applicability):

* RG-LRU: the recurrence is diagonal (per-channel), so the recurrence
  width shards cleanly over TP -- conv, gates and the scan are all
  channel-local; only the out-projection produces TP-partial sums.
* mLSTM: the matrix state C = sum_t (f..) i_t v_t k_t^T decomposes over
  the *v/output* dimension, so v (and the output) shard over TP while the
  q/k projections are replicated (their grads are exact under a TP psum
  because each device contributes a disjoint output slice).
* sLSTM: dense per-head recurrent weights resist head-splitting below
  n_heads; computation is replicated over TP and the output sliced back
  into the sequence-parallel residual (documented inefficiency; xLSTM-1.3b
  is 7:1 mLSTM-dominated).

All scans are ``lax.scan`` / ``lax.associative_scan`` over the sequence --
TPU-friendly (no dynamic control flow) and differentiable.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense
from repro.parallel.api import ParallelConfig


# ===========================================================================
#  RG-LRU (Griffin)
# ===========================================================================

class RGLRUState(NamedTuple):
    h: jnp.ndarray          # (B, w_local) recurrence state, fp32
    conv: jnp.ndarray       # (B, conv_width-1, w_local) conv tail


_C_RGLRU = 8.0


def _rglru_scan(x, a_log, gate_r, gate_i, h0):
    """Diagonal gated linear recurrence via associative scan.

    x       (B, S, w) inputs, fp32
    a_log   (w,)      log-space recurrence parameter (Lambda)
    gate_r  (B, S, w) recurrence gate in [0,1]
    gate_i  (B, S, w) input gate in [0,1]
    h0      (B, w)    carried state
    returns (B, S, w) outputs and final state.
    """
    log_a = -_C_RGLRU * jax.nn.softplus(a_log) * gate_r        # <= 0
    a = jnp.exp(log_a)
    multiplier = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    b = multiplier * (gate_i * x)
    # fold carried state into the first step
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = lax.associative_scan(combine, (a, b), axis=1)
    return hh, hh[:, -1, :]


def rglru_block(p, xg, cfg, pc: ParallelConfig, *,
                state: Optional[RGLRUState] = None
                ) -> Tuple[jnp.ndarray, Optional[RGLRUState]]:
    """Griffin recurrent block.  xg (B, S, d) full-seq.

    Returns (B, S, d) partial-over-TP output + new state (decode).
    """
    B, S, d = xg.shape
    w_local = p["w_x"].shape[1]
    # two branches: gate (GeLU) and recurrent
    g = jax.nn.gelu(dense(xg, p["w_gate"]))                  # (B, S, w/tp)
    x = dense(xg, p["w_x"])                                  # (B, S, w/tp)

    # temporal conv (depthwise, causal, width cw)
    cw = cfg.conv_width
    if state is not None:
        hist = jnp.concatenate([state.conv.astype(x.dtype), x], axis=1)
    else:
        hist = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    kernel = p["conv_w"]                                     # (cw, w/tp)
    x = sum(hist[:, i:i + S, :] * kernel[i][None, None, :]
            for i in range(cw)) + p["conv_b"][None, None, :]

    xf = x.astype(jnp.float32)
    gr = jax.nn.sigmoid(dense(xg, p["w_rg"]).astype(jnp.float32))
    gi = jax.nn.sigmoid(dense(xg, p["w_ig"]).astype(jnp.float32))
    h0 = state.h if state is not None else jnp.zeros((B, w_local), jnp.float32)
    y, h_last = _rglru_scan(xf, p["a_log"].astype(jnp.float32), gr, gi, h0)
    y = y.astype(xg.dtype) * g
    out = jax.lax.dot_general(
        y, p["w_out"].astype(y.dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=y.dtype)
    new_state = None
    if state is not None:
        tail = hist[:, -(cw - 1):, :] if cw > 1 else \
            jnp.zeros((B, 0, w_local), x.dtype)
        new_state = RGLRUState(h_last, tail.astype(jnp.float32))
    return out, new_state


def init_rglru_state(cfg, pc: ParallelConfig, batch_local: int) -> RGLRUState:
    w_local = (cfg.rnn_width or cfg.d_model) // pc.tp
    return RGLRUState(
        jnp.zeros((batch_local, w_local), jnp.float32),
        jnp.zeros((batch_local, cfg.conv_width - 1, w_local), jnp.float32))


# ===========================================================================
#  mLSTM (xLSTM) -- chunkwise parallel form
# ===========================================================================

class MLSTMState(NamedTuple):
    C: jnp.ndarray          # (B, H, hd_v_local, hd_qk) matrix memory, fp32
    n: jnp.ndarray          # (B, H, hd_qk) normalizer, fp32
    m: jnp.ndarray          # (B, H) log-space stabilizer, fp32


_MLSTM_CHUNK = 64


def _mlstm_step(carry, inp, scale: float):
    C, n, m = carry
    qt, kt, vt, it, ft = inp
    # projections stream through the scan in bf16 (a fp32 copy of the
    # full (S, B, H, Dk) q/k arrays costs ~1 GB/layer); the state math
    # itself runs in fp32
    qt = qt.astype(jnp.float32)
    kt = kt.astype(jnp.float32)
    vt = vt.astype(jnp.float32)
    m_new = jnp.maximum(ft + m, it)                       # (B, H)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    C = f_[..., None, None] * C + i_[..., None, None] * (
        vt[..., :, None] * kt[..., None, :])              # (B,H,Dv,Dk)
    n = f_[..., None] * n + i_[..., None] * kt
    num = jnp.einsum("bhvk,bhk->bhv", C, qt * scale)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt * scale))
    den = jnp.maximum(den, jnp.exp(-m_new))               # xLSTM stabilizer
    h = num / den[..., None]
    return (C, n, m_new), h


def _mlstm_recurrence(q, k, v, i_log, f_log, st: MLSTMState, scale: float,
                      chunk: int = _MLSTM_CHUNK):
    """Stabilized mLSTM over S steps.

    Memory layout matters more than FLOPs here: a flat scan over S steps
    would checkpoint the (B, H, Dv, Dk) matrix state *per step* for the
    backward pass (TBs at S=4k).  We nest the scan -- outer over S/chunk
    chunks, inner (rematerialized) over the chunk -- so only the
    chunk-boundary states are saved: memory drops by ``chunk``x for one
    extra forward of the inner steps.  The fully-parallel chunkwise form
    is the documented next perf iteration (DESIGN.md).

    q, k   (B, S, H, Dk); v (B, S, H, Dv_local); i/f_log (B, S, H).
    """
    S = q.shape[1]
    step = partial(_mlstm_step, scale=scale)
    seq = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
           i_log.swapaxes(0, 1), f_log.swapaxes(0, 1))
    if S <= chunk:
        (C, n, m), hs = lax.scan(step, (st.C, st.n, st.m), seq)
        return hs.swapaxes(0, 1), MLSTMState(C, n, m)

    pad = (-S) % chunk
    if pad:
        def padseq(x, fill):
            return jnp.concatenate(
                [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        seq = (padseq(seq[0], 0.0), padseq(seq[1], 0.0), padseq(seq[2], 0.0),
               padseq(seq[3], -1e30),   # i = 0: padding never writes
               padseq(seq[4], 0.0))     # f = 1: state passes through
    n_chunks = (S + pad) // chunk
    seq = jax.tree.map(
        lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), seq)

    def outer(carry, inp):
        return lax.scan(step, carry, inp)

    (C, n, m), hs = lax.scan(jax.checkpoint(outer, prevent_cse=False),
                             (st.C, st.n, st.m), seq)
    hs = hs.reshape((n_chunks * chunk,) + hs.shape[2:])[:S]
    return hs.swapaxes(0, 1), MLSTMState(C, n, m)


def mlstm_block(p, xg, cfg, pc: ParallelConfig, *,
                state: Optional[MLSTMState] = None
                ) -> Tuple[jnp.ndarray, Optional[MLSTMState]]:
    """xLSTM mLSTM block.  xg (B, S, d) -> (B, S, d) partial over TP.

    v/output dims shard over TP; q/k replicated.
    """
    B, S, d = xg.shape
    H = cfg.n_heads
    w = int(d * cfg.mlstm_proj_factor)
    dk = w // H
    q = dense(xg, p["w_q"]).reshape(B, S, H, dk)          # bf16 until the step
    k = dense(xg, p["w_k"]).reshape(B, S, H, dk)
    v = dense(xg, p["w_v"])                               # (B,S,w/tp)
    dv = v.shape[-1] // H
    v = v.reshape(B, S, H, dv)
    i_log = dense(xg, p["w_i"]).astype(jnp.float32).reshape(B, S, H)
    f_log = -jax.nn.softplus(
        -dense(xg, p["w_f"]).astype(jnp.float32)).reshape(B, S, H)

    st = state if state is not None else MLSTMState(
        jnp.zeros((B, H, dv, dk), jnp.float32),
        jnp.zeros((B, H, dk), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32))
    hs, new_st = _mlstm_recurrence(q, k, v, i_log, f_log, st,
                                   scale=dk ** -0.5)
    y = hs.astype(xg.dtype).reshape(B, S, -1)                 # (B,S,w/tp)
    gate = jax.nn.silu(dense(xg, p["w_g"]))                   # (B,S,w/tp)
    out = jax.lax.dot_general(
        y * gate, p["w_out"].astype(y.dtype), (((2,), (0,)), ((), ())),
        preferred_element_type=y.dtype)
    return out, (new_st if state is not None else None)


def init_mlstm_state(cfg, pc: ParallelConfig, batch_local: int) -> MLSTMState:
    d = cfg.d_model
    H = cfg.n_heads
    w = int(d * cfg.mlstm_proj_factor)
    dk = w // H
    dv = (w // pc.tp) // H
    return MLSTMState(
        jnp.zeros((batch_local, H, dv, dk), jnp.float32),
        jnp.zeros((batch_local, H, dk), jnp.float32),
        jnp.full((batch_local, H), -1e30, jnp.float32))


# ===========================================================================
#  sLSTM (xLSTM) -- scalar-state, per-head dense recurrence
# ===========================================================================

class SLSTMState(NamedTuple):
    c: jnp.ndarray          # (B, d) cell, fp32
    n: jnp.ndarray          # (B, d) normalizer
    h: jnp.ndarray          # (B, d) hidden
    m: jnp.ndarray          # (B, d) stabilizer


def slstm_block(p, xg, cfg, pc: ParallelConfig, *,
                state: Optional[SLSTMState] = None
                ) -> Tuple[jnp.ndarray, Optional[SLSTMState]]:
    """sLSTM block, replicated across TP (output is a full value, the
    caller slices the sequence-parallel shard instead of reducing)."""
    B, S, d = xg.shape
    H = cfg.n_heads
    hd = d // H
    zx = dense(xg, p["w_z"]).astype(jnp.float32)
    ix = dense(xg, p["w_i"]).astype(jnp.float32)
    fx = dense(xg, p["w_f"]).astype(jnp.float32)
    ox = dense(xg, p["w_o"]).astype(jnp.float32)
    r_z, r_i, r_f, r_o = (p["r_z"], p["r_i"], p["r_f"], p["r_o"])  # (H,hd,hd)

    def rec(h, r):
        return jnp.einsum("bhx,hxy->bhy", h.reshape(B, H, hd),
                          r.astype(jnp.float32)).reshape(B, d)

    st = state if state is not None else SLSTMState(
        *[jnp.zeros((B, d), jnp.float32) for _ in range(3)],
        jnp.full((B, d), -1e30, jnp.float32))

    def step(carry, inp):
        c, n, h, m = carry
        zt, it, ft, ot = inp
        z = jnp.tanh(zt + rec(h, r_z))
        ilog = it + rec(h, r_i)
        flog = -jax.nn.softplus(-(ft + rec(h, r_f)))          # log sigmoid
        o = jax.nn.sigmoid(ot + rec(h, r_o))
        m_new = jnp.maximum(flog + m, ilog)
        i_ = jnp.exp(ilog - m_new)
        f_ = jnp.exp(flog + m - m_new)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    seq = tuple(a.swapaxes(0, 1) for a in (zx, ix, fx, ox))
    chunk = _MLSTM_CHUNK
    if S <= chunk:
        (c, n, h, m), hs = lax.scan(step, tuple(st), seq)
    else:
        # nested chunked scan (see _mlstm_recurrence): saves only
        # chunk-boundary states for the backward pass
        pad = (-S) % chunk
        if pad:
            seq = tuple(jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]) for x in seq)
        n_chunks = (S + pad) // chunk
        seq = jax.tree.map(
            lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), seq)

        def outer(carry, inp):
            return lax.scan(step, carry, inp)

        (c, n, h, m), hs = lax.scan(jax.checkpoint(outer, prevent_cse=False),
                                    tuple(st), seq)
        hs = hs.reshape((n_chunks * chunk,) + hs.shape[2:])[:S]
    y = hs.swapaxes(0, 1).astype(xg.dtype)                    # (B, S, d)
    out = dense(y, p["w_out"])                                # replicated full
    return out, (SLSTMState(c, n, h, m) if state is not None else None)


def init_slstm_state(cfg, pc: ParallelConfig, batch_local: int) -> SLSTMState:
    d = cfg.d_model
    return SLSTMState(
        jnp.zeros((batch_local, d), jnp.float32),
        jnp.zeros((batch_local, d), jnp.float32),
        jnp.zeros((batch_local, d), jnp.float32),
        jnp.full((batch_local, d), -1e30, jnp.float32))
