"""Continuous-batching serving engine on paged KV caches.

Requests enter a FIFO queue and are admitted onto fixed batch *slots*
independently: each slot prefills its own prompt (in chunks, interleaved
with other slots' decode steps) and decodes at its own position, and a
finished slot is recycled immediately without touching its neighbors --
no wave barrier.  The device-side state is one jitted paged serve step
(:func:`repro.train.step.make_paged_serve_step`): KV lives in fixed-size
blocks indexed by a host-managed block table
(:class:`repro.serve.kv.KVBlockManager`), so slot recycling is a table
update, never a cache copy.

Every tick runs ONE step of shape ``(B, S)`` with per-row valid counts
``n_new``: prefilling rows carry up to ``prefill_chunk`` prompt tokens,
decoding rows carry their 1 pending token, idle rows carry 0.  S stays
in {1, prefill_chunk} so the program compiles at most twice.  Recurrent
archs (rglru / xLSTM) cannot mask inside a chunk, so for them ticks are
*aligned*: a row joins a chunk tick only with a full chunk (its prompt
tail runs at S=1) and decode rows only join S=1 ticks.

Tensor-parallel decode runs its psum / vocab-gather on ExecPlan
collectives picked by ``autotune.choose()`` at the decode message sizes
(``decode_collectives="plan"``, the default) -- the r = max_r /
traff_rounds latency regime that is the paper's headline result.  With a
measured tuning table attached (``tuning=True`` +
``REPRO_TUNING_CACHE``), the trace-time picks report
``source="measured"``; inspect them via :attr:`Engine.decode_choices`.

Sampling is deterministic per ``(seed, request uid, token index)``
(Gumbel-max over the logits), so outputs are bit-stable regardless of
which slot a request lands on or what shares its batch.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Callable, Deque, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.attention import PageCtx
from repro.models.config import ModelConfig
from repro.models.model import init_paged_caches
from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram
from repro.parallel.api import (ParallelConfig, decode_choice_log,
                                reset_decode_choice_log)
from repro.serve.kv import KVBlockManager
from repro.train.step import make_paged_serve_step

_RECURRENT = ("rglru", "mlstm", "slstm")


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # called as stream(request, token) on every generated token
    stream: Optional[Callable[["Request", int], None]] = None
    uid: Optional[int] = None       # assigned at submit (sampling key)
    # lifecycle timestamps (microseconds, perf_counter epoch), recorded
    # unconditionally -- latency accounting must not require tracing on
    t_enqueue_us: Optional[float] = None
    t_first_token_us: Optional[float] = None
    t_done_us: Optional[float] = None

    @property
    def ttft_us(self) -> Optional[float]:
        """Enqueue -> first generated token."""
        if self.t_enqueue_us is None or self.t_first_token_us is None:
            return None
        return self.t_first_token_us - self.t_enqueue_us

    @property
    def latency_us(self) -> Optional[float]:
        """Enqueue -> done."""
        if self.t_enqueue_us is None or self.t_done_us is None:
            return None
        return self.t_done_us - self.t_enqueue_us


@dataclass
class _Slot:
    """One live request's device-side coordinates."""
    req: Request
    fed: int = 0          # tokens written to cache/state so far
    next_tok: int = -1    # pending decode input (last sampled token)
    fresh: bool = True    # recurrent-state reset pending (first tick)

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.req.prompt)


class Engine:
    def __init__(self, cfg: ModelConfig, pc: ParallelConfig, mesh, params, *,
                 batch_slots: int = 4, max_len: int = 256,
                 prefill_chunk: int = 32, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 tuning: Optional[bool] = None,
                 decode_collectives: str = "plan",
                 bundle=None):
        """``batch_slots`` / ``n_blocks`` are PER DP SHARD; the global
        batch is ``batch_slots * dp``.  ``n_blocks`` defaults to full
        residency (every slot can hold ``max_len`` tokens) + the garbage
        block; pass less to exercise admission under block pressure.
        ``tuning`` / ``decode_collectives`` override the matching
        ParallelConfig fields without rebuilding it at call sites.
        ``bundle``: inject a prebuilt ``make_paged_serve_step`` result
        to share one compiled program across engines (tests)."""
        if tuning is not None and tuning != pc.tuning:
            pc = replace(pc, tuning=tuning)
        if decode_collectives != pc.decode_collectives:
            pc = replace(pc, decode_collectives=decode_collectives)
        self.cfg, self.pc, self.mesh = cfg, pc, mesh
        self.params = params
        self.dp = max(pc.dp, 1)
        self.slots_per_shard = batch_slots
        self.B = batch_slots * self.dp
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.block_size = block_size
        self.temperature = temperature
        self.seed = seed
        # recurrent rows cannot mask mid-chunk: aligned tick scheduling
        self.aligned = any(k in _RECURRENT for k in cfg.blocks)
        self.nb_max = -(-max_len // block_size)
        if n_blocks is None:
            n_blocks = 1 + batch_slots * self.nb_max
        self.n_blocks = n_blocks
        self.kv = [KVBlockManager(n_blocks, block_size, self.nb_max,
                                  batch_slots) for _ in range(self.dp)]
        if bundle is None:
            # fresh compile session: picks logged at trace time belong
            # to this bundle.  An injected bundle keeps its log -- its
            # programs (and their choices) predate this engine.
            reset_decode_choice_log()
            bundle = make_paged_serve_step(cfg, pc, mesh)
        self.bundle = bundle
        self.caches = init_paged_caches(cfg, pc, self.B,
                                        n_blocks * self.dp, block_size)
        self.lengths = np.zeros(self.B, np.int32)
        self.slots: List[Optional[_Slot]] = [None] * self.B
        self.queue: Deque[Request] = deque()
        self._next_uid = 0
        # always-on request accounting (tracing adds spans on top)
        self._ttft = Histogram("ttft_us")
        self._latency = Histogram("request_latency_us")
        self._n_requests = 0
        self._n_tokens = 0
        self._n_ticks = 0
        self._n_prefill_ticks = 0

    # ------------------------------------------------------------ queue
    def submit(self, req: Request) -> Request:
        """Enqueue one request (FIFO).  Returns it with ``uid`` set."""
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_len:
            raise ValueError(f"prompt+max_new={total} exceeds "
                             f"max_len={self.max_len}")
        if req.t_enqueue_us is None:
            req.t_enqueue_us = _now_us()
        if req.uid is None:
            req.uid = self._next_uid
            self._next_uid += 1
        self.queue.append(req)
        self._n_requests += 1
        return req

    def _admit(self) -> None:
        """Strict-FIFO admission: the queue head is admitted to the first
        shard with a free slot AND room for its full block footprint;
        if the head cannot be placed, nothing behind it jumps ahead."""
        while self.queue:
            req = self.queue[0]
            need = len(req.prompt) + req.max_new_tokens
            placed = False
            for shard in range(self.dp):
                if not self.kv[shard].fits(need):
                    continue
                base = shard * self.slots_per_shard
                for local in range(self.slots_per_shard):
                    b = base + local
                    if self.slots[b] is None:
                        self.kv[shard].admit(local, need)
                        self.slots[b] = _Slot(req=req)
                        self.lengths[b] = 0
                        placed = True
                        break
                if placed:
                    break
            if not placed:
                return
            self.queue.popleft()

    # ------------------------------------------------------------ ticking
    def _plan_tick(self):
        """Pick this tick's S and per-row (tokens, n_new)."""
        chunk = self.prefill_chunk
        if self.aligned:
            # chunk ticks carry ONLY rows with >= chunk prompt tokens left
            full = [b for b, s in enumerate(self.slots)
                    if s is not None
                    and len(s.req.prompt) - s.fed >= chunk]
            if full:
                return chunk, full
            live = [b for b, s in enumerate(self.slots) if s is not None]
            return 1, live
        any_prefill = any(s is not None and s.prefilling
                          for s in self.slots)
        live = [b for b, s in enumerate(self.slots) if s is not None]
        return (chunk if any_prefill else 1), live

    def step(self) -> int:
        """Admit + run one device tick.  Returns #tokens generated."""
        self._admit()
        if all(s is None for s in self.slots):
            return 0
        S, rows = self._plan_tick()
        toks = np.zeros((self.B, S), np.int32)
        n_new = np.zeros(self.B, np.int32)
        reset = np.zeros(self.B, bool)
        for b in rows:
            s = self.slots[b]
            if s.prefilling:
                n = min(S, len(s.req.prompt) - s.fed)
                toks[b, :n] = s.req.prompt[s.fed:s.fed + n]
            else:
                n = 1
                toks[b, 0] = s.next_tok
            n_new[b] = n
            reset[b] = s.fresh
            s.fresh = False
        table = np.concatenate([m.table for m in self.kv], axis=0)
        ctx = PageCtx(block_table=jnp.asarray(table),
                      lengths=jnp.asarray(self.lengths),
                      n_new=jnp.asarray(n_new),
                      reset=jnp.asarray(reset))
        prefill = bool((n_new > 1).any()) or any(
            self.slots[b].prefilling for b in rows if self.slots[b])
        with obs_trace.span("engine.tick", cat="serve", s=S,
                            live=len(rows), queued=len(self.queue),
                            prefill=prefill):
            logits, self.caches = self.bundle.serve_step(
                self.params, jnp.asarray(toks), self.caches, ctx)
        self._n_ticks += 1
        self._n_prefill_ticks += int(S > 1)
        self.lengths += n_new
        lg = None   # fetched lazily: pure-prefill ticks never read logits
        emitted = 0
        for b in rows:
            s = self.slots[b]
            s.fed += int(n_new[b])
            if s.fed < len(s.req.prompt) + len(s.req.out_tokens):
                continue      # mid-prefill: logits not meaningful yet
            if lg is None:
                lg = np.asarray(logits[:, 0], np.float32)
            tok = self._sample(lg[b], s.req.uid, len(s.req.out_tokens))
            s.req.out_tokens.append(tok)
            s.next_tok = tok
            self._n_tokens += 1
            emitted += 1
            now = _now_us()
            if s.req.t_first_token_us is None:
                s.req.t_first_token_us = now
                if s.req.ttft_us is not None:
                    self._ttft.record(s.req.ttft_us)
            if s.req.stream is not None:
                s.req.stream(s.req, tok)
            if len(s.req.out_tokens) >= s.req.max_new_tokens:
                s.req.done = True
                s.req.t_done_us = now
                if s.req.latency_us is not None:
                    self._latency.record(s.req.latency_us)
                shard, local = divmod(b, self.slots_per_shard)
                self.kv[shard].retire(local)
                self.slots[b] = None
                self.lengths[b] = 0
        return emitted

    def run(self) -> None:
        """Drive ticks until queue and slots drain."""
        while self.queue or any(s is not None for s in self.slots):
            self.step()

    def generate(self, requests: List[Request]) -> List[Request]:
        """Submit a batch and serve it to completion (offline mode)."""
        for r in requests:
            self.submit(r)
        self.run()
        return requests

    # ------------------------------------------------------------ sampling
    def _sample(self, logits_row: np.ndarray, uid: int, step: int) -> int:
        """Greedy argmax, or Gumbel-max at ``temperature`` keyed by
        (seed, uid, step): one vectorized argmax over the vocab, and the
        draw depends only on the request identity -- not on its slot,
        admission order, or batch mates."""
        if self.temperature <= 0:
            return int(logits_row.argmax())
        g = np.random.default_rng(
            np.random.SeedSequence([self.seed, uid, step]))
        gumbel = -np.log(-np.log(g.random(logits_row.shape[-1])))
        return int((logits_row / self.temperature + gumbel).argmax())

    # ------------------------------------------------------------ stats
    @property
    def decode_choices(self):
        """Trace-time decode collective picks: [(op, nbytes, Choice)]."""
        return decode_choice_log()

    def stats(self) -> dict:
        """Always-on serving statistics (independent of tracing).

        ``ttft_us`` / ``request_latency_us`` are enqueue -> first-token
        and enqueue -> done distributions (count/mean/p50/p90/p99) over
        every finished request; ``tokens`` counts generated tokens;
        ``ticks`` counts device steps (``prefill_ticks`` of them at
        S = prefill_chunk).  The dict is plain JSON, merged into the
        metrics snapshot by the serving benchmarks.
        """
        return {
            "requests": self._n_requests,
            "tokens": self._n_tokens,
            "ticks": self._n_ticks,
            "prefill_ticks": self._n_prefill_ticks,
            "queued": len(self.queue),
            "live": sum(s is not None for s in self.slots),
            "kv": [m.stats() for m in self.kv],
            "ttft_us": self._ttft.summary(),
            "request_latency_us": self._latency.summary(),
        }
