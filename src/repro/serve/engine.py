"""Batched serving engine: chunked prefill + decode with continuous
batching over fixed cache slots.

The engine owns one jitted ``serve_step`` (a shard_map program) reused for
both prefill (S_new = chunk) and decode (S_new = 1) -- prefill chunks keep
the compiled-shape set small.  Requests are multiplexed onto ``B`` cache
slots; when a sequence finishes (EOS or max tokens) its slot is handed to
the next queued request without touching the other slots' caches
(per-slot position vector).

Note: per-slot positions require per-batch-row cache offsets; for
simplicity and dry-run parity the engine recycles slots in *waves* (all
slots prefill together) unless ``continuous=True``, which tracks per-slot
positions host-side and re-prefills individual slots.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import init_caches
from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram
from repro.parallel.api import ParallelConfig
from repro.train.step import make_serve_step


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # lifecycle timestamps (microseconds, perf_counter epoch), recorded
    # unconditionally -- latency accounting must not require tracing on
    t_enqueue_us: Optional[float] = None
    t_first_token_us: Optional[float] = None
    t_done_us: Optional[float] = None

    @property
    def ttft_us(self) -> Optional[float]:
        """Enqueue -> first generated token."""
        if self.t_enqueue_us is None or self.t_first_token_us is None:
            return None
        return self.t_first_token_us - self.t_enqueue_us

    @property
    def latency_us(self) -> Optional[float]:
        """Enqueue -> done."""
        if self.t_enqueue_us is None or self.t_done_us is None:
            return None
        return self.t_done_us - self.t_enqueue_us


class Engine:
    def __init__(self, cfg: ModelConfig, pc: ParallelConfig, mesh, params, *,
                 batch_slots: int = 4, max_len: int = 256,
                 rolling: bool = False, prefill_chunk: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 tuning: Optional[bool] = None):
        # ``tuning`` overrides pc.tuning for this engine: opt the serve
        # step's collectives into the measured tuning table without
        # rebuilding the ParallelConfig at every call site.
        if tuning is not None and tuning != pc.tuning:
            pc = replace(pc, tuning=tuning)
        self.cfg, self.pc, self.mesh = cfg, pc, mesh
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.rolling = rolling
        self.prefill_chunk = prefill_chunk
        self.temperature = temperature
        self.bundle = make_serve_step(cfg, pc, mesh, rolling=rolling)
        self.rng = np.random.default_rng(seed)
        # always-on request accounting (tracing adds spans on top)
        self._ttft = Histogram("ttft_us")
        self._latency = Histogram("request_latency_us")
        self._n_requests = 0
        self._n_tokens = 0
        self._n_waves = 0

    # ------------------------------------------------------------ helpers
    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(p.shape[-1], p=row)
                         for row in p], np.int32)

    def _note_tokens(self, reqs: List["Request"]):
        """Stamp first-token / done timestamps on freshly updated requests
        and fold finished ones into the always-on latency accounting."""
        now = _now_us()
        for r in reqs:
            if r.out_tokens and r.t_first_token_us is None:
                r.t_first_token_us = now
                if r.ttft_us is not None:
                    self._ttft.record(r.ttft_us)
            if r.done and r.t_done_us is None:
                r.t_done_us = now
                if r.latency_us is not None:
                    self._latency.record(r.latency_us)

    def stats(self) -> dict:
        """Always-on serving statistics (independent of tracing).

        ``ttft_us`` / ``request_latency_us`` are enqueue -> first-token
        and enqueue -> done distributions (count/mean/p50/p90/p99) over
        every request this engine has finished; ``tokens`` counts
        generated tokens.  The dict is plain JSON, merged into the
        metrics snapshot by the serving benchmarks.
        """
        return {
            "requests": self._n_requests,
            "waves": self._n_waves,
            "tokens": self._n_tokens,
            "ttft_us": self._ttft.summary(),
            "request_latency_us": self._latency.summary(),
        }

    # ------------------------------------------------------------- waves
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve requests in waves of B slots."""
        now = _now_us()
        for r in requests:
            if r.t_enqueue_us is None:
                r.t_enqueue_us = now
        self._n_requests += len(requests)
        pending = list(requests)
        while pending:
            wave, pending = pending[:self.B], pending[self.B:]
            with obs_trace.span("engine.wave", cat="serve",
                                n_requests=len(wave), queued=len(pending)):
                self._run_wave(wave)
            self._n_waves += 1
        return requests

    def _run_wave(self, wave: List[Request]):
        B = self.B
        caches = init_caches(self.cfg, self.pc, B, self.max_len,
                             rolling=self.rolling)
        # right-pad the wave to B slots with a dummy request
        reqs = wave + [Request(prompt=np.zeros(1, np.int32),
                               max_new_tokens=0)] * (B - len(wave))
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        pos = 0
        logits = None
        with obs_trace.span("engine.prefill", cat="serve", tokens=plen,
                            chunk=self.prefill_chunk):
            for lo in range(0, plen, self.prefill_chunk):
                chunk = toks[:, lo:lo + self.prefill_chunk]
                logits, caches = self.bundle.serve_step(
                    self.params, jnp.asarray(chunk), caches, jnp.int32(pos))
                pos += chunk.shape[1]
            nxt = self._sample(np.asarray(logits[:, -1], np.float32))
        max_new = max(r.max_new_tokens for r in reqs)
        with obs_trace.span("engine.decode", cat="serve",
                            max_new=max_new) as sp:
            for t in range(max_new):
                for i, r in enumerate(reqs):
                    if not r.done and t < r.max_new_tokens:
                        r.out_tokens.append(int(nxt[i]))
                        self._n_tokens += 1
                        if len(r.out_tokens) >= r.max_new_tokens:
                            r.done = True
                self._note_tokens(wave)
                if all(r.done or r.max_new_tokens == 0 for r in reqs):
                    sp.set(steps=t + 1)
                    break
                logits, caches = self.bundle.serve_step(
                    self.params, jnp.asarray(nxt[:, None]), caches,
                    jnp.int32(pos))
                pos += 1
                nxt = self._sample(np.asarray(logits[:, -1], np.float32))
        return reqs
