"""Batched serving engine: chunked prefill + decode with continuous
batching over fixed cache slots.

The engine owns one jitted ``serve_step`` (a shard_map program) reused for
both prefill (S_new = chunk) and decode (S_new = 1) -- prefill chunks keep
the compiled-shape set small.  Requests are multiplexed onto ``B`` cache
slots; when a sequence finishes (EOS or max tokens) its slot is handed to
the next queued request without touching the other slots' caches
(per-slot position vector).

Note: per-slot positions require per-batch-row cache offsets; for
simplicity and dry-run parity the engine recycles slots in *waves* (all
slots prefill together) unless ``continuous=True``, which tracks per-slot
positions host-side and re-prefills individual slots.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import init_caches
from repro.parallel.api import ParallelConfig
from repro.train.step import make_serve_step


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, pc: ParallelConfig, mesh, params, *,
                 batch_slots: int = 4, max_len: int = 256,
                 rolling: bool = False, prefill_chunk: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 tuning: Optional[bool] = None):
        # ``tuning`` overrides pc.tuning for this engine: opt the serve
        # step's collectives into the measured tuning table without
        # rebuilding the ParallelConfig at every call site.
        if tuning is not None and tuning != pc.tuning:
            pc = replace(pc, tuning=tuning)
        self.cfg, self.pc, self.mesh = cfg, pc, mesh
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.rolling = rolling
        self.prefill_chunk = prefill_chunk
        self.temperature = temperature
        self.bundle = make_serve_step(cfg, pc, mesh, rolling=rolling)
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------------ helpers
    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(p.shape[-1], p=row)
                         for row in p], np.int32)

    # ------------------------------------------------------------- waves
    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve requests in waves of B slots."""
        pending = list(requests)
        while pending:
            wave, pending = pending[:self.B], pending[self.B:]
            self._run_wave(wave)
        return requests

    def _run_wave(self, wave: List[Request]):
        B = self.B
        caches = init_caches(self.cfg, self.pc, B, self.max_len,
                             rolling=self.rolling)
        # right-pad the wave to B slots with a dummy request
        reqs = wave + [Request(prompt=np.zeros(1, np.int32),
                               max_new_tokens=0)] * (B - len(wave))
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        pos = 0
        logits = None
        for lo in range(0, plen, self.prefill_chunk):
            chunk = toks[:, lo:lo + self.prefill_chunk]
            logits, caches = self.bundle.serve_step(
                self.params, jnp.asarray(chunk), caches, jnp.int32(pos))
            pos += chunk.shape[1]
        nxt = self._sample(np.asarray(logits[:, -1], np.float32))
        max_new = max(r.max_new_tokens for r in reqs)
        for t in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done and t < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                    if len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
            if all(r.done or r.max_new_tokens == 0 for r in reqs):
                break
            logits, caches = self.bundle.serve_step(
                self.params, jnp.asarray(nxt[:, None]), caches,
                jnp.int32(pos))
            pos += 1
            nxt = self._sample(np.asarray(logits[:, -1], np.float32))
        return reqs
