"""Host-side paged KV-cache bookkeeping for the continuous-batching engine.

The device side is a fixed pool of equal-size KV blocks per attention
layer (:class:`repro.models.attention.PagedKV`); which physical block
backs logical block ``j`` of batch slot ``b`` is decided here, on the
host, and shipped to the step function as the ``(B, nb_max)`` block
table inside :class:`repro.models.attention.PageCtx`.

Allocation policy: a request reserves every block it can ever need
(``ceil((prompt + max_new) / block_size)``) at admission and releases
them all at retirement.  Reserving up front keeps the scheduler
deadlock-free by construction -- an admitted request can always run to
completion -- at the cost of holding a request in the queue until its
whole footprint fits (the paper-relevant part of this engine is the
decode-time collectives, not cache oversubscription).

Physical block 0 is the *garbage block*: it backs unallocated table
entries, is never handed out, and is never read back (per-row
``kv_valid`` masking stops attention at each slot's true length).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class KVBlockManager:
    """Free-list allocator over one device pool (one per DP shard).

    Invariants (checked by :meth:`check`, property-tested in
    ``tests/test_serve_scheduler.py``):

    * a physical block is owned by at most one slot at a time;
    * block 0 is never allocated;
    * ``owned + free == {1, ..., n_blocks - 1}`` at all times.
    """

    def __init__(self, n_blocks: int, block_size: int, nb_max: int,
                 n_slots: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the garbage "
                             "block)")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.nb_max = int(nb_max)
        self.n_slots = int(n_slots)
        # pop() hands out low block ids first
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {s: [] for s in range(n_slots)}
        self.table = np.zeros((n_slots, nb_max), np.int32)
        self.peak_blocks_used = 0

    # ------------------------------------------------------------ queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.block_size)

    def fits(self, n_tokens: int) -> bool:
        n = self.blocks_for(n_tokens)
        return n <= self.nb_max and n <= self.n_free

    # ------------------------------------------------------- alloc / free
    def admit(self, slot: int, n_tokens: int) -> None:
        """Reserve the full block footprint of a request entering ``slot``."""
        assert not self._owned[slot], f"slot {slot} already occupied"
        n = self.blocks_for(n_tokens)
        if n > self.nb_max:
            raise ValueError(
                f"request needs {n} blocks > nb_max={self.nb_max}")
        if n > self.n_free:
            raise RuntimeError(
                f"admit called with {self.n_free} free < {n} needed "
                f"(callers must gate on fits())")
        blocks = [self._free.pop() for _ in range(n)]
        self._owned[slot] = blocks
        self.table[slot, :n] = blocks
        self.peak_blocks_used = max(self.peak_blocks_used, self.n_used)

    def retire(self, slot: int) -> None:
        """Release every block owned by ``slot`` (request finished)."""
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self.table[slot, :] = 0

    # ---------------------------------------------------------- invariants
    def check(self) -> None:
        owned = [b for blocks in self._owned.values() for b in blocks]
        assert 0 not in owned, "garbage block handed out"
        assert 0 not in self._free, "garbage block on the free list"
        assert len(set(owned)) == len(owned), "block owned by two slots"
        assert sorted(owned + self._free) == list(range(1, self.n_blocks)), \
            "block leak: owned + free != all allocatable blocks"
        for s, blocks in self._owned.items():
            nz = self.table[s][self.table[s] != 0]
            assert list(nz) == blocks, f"table row {s} out of sync"

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "blocks_used": self.n_used,
            "peak_blocks_used": self.peak_blocks_used,
        }
