"""End-to-end parallelism equivalence (subprocess, 8 host devices).

Each check trains / decodes the same reduced model under a real
(dp, tp[, pod]) mesh and asserts bitwise-close agreement with the
single-device reference -- the strongest correctness statement we can
make about the manual-SPMD stack (TP + SP + FSDP/ZeRO + the paper's
gradient allreduce) without hardware.
"""
import os
import subprocess
import sys

import pytest

# subprocess-spawning module: serialized under pytest-xdist (loadgroup)
pytestmark = pytest.mark.xdist_group("subprocess")

_WORKER = os.path.join(os.path.dirname(__file__), "_parallel_worker.py")


def _run(which: str, devices: int = 8, timeout: int = 1200):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    res = subprocess.run([sys.executable, _WORKER, which], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, \
        f"{which} failed:\n{res.stdout[-4000:]}\n{res.stderr[-4000:]}"
    assert "ALL-OK" in res.stdout


def test_param_modes_dp_zero1_fsdp():
    _run("modes")


@pytest.mark.slow
def test_all_archs_tp2_dp2():
    _run("archs_tp")


def test_decode_under_tp():
    _run("decode")


def test_multipod_hierarchical_dp():
    _run("multipod")


def test_seq_sharded_kv_cache_decode():
    _run("seqshard")


def test_group_collectives_at_tp_boundary():
    _run("groupcoll")
