"""ExecPlan lowering tests: the dense-table executor must be bit-exact
against the symbolic numpy simulator for every (P, r, kind), including
the multi-bucket pipelined replay.

These tests run :func:`repro.core.execplan.simulate_plan`, the pure-numpy
runner over the *same* index tables the JAX executor gathers with, so the
full matrix is covered without spawning multi-device subprocesses (the
JAX side of the executor is exercised on real forced-host devices by
``tests/test_collectives_jax.py::test_execplan_8dev``).  Integer inputs
make every comparison exact (no float tolerance can hide a wrong index).
"""
import numpy as np
import pytest

from repro.core.execplan import (compile_plan, final_row_table,
                                 initial_row_table, simulate_plan)
from repro.core.schedule import (build_all_gather, build_bruck_all_gather,
                                 build_generalized, build_reduce_scatter,
                                 build_ring, max_r)
from repro.core.simulator import (simulate, simulate_all_gather,
                                  simulate_reduce_scatter)

PS = [2, 3, 4, 6, 8, 16]


def _ivecs(rng, P, m):
    return [rng.integers(-1000, 1000, m).astype(np.int64) for _ in range(P)]


# ------------------------------------------------------ full matrix, exact
@pytest.mark.parametrize("P", PS)
def test_generalized_all_r_bit_exact(P):
    rng = np.random.default_rng(P)
    for r in range(max_r(P) + 1):
        sched = build_generalized(P, r)
        for m in (1, P, 3 * P + 5):
            vecs = _ivecs(rng, P, m)
            want = simulate(sched, vecs)
            got = simulate_plan(sched, vecs)
            for d in range(P):
                assert np.array_equal(got[d], want[d]), (P, r, m, d)


@pytest.mark.parametrize("P", PS)
def test_ring_bit_exact(P):
    rng = np.random.default_rng(P)
    sched = build_ring(P)
    vecs = _ivecs(rng, P, 2 * P + 3)
    want = simulate(sched, vecs)
    got = simulate_plan(sched, vecs)
    for d in range(P):
        assert np.array_equal(got[d], want[d])


@pytest.mark.parametrize("P", PS)
def test_reduce_scatter_bit_exact(P):
    rng = np.random.default_rng(P)
    sched = build_reduce_scatter(P)
    vecs = _ivecs(rng, P, 3 * P)
    want, owners = simulate_reduce_scatter(sched, vecs)
    got = simulate_plan(sched, vecs)
    assert owners == list(range(P))
    for d in range(P):
        assert np.array_equal(got[d], want[d])


@pytest.mark.parametrize("P", PS)
@pytest.mark.parametrize("builder", [build_all_gather,
                                     build_bruck_all_gather])
def test_all_gather_kinds_bit_exact(P, builder):
    rng = np.random.default_rng(P)
    sched = builder(P)
    chunks = _ivecs(rng, P, 4)
    want = simulate_all_gather(sched, chunks)
    got = simulate_plan(sched, chunks)
    for d in range(P):
        assert np.array_equal(got[d], want[d])


# ------------------------------------------------------ bucketed pipeline
@pytest.mark.parametrize("P", [3, 6, 8])
@pytest.mark.parametrize("n_buckets", [1, 2, 4])
def test_bucketed_replay_identical_sums(P, n_buckets):
    """Splitting the message into pipelined buckets must not change a
    single bit of the result (each bucket replays the same plan on a
    disjoint slice)."""
    rng = np.random.default_rng(P * 10 + n_buckets)
    for r in (0, max_r(P)):
        sched = build_generalized(P, r)
        for m in (1, 7, 3 * P + 5):   # incl. sizes the bucket split pads
            vecs = _ivecs(rng, P, m)
            want = simulate(sched, vecs)
            got = simulate_plan(sched, vecs, n_buckets=n_buckets)
            for d in range(P):
                assert np.array_equal(got[d], want[d]), (P, r, m, d)


# ------------------------------------------------------ plan structure
def test_plan_tables_cached_per_schedule():
    """compile_plan and the row tables are lru-cached on the schedule
    object: repeated traces of the same collective reuse the exact same
    table objects instead of re-running O(P^2) Python loops."""
    sched = build_generalized(12, 1)
    assert compile_plan(sched) is compile_plan(sched)
    assert initial_row_table(sched) is initial_row_table(sched)
    assert final_row_table(sched) is final_row_table(sched)
    assert not initial_row_table(sched).flags.writeable


def test_plan_folds_bookkeeping_steps():
    """Ring's trailing zero-communication row compaction is folded into
    the final gather table, not executed."""
    P = 7
    sched = build_ring(P)
    plan = compile_plan(sched)
    n_comm = sum(1 for st in sched.steps if st.n_tx)
    assert plan.n_steps == n_comm == 2 * (P - 1)
    assert all(st.n_tx for st in plan.steps)


def test_plan_traffic_matches_schedule():
    """The lowering preserves the schedule's exact per-step traffic --
    the quantities the cost model charges."""
    for P in (5, 8, 12):
        for r in range(max_r(P) + 1):
            sched = build_generalized(P, r)
            plan = compile_plan(sched)
            assert sum(st.n_tx for st in plan.steps) == sched.units_sent
            assert sum(st.n_adds for st in plan.steps) == sched.units_reduced


def test_final_rows_complete_for_allreduce():
    for P in (4, 6):
        plan = compile_plan(build_generalized(P, 1))
        assert (plan.final_rows >= 0).all()
        plan = compile_plan(build_reduce_scatter(P))
        # reduce-scatter: exactly one materialized chunk per device --
        # device d owns chunk d (canonical place-0 layout) at storage 0
        for d in range(P):
            col = plan.final_rows[:, d]
            assert (col >= 0).sum() == 1
            assert col[d] == 0
