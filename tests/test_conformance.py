"""Property-based conformance harness for the generalized collective family.

Every drawn case fixes one point of the family -- process count P
(non-powers-of-two weighted), step trade-off r, message length (ragged
sizes included), dtype, bucket count, and combine monoid -- and asserts
the whole verification chain bit-exactly:

    symbolic simulator  ==  lowered ExecPlan replay  ==  ground truth

where the ground truth is exactly what the matching ``lax`` collective
computes (psum / pmax / pmin / psum-over-P / all_to_all); the *actual*
``lax`` primitives are exercised against the same executors on real
devices by ``test_conformance_vs_lax_16dev`` below (subprocess with 16
forced host devices, meshes over the first P) for every acceptance P.

Failing cases shrink (see ``_hypothesis_compat``) and report a
replayable repr: the drawn parameters plus ``schedule_summary`` of the
offending compiled Schedule appear in the assertion message.

The negative half mutates verified schedules (dropped step, swapped
ppermute shift, wrong chunk widths) and asserts the machinery *catches*
the corruption -- structural verification, a raised error, or a
detected mis-reduction -- rather than silently returning wrong numbers.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core.execplan import (compile_a2a_plan, simulate_a2a,  # noqa: E402
                                 simulate_plan)
from repro.core.monoid import (MAX, MEAN, MIN, SUM, premul_sum,  # noqa: E402
                               resolve_combine)
from repro.core.schedule import (InvalidScheduleError, Schedule,  # noqa: E402
                                 ShapeError, _verify, build_dual_root,
                                 build_generalized, build_ring,
                                 build_sorted_generalized, build_traff_rounds,
                                 max_r, ragged_sizes, schedule_summary)
from repro.core.simulator import simulate  # noqa: E402

# non-powers-of-two deliberately over-represented: they are the paper's
# headline case and the ragged split's hardest geometry
PS = [2, 3, 3, 5, 5, 6, 6, 7, 7, 9, 10, 11, 11, 12, 13, 13, 14, 15, 4, 8, 16]

MONOIDS = [SUM, MAX, MIN, MEAN, premul_sum(0.5)]

DTYPES = [np.int32, np.int64, np.float32]


def _draw_vectors(data, P, m, dtype):
    """Integer-valued inputs: every monoid reduction is then exact in
    every dtype (f32 holds the magnitudes involved exactly), so all
    comparisons below are ==, never allclose."""
    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    rng = np.random.default_rng(seed)
    return [rng.integers(-1000, 1000, (m,)).astype(dtype) for _ in range(P)]


def _reference(monoid, vectors):
    stack = np.stack(vectors)
    if monoid.pre_scale is not None and stack.dtype.kind != "f":
        # premul on ints: scale in float so the reference matches the
        # executor's elementwise multiply semantics
        stack = stack.astype(np.float64)
    return monoid.reference(stack)


@settings(max_examples=120, deadline=None)
@given(data=st.data())
def test_conformance_allreduce_family(data):
    """simulate == simulate_plan == monoid ground truth, bit for bit."""
    P = data.draw(st.sampled_from(PS), label="P")
    kind = data.draw(st.sampled_from(["generalized", "generalized", "ring",
                                      "sorted", "traff_rounds", "dual_root"]),
                     label="kind")
    r = data.draw(st.integers(0, max_r(P)), label="r") \
        if kind in ("generalized", "sorted") else 0
    m = data.draw(st.integers(1, 4 * P + 7), label="m")
    dtype = data.draw(st.sampled_from(DTYPES), label="dtype")
    n_buckets = data.draw(st.sampled_from([1, 2, 4]), label="n_buckets")
    monoid = data.draw(st.sampled_from(MONOIDS), label="monoid")
    if monoid.pre_scale is not None and np.dtype(dtype).kind != "f":
        dtype = np.float32        # premul of ints would truncate
    if kind == "sorted":
        # a drawn relabeling: the arrival-sorted kind must be bit-exact
        # under *every* rank order, not just the model's pick
        order = list(range(P))
        seed = data.draw(st.integers(0, 2**31 - 1), label="order_seed")
        np.random.default_rng(seed).shuffle(order)
        sched = build_sorted_generalized(P, r, tuple(order))
    elif kind == "ring":
        sched = build_ring(P)
    elif kind == "traff_rounds":
        sched = build_traff_rounds(P)
    elif kind == "dual_root":
        sched = build_dual_root(P)
    else:
        sched = build_generalized(P, r)
    vectors = _draw_vectors(data, P, m, dtype)
    want = _reference(monoid, vectors)
    ctx = (f"case P={P} kind={kind} r={r} m={m} dtype={np.dtype(dtype)} "
           f"n_buckets={n_buckets} monoid={monoid.name} "
           f"sched={schedule_summary(sched)}")

    prepped = [np.asarray(monoid.prepare(v.astype(want.dtype), P))
               for v in vectors]
    sym = simulate(sched, prepped, op=monoid.np_op)
    plan = simulate_plan(sched, prepped, n_buckets=n_buckets,
                         op=monoid.np_op)
    for d in range(P):
        got_sym = monoid.finalize(sym[d], P)
        got_plan = monoid.finalize(plan[d], P)
        assert got_sym.shape == want.shape, ctx
        assert (got_sym == want).all(), f"symbolic simulator diverged; {ctx}"
        assert (got_plan == want).all(), f"ExecPlan lowering diverged; {ctx}"


@settings(max_examples=80, deadline=None)
@given(data=st.data())
def test_conformance_all_to_all(data):
    """simulate_a2a (both plan kinds) == the transpose lax.all_to_all
    computes, for every P and multiplier; non-divisible lengths raise."""
    P = data.draw(st.sampled_from(PS), label="P")
    kind = data.draw(st.sampled_from(["direct", "bruck"]), label="kind")
    mult = data.draw(st.integers(1, 5), label="mult")
    dtype = data.draw(st.sampled_from(DTYPES), label="dtype")
    m = P * mult
    vectors = _draw_vectors(data, P, m, dtype)
    got = simulate_a2a(vectors, kind)
    stack = np.stack(vectors).reshape(P, P, mult)
    ctx = f"case P={P} kind={kind} mult={mult} dtype={np.dtype(dtype)}"
    for d in range(P):
        want = stack[:, d, :].reshape(-1)       # chunk d of every source
        assert (got[d] == want).all(), f"all-to-all mispermuted; {ctx}"
    if P > 1:
        with pytest.raises(ShapeError):
            simulate_a2a([v[:-1] for v in vectors], kind)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_monoid_laws(data):
    """Associativity + identity of every built-in on drawn integers."""
    monoid = data.draw(st.sampled_from([SUM, MAX, MIN]), label="monoid")
    a = data.draw(st.integers(-10**6, 10**6), label="a")
    b = data.draw(st.integers(-10**6, 10**6), label="b")
    c = data.draw(st.integers(-10**6, 10**6), label="c")
    op = monoid.np_op
    x, y, z = (np.int64(v) for v in (a, b, c))
    assert op(op(x, y), z) == op(x, op(y, z))
    e = monoid.identity(np.int64)
    assert op(x, e) == x and op(e, x) == x


def test_sorted_schedule_acceptance_sweep():
    """Acceptance criterion: the skew-sorted kind, under the cost model's
    own order pick *and* adversarial orders, is bit-exact vs the symbolic
    simulator for every acceptance P -- and structurally identical (same
    steps, traffic, multiplicity) to the plain generalized schedule it
    relabels."""
    from repro.core.cost_model import PAPER_10GE, choose_arrival_order
    rng = np.random.default_rng(7)
    for P in (2, 3, 5, 6, 7, 8):
        deltas = [float(x) for x in rng.integers(0, 400, P)]
        for r in range(max_r(P) + 1):
            order, _ = choose_arrival_order(P, r, 4096, PAPER_10GE, deltas)
            adversarial = tuple(reversed(range(P)))
            for o in (order, adversarial):
                sched = build_sorted_generalized(P, r, o)
                base = build_generalized(P, r)
                assert sched.kind == "sorted" and sched.s == base.s
                assert [st.tx_rows for st in sched.steps] \
                    == [st.tx_rows for st in base.steps]
                m = 3 * P + 2              # ragged on every P
                vecs = [np.arange(m, dtype=np.int64) * (d + 1) + d
                        for d in range(P)]
                want = np.stack(vecs).sum(0)
                for out in simulate(sched, vecs):
                    assert (out == want).all(), (P, r, o)
                for out in simulate_plan(sched, vecs, n_buckets=2):
                    assert (out == want).all(), (P, r, o, "plan")


def test_new_family_acceptance_sweep():
    """Acceptance criterion for the Traff-rounds and dual-root kinds:
    bit-exact vs the symbolic simulator oracle for every acceptance P
    (primes included), divisible and ragged sizes, every bucket count --
    and the structural claims hold: traff_rounds runs 2*ceil(lg P)
    rounds at 2*(P-1) chunk-units (the optimal non-pipelined figures,
    arXiv:2410.14234), dual_root runs one round fewer with two result
    copies after reduction (arXiv:2109.12626)."""
    import math
    for P in (2, 3, 5, 6, 7, 8, 16):
        K = math.ceil(math.log2(P))
        traff = build_traff_rounds(P)
        assert traff.n_steps == 2 * K
        assert traff.units_sent == 2 * (P - 1)
        assert traff.units_reduced == P - 1
        dual = build_dual_root(P)
        assert dual.n_steps == 2 * K - 1
        assert dual.s == 2
        for sched in (traff, dual):
            assert sorted(sl.place for sl in sched.final_slots) \
                == list(range(P))
            for m in (1, max(P - 1, 1), P, 3 * P + 2):
                vecs = [np.arange(m, dtype=np.int64) * (d + 2) - d
                        for d in range(P)]
                want = np.stack(vecs).sum(0)
                for out in simulate(sched, vecs):
                    assert (out == want).all(), (P, sched.kind, m)
                for nb in (1, 2, 4):
                    for out in simulate_plan(sched, vecs, n_buckets=nb):
                        assert (out == want).all(), (P, sched.kind, m, nb)


def test_new_family_edge_cases():
    """Degenerate corners of the new kinds, bit-exact vs oracles:
    P=1 is a no-op, P=2 collapses to one exchange (dual_root) / two
    rounds (traff_rounds), m < P rides the ragged split with zero-width
    chunks, and dual_root with n_buckets=1 (pipelining disabled) matches
    the symbolic simulator exactly."""
    # P=1: empty step list, input passes through untouched
    for build in (build_traff_rounds, build_dual_root):
        s1 = build(1)
        assert s1.n_steps == 0
        v = [np.arange(5, dtype=np.int64)]
        assert (simulate(s1, v)[0] == v[0]).all()
        assert (simulate_plan(s1, v)[0] == v[0]).all()
    # P=2 degenerate rounds: dual_root needs a single exchange (both
    # "roots" are the two devices), traff_rounds one RS + one AG round
    assert build_dual_root(2).n_steps == 1
    assert build_traff_rounds(2).n_steps == 2
    # m < P: some chunks are zero-width; still exact for every kind
    for P in (5, 7, 8):
        for m in (1, 2, P - 1):
            vecs = [np.full((m,), d + 1, dtype=np.int64) for d in range(P)]
            want = np.stack(vecs).sum(0)
            for build in (build_traff_rounds, build_dual_root):
                sched = build(P)
                for out in simulate(sched, vecs):
                    assert (out == want).all(), (P, m, sched.kind)
                for out in simulate_plan(sched, vecs, n_buckets=1):
                    assert (out == want).all(), (P, m, sched.kind, "plan")
    # dual_root with pipelining disabled (n_buckets=1) on a non-trivial
    # ragged size: the unbucketed replay is the plain schedule semantics
    sched = build_dual_root(7)
    rng = np.random.default_rng(3)
    vecs = [rng.integers(-1000, 1000, (23,)).astype(np.int64)
            for _ in range(7)]
    want = np.stack(vecs).sum(0)
    got_sym = simulate(sched, vecs)
    got_plan = simulate_plan(sched, vecs, n_buckets=1)
    for d in range(7):
        assert (got_sym[d] == want).all()
        assert (got_plan[d] == want).all()


def test_conformance_case_count():
    """The harness above draws >= 200 cases per run (acceptance floor)."""
    drawn = 120 + 80 + 40
    assert drawn >= 200


# ---------------------------------------------------------------------------
#  negative / mutation tests: corrupted schedules must be *caught*
# ---------------------------------------------------------------------------

def _caught_by_machinery(mutated: Schedule, P: int) -> bool:
    """A corrupted schedule counts as caught when the structural verifier
    rejects it, the simulator raises, or the simulated result visibly
    differs from the ground truth -- silence with wrong numbers is the
    only failure."""
    try:
        _verify(mutated)
        verified = True
    except InvalidScheduleError:
        return True
    assert verified
    rng = np.random.default_rng(0)
    vectors = [rng.integers(-1000, 1000, (3 * P + 1,)).astype(np.int64)
               for _ in range(P)]
    want = np.stack(vectors).sum(0)
    try:
        out = simulate(mutated, vectors)
    except Exception:
        return True
    return any(o.shape != want.shape or not (o == want).all() for o in out)


@pytest.mark.parametrize("P", [4, 6, 8])
def test_mutation_dropped_step(P):
    sched = build_generalized(P, 1)
    mutated = dataclasses.replace(sched, steps=sched.steps[:-1])
    assert _caught_by_machinery(mutated, P), \
        "dropping the last step went unnoticed"
    mutated = dataclasses.replace(sched, steps=sched.steps[1:])
    assert _caught_by_machinery(mutated, P), \
        "dropping the first step went unnoticed"


@pytest.mark.parametrize("P", [4, 6, 8])
def test_mutation_swapped_ppermute(P):
    """Perturbing one step's group element (the ppermute pairing) must be
    caught for every step of the schedule."""
    sched = build_generalized(P, 1)
    for k, step in enumerate(sched.steps):
        wrong = dataclasses.replace(step,
                                    shift=(step.shift + 1) % P or 1)
        steps = sched.steps[:k] + (wrong,) + sched.steps[k + 1:]
        mutated = dataclasses.replace(sched, steps=steps)
        assert _caught_by_machinery(mutated, P), \
            f"swapped ppermute at step {k} went unnoticed"


@pytest.mark.parametrize("P", [4, 7])
def test_mutation_wrong_chunk_size(P):
    """Chunk geometry violations surface as raised errors, not silent
    mis-reductions: per-device vectors of inconsistent lengths cannot be
    combined, and the typed ShapeError carries the offending sizes."""
    sched = build_generalized(P, 0)
    rng = np.random.default_rng(1)
    vectors = [rng.integers(0, 10, (2 * P,)).astype(np.int64)
               for _ in range(P)]
    vectors[1] = vectors[1][:-3]          # one device disagrees on m
    with pytest.raises(ShapeError) as ei:
        simulate(sched, vectors)
    assert ei.value.actual == (2 * P - 3,)
    with pytest.raises(ShapeError) as ei:
        ragged_sizes(-1, P)
    assert ei.value.actual == -1
    with pytest.raises(ValueError):
        compile_a2a_plan(P, "sideways")


# ---------------------------------------------------------------------------
#  combine= argument surface
# ---------------------------------------------------------------------------

def test_premul_int_truncation_refused():
    """A fractional premul factor on an integer buffer would silently
    multiply by 0 in the input dtype -- the bookend must refuse, loudly,
    at prepare time (the library path, not just the harness's dtype
    forcing)."""
    with pytest.raises(TypeError, match="truncate"):
        premul_sum(0.5).prepare(np.arange(4, dtype=np.int32), 2)
    # integral factors cast exactly and stay allowed
    out = premul_sum(2.0).prepare(np.arange(4, dtype=np.int32), 2)
    assert out.dtype == np.int32 and (out == [0, 2, 4, 6]).all()


def test_resolve_combine_surface():
    assert resolve_combine("sum")[0] is SUM
    assert resolve_combine("mean")[0] is MEAN
    assert resolve_combine("auto") == (SUM, "auto")
    assert resolve_combine("add") == (SUM, "op")
    assert resolve_combine("max:pallas") == (MAX, "pallas")
    m, impl = resolve_combine(lambda a, b: a + b)
    assert m.kind == "custom" and impl == "op"
    with pytest.raises(ValueError):
        resolve_combine("median")
    with pytest.raises(TypeError):
        resolve_combine(3)
    assert MIN.identity(np.float32) == np.finfo(np.float32).max


# ---------------------------------------------------------------------------
#  the real lax references on real devices (subprocess, 16 host devices)
# ---------------------------------------------------------------------------

_WORKER = os.path.join(os.path.dirname(__file__), "_multidevice_worker.py")


@pytest.mark.xdist_group("subprocess")
def test_conformance_vs_lax_16dev():
    """max/min/mean allreduce and schedule-driven all_to_all, bit-exact
    vs lax.pmax/pmin/psum/all_to_all for P in {2,3,5,6,7,8,16} incl.
    ragged sizes (acceptance criterion)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, _WORKER, "conformance"], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"worker failed:\n{res.stdout}\n{res.stderr}"
    assert "ALL-OK" in res.stdout, res.stdout
    for P in (2, 3, 5, 6, 7, 8, 16):
        assert f"ok conformance P={P}" in res.stdout, res.stdout
