"""Measured-autotuning subsystem: cache persistence, fingerprint gating,
corrupt-file recovery, nearest-size interpolation, and the
measurement-beats-model wiring of ``autotune.choose``."""

import json

import pytest

from repro.core.autotune import choose
from repro.core.cost_model import HOST_CPU
from repro.tuning import (
    Fingerprint,
    Measurement,
    TuningCache,
    best_measured,
    current_fingerprint,
    policy,
)
from repro.tuning import cache as cache_mod

FP = Fingerprint(
    platform="cpu",
    device_kind="cpu",
    device_count=8,
    jax_version="0.0.test",
    package_version="0.0.test",
)
OTHER_FP = Fingerprint(
    platform="tpu",
    device_kind="v5e",
    device_count=256,
    jax_version="0.0.test",
    package_version="0.0.test",
)


def meas(nbytes, kind, r, b, us, P=8):
    return Measurement(P=P, nbytes=nbytes, kind=kind, r=r, n_buckets=b, us=us)


@pytest.fixture
def tuned_env(tmp_path, monkeypatch):
    """Point the tuning subsystem at a throwaway cache file and reset all
    in-process caches on entry and exit."""
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    monkeypatch.delenv("REPRO_TUNING", raising=False)
    policy.invalidate()
    yield path
    policy.invalidate()


# ---------------------------------------------------------------------------
#  cache persistence
# ---------------------------------------------------------------------------


def test_cache_roundtrip(tuned_env):
    c = TuningCache.load(tuned_env)
    c.record(FP, meas(1 << 20, "generalized", 1, 2, 123.4))
    c.record(FP, meas(1 << 20, "ring", 0, 1, 456.7))
    saved = c.save()
    assert saved == tuned_env and tuned_env.exists()
    # atomic write leaves no temp droppings
    assert list(tuned_env.parent.glob("*.tmp")) == []

    back = TuningCache.load(tuned_env)
    assert back.n_measurements == 2
    assert sorted(back.lookup(FP, 8), key=lambda m: m.us) == sorted(
        c.lookup(FP, 8), key=lambda m: m.us
    )


def test_record_replaces_same_grid_point(tuned_env):
    c = TuningCache.load(tuned_env)
    c.record(FP, meas(1 << 20, "ring", 0, 1, 100.0))
    c.record(FP, meas(1 << 20, "ring", 0, 1, 50.0))
    assert c.n_measurements == 1
    assert c.lookup(FP, 8)[0].us == 50.0


def test_fingerprint_mismatch_invalidates(tuned_env):
    c = TuningCache.load(tuned_env)
    c.record(FP, meas(1 << 20, "ring", 0, 1, 100.0))
    c.save()
    back = TuningCache.load(tuned_env)
    assert back.lookup(OTHER_FP, 8) == []
    assert policy.lookup(8, 1 << 20, fingerprint=OTHER_FP) is None
    assert policy.lookup(8, 1 << 20, fingerprint=FP) is not None


@pytest.mark.parametrize(
    "content",
    [
        "not json at all {",
        '{"version": 1, "entries": {"x": {"fingerpr',  # truncated mid-write
        '{"version": 99, "entries": {}}',  # future schema
        '{"version": 1, "entries": {"k": {"fingerprint": {"bogus": 1},'
        ' "measurements": []}}}',  # wrong shape
        "[]",  # wrong top-level type
    ],
)
def test_corrupt_cache_recovers_empty(tuned_env, content):
    tuned_env.write_text(content)
    c = TuningCache.load(tuned_env)
    assert c.n_measurements == 0
    # the corrupt file was quarantined, so the next save starts clean
    assert not tuned_env.exists()
    assert tuned_env.with_suffix(".json.corrupt").exists()
    c.record(FP, meas(1 << 20, "ring", 0, 1, 1.0))
    c.save()
    assert TuningCache.load(tuned_env).n_measurements == 1


def test_cache_version_field_written(tuned_env):
    c = TuningCache.load(tuned_env)
    c.record(FP, meas(1 << 20, "ring", 0, 1, 1.0))
    c.save()
    raw = json.loads(tuned_env.read_text())
    assert raw["version"] == cache_mod.SCHEMA_VERSION


# ---------------------------------------------------------------------------
#  policy: nearest-size interpolation
# ---------------------------------------------------------------------------


def test_interpolation_picks_crossing_winner():
    # candidate A wins at 64 KiB, candidate B wins at 4 MiB; the crossover
    # sits between, so the interpolated argmin flips with the query size
    rows = [
        meas(64 << 10, "generalized", 3, 1, 10.0),
        meas(4 << 20, "generalized", 3, 1, 500.0),
        meas(64 << 10, "ring", 0, 1, 100.0),
        meas(4 << 20, "ring", 0, 1, 120.0),
    ]
    small = best_measured(rows, 80 << 10)
    big = best_measured(rows, 3 << 20)
    assert (small.kind, small.r) == ("generalized", 3)
    assert big.kind == "ring"
    # measured cost is interpolated, not copied from an endpoint
    mid = best_measured(rows, 512 << 10)
    assert 10e-6 < mid.cost < 500e-6
    assert mid.source == "measured"


def test_extrapolation_bounded():
    rows = [meas(64 << 10, "ring", 0, 1, 10.0)]
    # within 4x of the only measured size: nearest measurement answers
    assert best_measured(rows, 128 << 10) is not None
    # far outside: the table has no opinion
    assert best_measured(rows, 1 << 30) is None
    assert best_measured(rows, 1 << 10) is None


# ---------------------------------------------------------------------------
#  choose() wiring: measurement-backed vs analytic fallback
# ---------------------------------------------------------------------------


def _flip_cache(path, nbytes=1 << 20):
    """Write a synthetic cache whose winner differs from the model pick."""
    model = choose(8, nbytes, HOST_CPU, tune=False)
    flipped_kind = "ring" if model.kind != "ring" else "generalized"
    flipped_r = 0 if model.kind != "ring" else 2
    c = TuningCache.load(path)
    fp = current_fingerprint()
    for size in (nbytes // 4, nbytes * 4):
        c.record(fp, meas(size, flipped_kind, flipped_r, 2, us=10.0))
        c.record(fp, meas(size, model.kind, model.r, model.n_buckets, us=900.0))
    c.save()
    policy.invalidate()
    return model, flipped_kind, flipped_r


def test_synthetic_cache_flips_winner(tuned_env):
    model, fkind, fr = _flip_cache(tuned_env)
    tuned = choose(8, 1 << 20, HOST_CPU, tune=True)
    assert tuned.source == "measured"
    assert (tuned.kind, tuned.r, tuned.n_buckets) == (fkind, fr, 2)
    assert (tuned.kind, tuned.r) != (model.kind, model.r)
    # tune=False keeps the analytic answer
    again = choose(8, 1 << 20, HOST_CPU, tune=False)
    assert again.source == "model"
    assert (again.kind, again.r) == (model.kind, model.r)


def test_choose_falls_back_when_cache_empty(tuned_env):
    assert not tuned_env.exists()
    ch = choose(8, 1 << 20, HOST_CPU, tune=True)
    assert ch.source == "model"


def test_choose_falls_back_outside_measured_range(tuned_env):
    _flip_cache(tuned_env)
    far = choose(8, 1 << 30, HOST_CPU, tune=True)
    assert far.source == "model"


def test_allow_ring_respected_when_tuned(tuned_env):
    # the cache says ring is fastest, but the caller excluded ring: the
    # measured answer must honor the schedule-family restriction
    c = TuningCache.load(tuned_env)
    fp = current_fingerprint()
    for size in (256 << 10, 4 << 20):
        c.record(fp, meas(size, "ring", 0, 1, us=1.0))
        c.record(fp, meas(size, "generalized", 1, 1, us=5.0))
    c.save()
    policy.invalidate()
    ch = choose(8, 1 << 20, HOST_CPU, allow_ring=False, tune=True)
    assert ch.source == "measured"
    assert ch.kind == "generalized"
    assert choose(8, 1 << 20, HOST_CPU, allow_ring=True, tune=True).kind == "ring"


def test_env_var_opt_in(tuned_env, monkeypatch):
    _flip_cache(tuned_env)
    # default (no env, tune=None) stays analytic
    assert choose(8, 1 << 20, HOST_CPU).source == "model"
    monkeypatch.setenv("REPRO_TUNING", "1")
    assert choose(8, 1 << 20, HOST_CPU).source == "measured"


def test_choose_collective_consults_policy(tuned_env):
    from repro.topology import MULTI_POD_2X256, choose_collective, v5e_pod

    _flip_cache(tuned_env)
    flat = choose_collective(v5e_pod(8), 1 << 20, tune=True)
    assert flat.source == "measured"
    assert flat.kind in ("flat-ring", "flat-generalized")
    # the model's verdict is untouched without tuning
    assert choose_collective(v5e_pod(8), 1 << 20, tune=False).source == "model"
    # multi-level fabrics have no compatible flat measurement: model decides
    hier = choose_collective(MULTI_POD_2X256, 1 << 20, tune=True)
    assert hier.source == "model"


@pytest.mark.xdist_group("subprocess")
def test_tuned_choice_executes_correctly(tuned_env, tmp_path):
    """End to end: a measured Choice coming out of the cache drives the
    real shard_map executor and still reduces correctly (2 forced host
    devices; the synthetic cache pins an off-model candidate)."""
    import os
    import subprocess
    import sys

    import jax

    from repro.tuning.cache import _package_version

    fp = Fingerprint(
        platform="cpu",
        device_kind="cpu",
        device_count=2,
        jax_version=jax.__version__,
        package_version=_package_version(),
    )
    c = TuningCache.load(tuned_env)
    for size in (64 << 10, 1 << 20):
        c.record(fp, meas(size, "ring", 0, 1, us=1.0, P=2))
    c.save()

    prog = """
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.allreduce import allreduce_tree
from repro.core.autotune import choose

ch = choose(2, 256 << 10, tune=True)
assert ch.source == "measured" and ch.kind == "ring", ch
mesh = jax.make_mesh((2,), ("data",))
x = np.random.default_rng(0).standard_normal((2, 65536)).astype(np.float32)
fn = jax.jit(shard_map(
    lambda v: allreduce_tree(v[0], "data", tune=True)[None],
    mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
ref = jax.jit(shard_map(
    lambda v: lax.psum(v, "data"), mesh=mesh,
    in_specs=P("data", None), out_specs=P(None, None)))
np.testing.assert_allclose(np.asarray(fn(x))[0], np.asarray(ref(x))[0],
                           rtol=1e-6, atol=1e-6)
print("TUNED_EXEC_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_TUNING_CACHE"] = str(tuned_env)
    # the child doesn't go through pytest's pythonpath handling
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", prog], env=env, capture_output=True, text=True
    )
    assert res.returncode == 0, res.stderr[-4000:]
    assert "TUNED_EXEC_OK" in res.stdout


def test_measure_grid_prunes_tiny_buckets():
    from repro.tuning import candidate_grid

    grid = candidate_grid(8, 64 << 10, smoke=True)
    assert all(b == 1 for _, _, b in grid)  # 8 KiB chunks: no pipelining
    grid_big = candidate_grid(8, 4 << 20, smoke=False)
    assert {b for _, _, b in grid_big} == {1, 2, 4}
    kinds = {(k, r) for k, r, _ in grid_big}
    assert ("ring", 0) in kinds and ("generalized", 0) in kinds


# ---------------------------------------------------------------------------
#  schema v2: per-rep timings, noise, arrival skew
# ---------------------------------------------------------------------------


def test_measurement_v2_fields_roundtrip(tuned_env):
    m = Measurement(
        P=8,
        nbytes=1 << 20,
        kind="generalized",
        r=1,
        n_buckets=2,
        us=100.0,
        reps_us=(110.0, 100.0, 130.0),
        noise=0.3,
        skew_us=42.5,
    )
    c = TuningCache.load(tuned_env)
    c.record(FP, m)
    c.save()
    back = TuningCache.load(tuned_env).lookup(FP, 8)[0]
    assert back.reps_us == (110.0, 100.0, 130.0)
    assert back.noise == 0.3
    assert back.skew_us == 42.5
    assert json.loads(tuned_env.read_text())["version"] == 2


def test_cache_v1_file_loads_with_defaults(tuned_env):
    """A v1 cache (pre reps/noise/skew) must load, not quarantine."""
    c = TuningCache.load(tuned_env)
    c.record(FP, meas(1 << 20, "ring", 0, 1, 77.0))
    c.save()
    raw = json.loads(tuned_env.read_text())
    raw["version"] = 1
    for entry in raw["entries"].values():
        for m in entry["measurements"]:
            for k in ("reps_us", "noise", "skew_us"):
                m.pop(k, None)
    tuned_env.write_text(json.dumps(raw))
    back = TuningCache.load(tuned_env)
    assert back.n_measurements == 1
    m = back.lookup(FP, 8)[0]
    assert m.us == 77.0
    assert m.reps_us is None and m.noise == 0.0 and m.skew_us is None
    # re-saving migrates the file to the current schema
    back.save()
    assert json.loads(tuned_env.read_text())["version"] == cache_mod.SCHEMA_VERSION


def test_unstable_cells_flags_noisy_measurements():
    from repro.tuning.policy import NOISE_THRESHOLD, unstable_cells

    quiet = Measurement(
        P=8, nbytes=1 << 20, kind="ring", r=0, n_buckets=1, us=100.0, noise=0.05
    )
    noisy = Measurement(
        P=8,
        nbytes=1 << 20,
        kind="generalized",
        r=2,
        n_buckets=2,
        us=50.0,
        reps_us=(50.0, 80.0),
        noise=0.6,
    )
    noisier = Measurement(
        P=8, nbytes=64 << 10, kind="ring", r=0, n_buckets=1, us=10.0, noise=0.9
    )
    out = unstable_cells([quiet, noisy, noisier])
    assert [c["noise"] for c in out] == [0.9, 0.6]  # worst first
    assert out[1]["kind"] == "generalized" and out[1]["reps_us"] == [50.0, 80.0]
    assert unstable_cells([quiet]) == []
    assert 0.0 < NOISE_THRESHOLD < 1.0


# ---------------------------------------------------------------------------
#  arrival deltas: persistence and the skew-aware choose() feed
# ---------------------------------------------------------------------------


def test_deltas_roundtrip_and_arrival_deltas(tuned_env):
    deltas = (0.0, 12.0, 3.0, 250.0, 1.0, 0.5, 9.0, 40.0)
    m = Measurement(
        P=8,
        nbytes=1 << 20,
        kind="generalized",
        r=1,
        n_buckets=1,
        us=100.0,
        skew_us=250.0,
        deltas_us=deltas,
    )
    c = TuningCache.load(tuned_env)
    c.record(FP, m)
    c.save()
    assert TuningCache.load(tuned_env).lookup(FP, 8)[0].deltas_us == deltas
    policy.invalidate()
    # nearest-size answer, within the extrapolation cap
    assert policy.arrival_deltas(8, 1 << 20, fingerprint=FP) == deltas
    assert policy.arrival_deltas(8, 2 << 20, fingerprint=FP) == deltas
    # beyond the cap / wrong operator / wrong P: no opinion
    assert policy.arrival_deltas(8, 1 << 30, fingerprint=FP) is None
    assert policy.arrival_deltas(8, 1 << 20, op="max", fingerprint=FP) is None
    assert policy.arrival_deltas(4, 1 << 20, fingerprint=FP) is None


def test_arrival_deltas_ignores_rows_without_profile(tuned_env):
    c = TuningCache.load(tuned_env)
    c.record(FP, meas(1 << 20, "ring", 0, 1, 50.0))  # scalar-only row
    c.save()
    policy.invalidate()
    assert policy.arrival_deltas(8, 1 << 20, fingerprint=FP) is None


def test_skewed_cells_flags_heavy_skew():
    from repro.tuning.policy import SKEW_THRESHOLD_US, skewed_cells

    calm = Measurement(
        P=8, nbytes=1 << 20, kind="ring", r=0, n_buckets=1, us=100.0, skew_us=5.0
    )
    unprobed = meas(1 << 20, "generalized", 0, 1, 90.0)
    skewed = Measurement(
        P=8,
        nbytes=1 << 20,
        kind="generalized",
        r=2,
        n_buckets=1,
        us=50.0,
        skew_us=400.0,
        deltas_us=(0.0,) * 7 + (400.0,),
    )
    worse = Measurement(
        P=8, nbytes=64 << 10, kind="ring", r=0, n_buckets=1, us=10.0, skew_us=900.0
    )
    out = skewed_cells([calm, unprobed, skewed, worse])
    assert [c["skew_us"] for c in out] == [900.0, 400.0]  # worst first
    assert out[1]["deltas_us"] == [0.0] * 7 + [400.0]
    assert skewed_cells([calm, unprobed]) == []
    assert SKEW_THRESHOLD_US > 0


# ---------------------------------------------------------------------------
#  CI family-coverage gate (benchmarks/check_regression.py --families)
# ---------------------------------------------------------------------------


def _tuning_payload(path, kinds_per_row):
    """Write a minimal results/tuning.json-shaped payload."""
    payload = {
        "results": [
            {
                "label": f"row{i}",
                "measurements": [
                    {"P": 8, "nbytes": 1 << 20, "kind": k, "r": 0, "n_buckets": 1}
                    for k in kinds
                ],
            }
            for i, kinds in enumerate(kinds_per_row)
        ]
    }
    path.write_text(json.dumps(payload))
    return path


def test_family_gate_passes_and_fails(tmp_path):
    """The --families gate holds the measured family set: a doctored
    baseline carrying a family the current run never measures must exit
    2 (MISWIRED) and name the missing family; full coverage passes."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
    from check_regression import load_families, main

    base = _tuning_payload(
        tmp_path / "base.json",
        [["generalized", "ring"], ["traff_rounds", "dual_root"]],
    )
    cur_ok = _tuning_payload(
        tmp_path / "cur_ok.json",
        [["generalized", "ring", "traff_rounds", "dual_root", "extra_kind"]],
    )
    assert load_families(base) == {"generalized", "ring", "traff_rounds", "dual_root"}

    verdict = tmp_path / "verdict.json"
    argv = ["--families", "--baseline", str(base), "--json", str(verdict)]
    # full coverage (extra current-only families are fine): pass
    assert main(argv + ["--current", str(cur_ok)]) == 0
    assert json.loads(verdict.read_text())["verdict"] == "OK"

    # doctored current drops dual_root from the candidate grid: MISWIRED
    cur_bad = _tuning_payload(
        tmp_path / "cur_bad.json", [["generalized", "ring", "traff_rounds"]]
    )
    assert main(argv + ["--current", str(cur_bad)]) == 2
    out = json.loads(verdict.read_text())
    assert out["verdict"] == "MISWIRED"
    assert out["missing_families"] == ["dual_root"]

    # a baseline that measures nothing is a mis-wired gate, not a pass
    empty = _tuning_payload(tmp_path / "empty.json", [])
    assert main(["--families", "--baseline", str(empty), "--current", str(cur_ok)]) == 2


def test_committed_tuning_table_has_competing_families():
    """The committed table the CI gate treats as source of truth must
    itself measure every family in the candidate grid, and at least two
    distinct families must win cells (the point of the competition)."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
    from check_regression import load_families

    path = os.path.join(os.path.dirname(__file__), "..", "results", "tuning.json")
    assert load_families(path) >= {"generalized", "ring", "traff_rounds", "dual_root"}
    with open(path) as f:
        payload = json.load(f)
    winners = {row["measured_winner"]["kind"] for row in payload["results"]}
    assert len(winners) >= 2, winners


def test_choose_uses_persisted_deltas_when_tuned(tuned_env):
    """A heavy arrival profile persisted by the tuning grid flips a tuned
    choose() onto the skew timeline even when the caller passes no live
    deltas; without tuning the same query stays analytic."""
    from repro.core.cost_model import TPU_V5E_ICI

    fp = current_fingerprint()
    c = TuningCache.load(tuned_env)
    c.record(
        fp,
        Measurement(
            P=8,
            nbytes=512,
            kind="generalized",
            r=3,
            n_buckets=1,
            us=30.0,
            itemsize=4,
            skew_us=300.0,
            deltas_us=(0.0,) * 7 + (300.0,),
        ),
    )
    c.save()
    policy.invalidate()
    ch = choose(8, 512, TPU_V5E_ICI, tune=True)
    assert ch.source == "skew"
    assert choose(8, 512, TPU_V5E_ICI, tune=False).source == "model"


# ---------------------------------------------------------------------------
#  class boundaries at the extrapolation edge + overlap-hinted queries
# ---------------------------------------------------------------------------


def test_out_of_range_query_with_only_wrong_op_neighbors_is_none():
    # the sum-op class was measured only at 16 KiB; a 4 MiB sum query is
    # 256x past it.  The max-op class has a 1 MiB neighbor within the 4x
    # window -- it must NOT answer the sum query: class filtering happens
    # before size bracketing, so the analytic model decides (None)
    rows = [
        Measurement(P=8, nbytes=16 << 10, kind="ring", r=0, n_buckets=1, us=10.0),
        Measurement(
            P=8, nbytes=1 << 20, kind="ring", r=0, n_buckets=1, us=90.0, op="max"
        ),
    ]
    near_max = best_measured(rows, 4 << 20, op="max")
    assert near_max is not None and near_max.source == "measured"
    assert best_measured(rows, 4 << 20, op="sum") is None
    # same guard for the element-ragged class: a ragged query (8193 f32
    # elements over P=8, well inside the sum row's 4x size window)
    # cannot borrow the divisible-geometry neighbor
    assert best_measured(rows, 8193 * 4, itemsize=4, op="sum") is None


def test_overlap_hinted_query_bypasses_measured_table(tuned_env):
    # the table would answer (and flip the winner) for a plain query...
    _flip_cache(tuned_env)
    assert choose(8, 1 << 20, HOST_CPU, tune=True).source == "measured"
    # ...but the grid times standalone collectives with no compute
    # running, so an overlap-hinted query is never answered from it:
    # both the policy layer and the tuned choose() fall back to the
    # model's exposed-cost ranking
    assert policy.lookup(8, 1 << 20, compute_overlap_us=1e3) is None
    hinted = choose(8, 1 << 20, HOST_CPU, tune=True, compute_overlap_us=1e3)
    assert hinted.source == "model"
    raw = choose(8, 1 << 20, HOST_CPU, tune=False).cost
    assert 0.0 <= hinted.cost <= raw
