"""Substrate tests: data pipeline, checkpointing, optimizer math,
serve engine, elastic runner (single device; multi-device elasticity is
covered by examples/elastic_failover.py and test_parallelism)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_steps,
                                         restore, save)
from repro.data.pipeline import DataConfig, DataLoader, synth_batch
from repro.launch.mesh import make_mesh
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.parallel.api import ParallelConfig
from repro.train.optimizer import OptConfig, init_opt_state, lr_at

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                   head_dim=16, act="swiglu")


# ------------------------------------------------------------------ data
def test_data_determinism_and_elastic_resharding():
    dc = DataConfig(seq_len=16, global_batch=8, seed=3)
    full = synth_batch(TINY, dc, step=5)
    lo = synth_batch(TINY, dc, step=5, host_slice=(0, 4))
    hi = synth_batch(TINY, dc, step=5, host_slice=(4, 8))
    np.testing.assert_array_equal(
        np.concatenate([lo["tokens"], hi["tokens"]]), full["tokens"])
    # a different host-count slicing of the SAME step yields the same data
    thirds = [synth_batch(TINY, dc, step=5, host_slice=(i, i + 2))
              for i in range(0, 8, 2)]
    np.testing.assert_array_equal(
        np.concatenate([t["labels"] for t in thirds]), full["labels"])


def test_data_loader_prefetch():
    dc = DataConfig(seq_len=8, global_batch=4)
    dl = DataLoader(TINY, dc, start_step=0, prefetch=2)
    steps = [next(dl)[0] for _ in range(5)]
    dl.close()
    assert steps == [0, 1, 2, 3, 4]


def test_labels_are_shifted_tokens():
    dc = DataConfig(seq_len=12, global_batch=2)
    b = synth_batch(TINY, dc, step=0)
    # labels = next token of the same stream
    assert b["tokens"].shape == b["labels"].shape
    assert not np.array_equal(b["tokens"], b["labels"])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    opt = init_opt_state(params, pc)
    d = str(tmp_path / "ckpt")
    save(d, 7, {"params": params, "opt": opt}, meta={"dp": 1})
    step, out = restore(d, {"params": params, "opt": opt})
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in [1, 2, 3]:
        ck.save(s, {"params": params})
    ck.wait()
    assert latest_steps(d) == [2, 3]            # gc kept last 2
    # a partial (uncommitted) dir must be ignored
    os.makedirs(os.path.join(d, "step_00000009"))
    assert latest_steps(d) == [2, 3]


def test_restore_incompatible_layout_keeps_fresh(tmp_path):
    """Elastic resize: zero1 flat buffers with a different dp are not
    force-loaded."""
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save(d, 1, {"opt": {"m": np.zeros(10), "v": np.zeros(10)}})
    fresh = {"opt": {"m": np.ones(6), "v": np.ones(6)}}
    _, out = restore(d, fresh)
    np.testing.assert_array_equal(out["opt"]["m"], np.ones(6))


# --------------------------------------------- checkpoint integrity
def _tiny_tree(fill):
    return {"params": {"w": np.full((4, 3), fill, np.float32),
                       "b": np.arange(5, dtype=np.float32) * fill}}


def _leaf_file(d, step):
    sd = os.path.join(d, f"step_{step:08d}")
    import json
    with open(os.path.join(sd, "manifest.json")) as f:
        man = json.load(f)
    fn = man["trees"]["params"]["w"]["file"]
    return sd, os.path.join(sd, fn)


def test_restore_falls_back_past_torn_checkpoint(tmp_path):
    """A leaf file truncated AFTER commit (torn disk write) must not be
    restored: the damaged step is quarantined and restore falls back to
    the newest earlier step that verifies."""
    from repro.checkpoint.checkpoint import validate_checkpoint
    d = str(tmp_path / "ckpt")
    save(d, 1, _tiny_tree(1.0))
    save(d, 2, _tiny_tree(2.0))
    sd2, leaf = _leaf_file(d, 2)
    with open(leaf, "r+b") as f:
        f.truncate(os.path.getsize(leaf) // 2)
    assert not validate_checkpoint(sd2)
    assert latest_steps(d, validate=True) == [1]
    step, out = restore(d, _tiny_tree(0.0))
    assert step == 1
    np.testing.assert_array_equal(out["params"]["w"],
                                  _tiny_tree(1.0)["params"]["w"])
    assert os.path.isdir(sd2 + ".corrupt") and not os.path.isdir(sd2)
    assert latest_steps(d) == [1]  # the quarantined dir stops being listed


def test_restore_explicit_damaged_step_raises(tmp_path):
    """Silent bit rot (same-size content change) is caught by the leaf
    checksums; asking for the damaged step explicitly raises instead of
    quarantining."""
    d = str(tmp_path / "ckpt")
    save(d, 3, _tiny_tree(3.0))
    sd, leaf = _leaf_file(d, 3)
    blob = bytearray(open(leaf, "rb").read())
    blob[-1] ^= 0xFF
    with open(leaf, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(ValueError, match="failed validation"):
        restore(d, _tiny_tree(0.0), step=3)
    assert os.path.isdir(sd)  # explicit requests never quarantine


def test_restore_garbage_manifest_and_all_damaged(tmp_path):
    """A garbage manifest fails validation (the commit marker pins its
    digest); with every step damaged, restore raises rather than loading
    corrupt state."""
    d = str(tmp_path / "ckpt")
    save(d, 1, _tiny_tree(1.0))
    sd = os.path.join(d, "step_00000001")
    with open(os.path.join(sd, "manifest.json"), "w") as f:
        f.write('{"trees": {')
    with pytest.raises(FileNotFoundError, match="passed validation"):
        restore(d, _tiny_tree(0.0))


def test_legacy_checkpoint_without_checksums_restores(tmp_path):
    """Checkpoints written before checksums existed ("ok" marker, no
    sha256 entries) still validate by file presence and restore."""
    import json
    from repro.checkpoint.checkpoint import validate_checkpoint
    d = str(tmp_path / "ckpt")
    save(d, 4, _tiny_tree(4.0))
    sd = os.path.join(d, "step_00000004")
    mpath = os.path.join(sd, "manifest.json")
    with open(mpath) as f:
        man = json.load(f)
    for leaves in man["trees"].values():
        for ent in leaves.values():
            ent.pop("sha256", None)
    with open(mpath, "w") as f:
        json.dump(man, f)
    with open(os.path.join(sd, "_COMMITTED"), "w") as f:
        f.write("ok")
    assert validate_checkpoint(sd)
    step, out = restore(d, _tiny_tree(0.0))
    assert step == 4
    np.testing.assert_array_equal(out["params"]["b"],
                                  _tiny_tree(4.0)["params"]["b"])


# -------------------------------------------------------------- optimizer
def test_lr_schedule():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                   min_lr_ratio=0.1)
    assert float(lr_at(oc, jnp.int32(0))) == 0.0
    assert float(lr_at(oc, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-4)


def test_adamw_decreases_loss_quadratic():
    """AdamW on a quadratic: sanity for the update math."""
    from repro.train.optimizer import apply_updates_dp
    pc = ParallelConfig(dp=1, tp=1)
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                   weight_decay=0.0, grad_clip=None)
    params = {"x": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params, pc)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, opt = apply_updates_dp(params, grads, opt, oc, pc)
    assert float(jnp.abs(params["x"]).max()) < 0.5


# ------------------------------------------------------------ serve engine
def test_engine_continuous_batching():
    from repro.serve.engine import Engine, Request
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    eng = Engine(TINY, pc, mesh, params, batch_slots=2, max_len=48,
                 prefill_chunk=8)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, TINY.vocab, 5).astype(np.int32),
                    max_new_tokens=4) for _ in range(5)]
    eng.generate(reqs)
    for r in reqs:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < TINY.vocab for t in r.out_tokens)
    for m in eng.kv:
        m.check()
    st = eng.stats()
    assert st["requests"] == 5 and st["tokens"] == 20
    assert st["live"] == 0 and st["queued"] == 0


def test_engine_greedy_matches_decode_step():
    """Greedy engine output == manual teacher-forced argmax decode."""
    from repro.serve.engine import Engine, Request
    from repro.models.model import decode_step, init_caches, param_shapes
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, specs = init_params(TINY, pc, jax.random.PRNGKey(4))
    prompt = np.arange(6, dtype=np.int32) + 3
    eng = Engine(TINY, pc, mesh, params, batch_slots=1, max_len=32,
                 prefill_chunk=8, temperature=0.0)
    req = Request(prompt=prompt, max_new_tokens=3)
    eng.generate([req])

    caches = init_caches(TINY, pc, 1, 32)
    lg, caches = decode_step(params, specs, jnp.asarray(prompt[None]),
                             caches, jnp.int32(0), TINY, pc)
    toks = []
    pos = len(prompt)
    for _ in range(3):
        t = int(np.asarray(lg[0, -1]).argmax())
        toks.append(t)
        lg, caches = decode_step(params, specs,
                                 jnp.full((1, 1), t, jnp.int32),
                                 caches, jnp.int32(pos), TINY, pc)
        pos += 1
    assert toks == req.out_tokens


def test_engine_mixed_length_prompts_match_solo():
    """Regression: the retired wave engine left-padded prompts, feeding
    pad tokens through the model at wrong positions -- shorter prompts
    in a mixed-length batch decoded differently from a solo run.  The
    paged engine gives every row its own positions/lengths, so batched
    greedy output must equal each request's B=1 sequential run."""
    from repro.serve.engine import Engine, Request
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(7))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, TINY.vocab, n).astype(np.int32)
               for n in (3, 11, 6, 17)]
    eng = Engine(TINY, pc, mesh, params, batch_slots=4, max_len=48,
                 prefill_chunk=8)
    batched = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    eng.generate(batched)
    solo = Engine(TINY, pc, mesh, params, batch_slots=1, max_len=48,
                  prefill_chunk=8, bundle=eng.bundle)
    for r in batched:
        ref = Request(prompt=r.prompt, max_new_tokens=5)
        solo.generate([ref])
        assert ref.out_tokens == r.out_tokens, \
            (len(r.prompt), r.out_tokens, ref.out_tokens)


def test_engine_sampling_deterministic_per_request():
    """Gumbel-max sampling is keyed by (seed, uid, step): outputs are
    bit-stable regardless of slot count / admission order / batch mates."""
    from repro.serve.engine import Engine, Request
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))

    def serve(slots, order):
        eng = Engine(TINY, pc, mesh, params, batch_slots=slots, max_len=48,
                     prefill_chunk=8, temperature=0.7, seed=11)
        reqs = [Request(prompt=np.arange(4, dtype=np.int32) + i,
                        max_new_tokens=4, uid=i) for i in range(4)]
        eng.generate([reqs[i] for i in order])
        return {r.uid: r.out_tokens for r in reqs}

    a = serve(2, [0, 1, 2, 3])
    b = serve(3, [2, 0, 3, 1])   # different slots AND submit order
    assert a == b
    # and distinct requests don't all sample identically by accident
    assert len({tuple(v) for v in a.values()}) > 1


# ------------------------------------------------------------ elastic
def test_elastic_runner_single_device(tmp_path):
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    from repro.data.pipeline import DataConfig
    runner = ElasticRunner(
        TINY, OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        ElasticConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=5),
        DataConfig(seq_len=16, global_batch=4),
        mesh_shape=(1, 1))
    logs = runner.run(12)
    assert logs[-1]["loss"] < logs[0]["loss"] + 0.2
    runner.ckpt.wait()
    assert latest_steps(str(tmp_path / "ck")) == [5, 10]
    step = runner.restore_latest()
    assert step == 10
    logs2 = runner.run(3)
    assert np.isfinite(logs2[-1]["loss"])


def test_straggler_watch_not_masked_by_prior_outlier():
    """The straggler EWMA must not be contaminated by the outlier it just
    alerted on: folding the raw spike in inflates the baseline so the
    NEXT straggler sails under the threshold."""
    from repro.runtime.elastic import StragglerWatch
    w = StragglerWatch(factor=3.0, decay=0.9)
    assert not any(w.observe(0.1) for _ in range(5))
    base = w.value
    assert w.observe(2.0)               # 20x: alert
    assert w.value < base * 1.25        # clamped fold, not raw 2.0
    assert w.observe(0.8)               # 8x original pace: still alerts
    # a persistent regime change converges instead of alerting forever
    alerts = [w.observe(1.0) for _ in range(40)]
    assert not any(alerts[-10:])
    assert abs(w.value - 1.0) < 0.1


def test_straggler_watch_warmup_and_runner_alerts(tmp_path):
    from repro.runtime.elastic import StragglerWatch
    w = StragglerWatch(factor=3.0, decay=0.9, warmup=3)
    assert not w.observe(0.1)           # seeds the baseline
    assert not w.observe(1.0)           # 10x, but still warming up
    w2 = StragglerWatch(factor=3.0)
    [w2.observe(0.1) for _ in range(4)]
    assert w2.observe(1.0)              # past warmup: alerts

    # runner plumbing: an alert carries (step, dt, baseline)
    from repro.data.pipeline import DataConfig
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    runner = ElasticRunner(
        TINY, OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        ElasticConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=50),
        DataConfig(seq_len=8, global_batch=2), mesh_shape=(1, 1))
    for dt in [0.1] * 5:
        runner._watch_straggler(dt)
        runner.step += 1
    runner._watch_straggler(5.0)
    [(step, dt, baseline)] = runner.alerts
    assert step == 5 and dt == 5.0 and baseline == pytest.approx(0.1)
    assert runner.step_time_ewma < 0.5  # clamped: spike didn't poison it


_WORKER = os.path.join(os.path.dirname(__file__), "_multidevice_worker.py")


@pytest.mark.slow
@pytest.mark.xdist_group("subprocess")
def test_elastic_resize_prime_counts_8dev():
    """resize() through prime dp counts 8 -> 7 -> 5 with zero1 opt-state
    reset and restore_latest across layout changes; runs in a subprocess
    with 8 forced host devices (see _multidevice_worker.py)."""
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, _WORKER, "elastic_resize"],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, f"worker failed:\n{res.stdout}\n{res.stderr}"
    assert "ok elastic_resize 8->7->5" in res.stdout, res.stdout


@pytest.mark.slow
@pytest.mark.xdist_group("subprocess")
def test_serve_engine_tp_dp_8dev():
    """Continuous-batching engine on dp=2 x tp=2 (of 8 forced host
    devices): batched paged decode bit-identical to the single-request
    path, and TP decode collectives picked by autotune.choose() from a
    measured tuning table (source="measured"); see check_serve in
    _multidevice_worker.py."""
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("REPRO_TUNING_CACHE", None)
    res = subprocess.run([sys.executable, _WORKER, "serve"],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, f"worker failed:\n{res.stdout}\n{res.stderr}"
    assert "ok serve" in res.stdout, res.stdout
