"""Substrate tests: data pipeline, checkpointing, optimizer math,
serve engine, elastic runner (single device; multi-device elasticity is
covered by examples/elastic_failover.py and test_parallelism)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_steps,
                                         restore, save)
from repro.data.pipeline import DataConfig, DataLoader, synth_batch
from repro.launch.mesh import make_mesh
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.parallel.api import ParallelConfig
from repro.train.optimizer import OptConfig, init_opt_state, lr_at

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                   head_dim=16, act="swiglu")


# ------------------------------------------------------------------ data
def test_data_determinism_and_elastic_resharding():
    dc = DataConfig(seq_len=16, global_batch=8, seed=3)
    full = synth_batch(TINY, dc, step=5)
    lo = synth_batch(TINY, dc, step=5, host_slice=(0, 4))
    hi = synth_batch(TINY, dc, step=5, host_slice=(4, 8))
    np.testing.assert_array_equal(
        np.concatenate([lo["tokens"], hi["tokens"]]), full["tokens"])
    # a different host-count slicing of the SAME step yields the same data
    thirds = [synth_batch(TINY, dc, step=5, host_slice=(i, i + 2))
              for i in range(0, 8, 2)]
    np.testing.assert_array_equal(
        np.concatenate([t["labels"] for t in thirds]), full["labels"])


def test_data_loader_prefetch():
    dc = DataConfig(seq_len=8, global_batch=4)
    dl = DataLoader(TINY, dc, start_step=0, prefetch=2)
    steps = [next(dl)[0] for _ in range(5)]
    dl.close()
    assert steps == [0, 1, 2, 3, 4]


def test_labels_are_shifted_tokens():
    dc = DataConfig(seq_len=12, global_batch=2)
    b = synth_batch(TINY, dc, step=0)
    # labels = next token of the same stream
    assert b["tokens"].shape == b["labels"].shape
    assert not np.array_equal(b["tokens"], b["labels"])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    opt = init_opt_state(params, pc)
    d = str(tmp_path / "ckpt")
    save(d, 7, {"params": params, "opt": opt}, meta={"dp": 1})
    step, out = restore(d, {"params": params, "opt": opt})
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in [1, 2, 3]:
        ck.save(s, {"params": params})
    ck.wait()
    assert latest_steps(d) == [2, 3]            # gc kept last 2
    # a partial (uncommitted) dir must be ignored
    os.makedirs(os.path.join(d, "step_00000009"))
    assert latest_steps(d) == [2, 3]


def test_restore_incompatible_layout_keeps_fresh(tmp_path):
    """Elastic resize: zero1 flat buffers with a different dp are not
    force-loaded."""
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save(d, 1, {"opt": {"m": np.zeros(10), "v": np.zeros(10)}})
    fresh = {"opt": {"m": np.ones(6), "v": np.ones(6)}}
    _, out = restore(d, fresh)
    np.testing.assert_array_equal(out["opt"]["m"], np.ones(6))


# -------------------------------------------------------------- optimizer
def test_lr_schedule():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                   min_lr_ratio=0.1)
    assert float(lr_at(oc, jnp.int32(0))) == 0.0
    assert float(lr_at(oc, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-4)


def test_adamw_decreases_loss_quadratic():
    """AdamW on a quadratic: sanity for the update math."""
    from repro.train.optimizer import apply_updates_dp
    pc = ParallelConfig(dp=1, tp=1)
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                   weight_decay=0.0, grad_clip=None)
    params = {"x": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params, pc)
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, opt = apply_updates_dp(params, grads, opt, oc, pc)
    assert float(jnp.abs(params["x"]).max()) < 0.5


# ------------------------------------------------------------ serve engine
def test_engine_wave_batching():
    from repro.serve.engine import Engine, Request
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
    eng = Engine(TINY, pc, mesh, params, batch_slots=2, max_len=48,
                 prefill_chunk=8)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, TINY.vocab, 5).astype(np.int32),
                    max_new_tokens=4) for _ in range(5)]
    eng.generate(reqs)
    for r in reqs:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < TINY.vocab for t in r.out_tokens)


def test_engine_greedy_matches_decode_step():
    """Greedy engine output == manual teacher-forced argmax decode."""
    from repro.serve.engine import Engine, Request
    from repro.models.model import decode_step, init_caches, param_shapes
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, specs = init_params(TINY, pc, jax.random.PRNGKey(4))
    prompt = np.arange(6, dtype=np.int32) + 3
    eng = Engine(TINY, pc, mesh, params, batch_slots=1, max_len=32,
                 prefill_chunk=8, temperature=0.0)
    req = Request(prompt=prompt, max_new_tokens=3)
    eng.generate([req])

    caches = init_caches(TINY, pc, 1, 32)
    # engine left-pads to the prompt length; with one request there is no
    # padding, so direct prefill matches
    lg, caches = decode_step(params, specs, jnp.asarray(prompt[None]),
                             caches, jnp.int32(0), TINY, pc)
    toks = []
    pos = len(prompt)
    for _ in range(3):
        t = int(np.asarray(lg[0, -1]).argmax())
        toks.append(t)
        lg, caches = decode_step(params, specs,
                                 jnp.full((1, 1), t, jnp.int32),
                                 caches, jnp.int32(pos), TINY, pc)
        pos += 1
    assert toks == req.out_tokens


# ------------------------------------------------------------ elastic
def test_elastic_runner_single_device(tmp_path):
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    from repro.data.pipeline import DataConfig
    runner = ElasticRunner(
        TINY, OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        ElasticConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=5),
        DataConfig(seq_len=16, global_batch=4),
        mesh_shape=(1, 1))
    logs = runner.run(12)
    assert logs[-1]["loss"] < logs[0]["loss"] + 0.2
    runner.ckpt.wait()
    assert latest_steps(str(tmp_path / "ck")) == [5, 10]
    step = runner.restore_latest()
    assert step == 10
    logs2 = runner.run(3)
    assert np.isfinite(logs2[-1]["loss"])
