"""Property-testing front end: real hypothesis when installed, otherwise
a small built-in drawing + shrinking engine with the same API surface.

The seed hard-imported ``hypothesis`` at module scope, so *every* test in
the importing file errored at collection when it was not installed.  The
first replacement shim skipped the property tests instead; this version
*runs* them everywhere: when hypothesis is available it re-exports the
real ``given``/``settings``/``st``, and when it is missing a minimal
engine stands in --

* deterministic seeding per test (derived from the test's qualified
  name, so failures replay without a database),
* the strategy subset the suite uses (``integers``, ``sampled_from``,
  ``booleans``, ``lists``, ``tuples``, ``data``),
* greedy shrinking of the failing example (integers toward their lower
  bound, samples toward earlier elements, lists toward shorter), with
  the falsifying example -- including every interactive ``data.draw``
  -- reported on the raised exception.

Only the API subset below is emulated; tests must stay inside it to keep
both worlds green (CI installs the real package).
"""
import functools
import random
import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 50
    _MAX_SHRINK_ATTEMPTS = 200

    class _Strategy:
        def draw(self, rng: random.Random):
            raise NotImplementedError

        def shrinks(self, value):
            """Candidate simpler values, most aggressive first."""
            return ()

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            if lo is None or hi is None:
                raise ValueError("the built-in engine needs bounded "
                                 "integers(min_value, max_value)")
            self.lo, self.hi = int(lo), int(hi)

        def draw(self, rng):
            roll = rng.random()
            if roll < 0.08:
                return self.lo
            if roll < 0.16:
                return self.hi
            return rng.randint(self.lo, self.hi)

        def shrinks(self, v):
            out = []
            for c in (self.lo, self.lo + (v - self.lo) // 2, v - 1):
                if self.lo <= c < v and c not in out:
                    out.append(c)
            return out

    class _SampledFrom(_Strategy):
        def __init__(self, seq):
            self.seq = list(seq)
            if not self.seq:
                raise ValueError("sampled_from needs a non-empty sequence")

        def draw(self, rng):
            return self.seq[rng.randrange(len(self.seq))]

        def shrinks(self, v):
            try:
                i = self.seq.index(v)
            except ValueError:
                return ()
            out = []
            for j in (0, i // 2, i - 1):
                if 0 <= j < i and self.seq[j] not in out:
                    out.append(self.seq[j])
            return out

    class _Lists(_Strategy):
        def __init__(self, elems, min_size=0, max_size=None):
            self.elems = elems
            self.min_size = int(min_size)
            self.max_size = int(max_size) if max_size is not None \
                else self.min_size + 8

        def draw(self, rng):
            size = rng.randint(self.min_size, self.max_size)
            return [self.elems.draw(rng) for _ in range(size)]

        def shrinks(self, v):
            out = []
            if len(v) > self.min_size:
                out.append(list(v[:self.min_size]))
                out.append(list(v[:-1]))
            for i, x in enumerate(v):
                for c in self.elems.shrinks(x):
                    out.append(v[:i] + [c] + v[i + 1:])
                    break           # one candidate per position bounds work
            return out

    class _Tuples(_Strategy):
        def __init__(self, strats):
            self.strats = strats

        def draw(self, rng):
            return tuple(s.draw(rng) for s in self.strats)

        def shrinks(self, v):
            out = []
            for i, (s, x) in enumerate(zip(self.strats, v)):
                for c in s.shrinks(x):
                    out.append(v[:i] + (c,) + v[i + 1:])
                    break
            return out

    class _DataMarker(_Strategy):
        """Placeholder: the runner substitutes a live _DataObject."""

        def draw(self, rng):
            return _DataObject(rng, [])

    class _DataObject:
        """Interactive draws; every draw is logged for the failure report."""

        def __init__(self, rng, log):
            self._rng = rng
            self._log = log

        def draw(self, strategy, label=None):
            v = strategy.draw(self._rng)
            self._log.append((label or f"data[{len(self._log)}]", v))
            return v

    class _St:
        @staticmethod
        def integers(min_value=None, max_value=None):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(seq):
            return _SampledFrom(seq)

        @staticmethod
        def booleans():
            return _SampledFrom([False, True])

        @staticmethod
        def lists(elems, min_size=0, max_size=None, **_):
            return _Lists(elems, min_size, max_size)

        @staticmethod
        def tuples(*strats):
            return _Tuples(strats)

        @staticmethod
        def data():
            return _DataMarker()

    st = _St()

    def settings(*args, **kwargs):
        def deco(fn):
            fn._hyp_settings = kwargs
            return fn
        return deco

    def _run_case(fn, seed, names, strats):
        """Draw every argument from a fresh rng at ``seed`` and call the
        test; returns (values, data_log, exception_or_None)."""
        rng = random.Random(seed)
        values, data_log = [], []
        for s in strats:
            if isinstance(s, _DataMarker):
                values.append(_DataObject(rng, data_log))
            else:
                values.append(s.draw(rng))
        return values, data_log, _call(fn, names, values)

    def _replay(fn, seed, names, strats, values):
        """Re-run with pinned non-data values; data draws re-derive from
        the case seed, so the attempt is deterministic."""
        rng = random.Random(seed)
        data_log = []
        vals = [(_DataObject(rng, data_log)
                 if isinstance(s, _DataMarker) else v)
                for s, v in zip(strats, values)]
        return vals, data_log, _call(fn, names, vals)

    def _call(fn, names, values):
        n_pos = names.count(None)
        args = values[:n_pos]
        kwargs = {k: v for k, v in zip(names[n_pos:], values[n_pos:])}
        try:
            fn(*args, **kwargs)
            return None
        except Exception as e:          # noqa: BLE001 - reported verbatim
            return e

    def _describe(names, values, data_log):
        parts = []
        for k, v in zip(names, values):
            if isinstance(v, _DataObject):
                continue
            parts.append(f"{k}={v!r}" if k else repr(v))
        parts += [f"{k}={v!r}" for k, v in data_log]
        return ", ".join(parts)

    def given(*arg_strats, **kw_strats):
        strats = list(arg_strats) + list(kw_strats.values())
        names = [None] * len(arg_strats) + list(kw_strats.keys())

        def deco(fn):
            @functools.wraps(fn)
            def runner():    # noqa: C901 - one self-contained engine loop
                cfg = getattr(runner, "_hyp_settings", {})
                max_examples = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(max_examples):
                    seed = base + i
                    values, dlog, exc = _run_case(fn, seed, names, strats)
                    if exc is None:
                        continue
                    # greedy shrink: accept any simpler still-failing value
                    attempts = 0
                    improved = True
                    while improved and attempts < _MAX_SHRINK_ATTEMPTS:
                        improved = False
                        for pos, s in enumerate(strats):
                            if isinstance(s, _DataMarker):
                                continue
                            for cand in s.shrinks(values[pos]):
                                attempts += 1
                                trial = list(values)
                                trial[pos] = cand
                                _, tl, terr = _replay(fn, seed, names,
                                                      strats, trial)
                                if terr is not None:
                                    values, dlog, exc = trial, tl, terr
                                    improved = True
                                    break
                                if attempts >= _MAX_SHRINK_ATTEMPTS:
                                    break
                            if improved or attempts >= _MAX_SHRINK_ATTEMPTS:
                                break
                    msg = (f"Falsifying example (seed={seed}): "
                           f"{fn.__name__}({_describe(names, values, dlog)})")
                    raise AssertionError(msg) from exc
            # pytest resolves fixtures through __wrapped__'s signature;
            # the runner is zero-arg, so drop the wraps() breadcrumb
            del runner.__wrapped__
            return runner
        return deco
