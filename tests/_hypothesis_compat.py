"""Optional-hypothesis shim for the test suite.

The seed hard-imported ``hypothesis`` at module scope, so *every* test in
the importing file errored at collection when it was not installed.
``pytest.importorskip`` at module scope would instead skip the whole file,
losing the plain (non-property) tests too.  This shim keeps plain tests
running everywhere: when hypothesis is available it re-exports the real
``given``/``settings``/``st``; when it is missing, ``@given`` replaces
just the property test with a skip stub.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _AnyStrategy:
        """Stands in for ``st``: any strategy expression evaluates to None,
        which the no-op ``given`` below ignores."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg stub so pytest does not treat the strategy
            # parameters as fixtures
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass  # pragma: no cover
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn
