"""Backward-overlapped gradient sync: reverse-layer bucketing, the
exposed-comm roofline, the overlap-hinted ``choose()`` path, and the
8-device bit-exactness gate (``tests/_multidevice_worker.py overlap``).

The bit-exactness contract being gated: the "backward" arm (per-bucket
``custom_vjp`` dispatch) and the "post" arm (identical per-bucket
collectives after the backward) run the same collectives over the same
leaf lists, so their fp32 gradients -- and therefore params over 3
steps -- must match bit-for-bit.  Whole-tree vs bucketed changes the
element->chunk assignment (different fp32 association), so that pair
is held to allclose instead.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.autotune import choose
from repro.core.cost_model import (HOST_CPU, PAPER_10GE,
                                   overlap_exposed_cost,
                                   overlap_tick_costs, ragged_tick_costs)
from repro.core.schedule import build_generalized, build_ring
from repro.models.config import ModelConfig
from repro.models.model import param_shapes
from repro.obs.validate import fit_ratio, validate_overlap
from repro.parallel.api import ParallelConfig, reverse_layer_buckets
from repro.train.step import _leaf_layers, overlap_buckets_for

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                   head_dim=16, act="swiglu")


# ---------------------------------------------------------------------------
#  reverse-layer bucketing
# ---------------------------------------------------------------------------

def test_reverse_layer_buckets_orders_deepest_first():
    # layer 2 completes its backward first -> its leaves lead bucket 0
    buckets = reverse_layer_buckets([0, 1, 1, 2], [4, 4, 4, 4], 8)
    assert buckets == [[3, 1], [2, 0]]
    flat = [i for b in buckets for i in b]
    assert sorted(flat) == [0, 1, 2, 3]          # partition, no dupes


def test_reverse_layer_buckets_budget_and_oversize():
    # an oversize leaf gets its own bucket; packing never exceeds the
    # budget except for a single leaf bigger than the whole budget
    buckets = reverse_layer_buckets([0, 0, 0], [10, 3, 3], 8)
    sizes = [10, 3, 3]
    assert [i for b in buckets for i in b] == [0, 1, 2]
    for b in buckets:
        if len(b) > 1:
            assert sum(sizes[i] for i in b) <= 8
    # one huge bucket budget -> everything packs together
    assert reverse_layer_buckets([0, 1], [4, 4], 1 << 30) == [[1, 0]]


def test_reverse_layer_buckets_ties_stable_and_validates():
    # equal layers keep ascending leaf order (deterministic across runs)
    assert reverse_layer_buckets([1, 1, 1], [1, 1, 1], 10) == [[0, 1, 2]]
    with pytest.raises(ValueError):
        reverse_layer_buckets([0, 1], [4], 8)


# ---------------------------------------------------------------------------
#  layer derivation over real param trees
# ---------------------------------------------------------------------------

def test_leaf_layers_dense_tree():
    pc = ParallelConfig(dp=8, tp=1, param_mode="dp")
    shapes, _ = param_shapes(TINY, pc)
    layers = _leaf_layers(shapes)
    leaves = jax.tree.leaves(shapes)
    assert len(layers) == len(leaves)
    import jax.tree_util as jtu
    flat, _ = jtu.tree_flatten_with_path(shapes)
    by_top = {}
    for (path, _leaf), layer in zip(flat, layers):
        by_top.setdefault(path[0].key, set()).add(layer)
    # embed's grad completes last (layer 0); the stacked scan is one
    # band; final_norm/head complete first (highest layer)
    assert by_top["embed"] == {0}
    assert by_top["cycles"] == {1}
    assert by_top["final_norm"] == {2}
    assert by_top["head"] == {2}


def test_overlap_buckets_for_gating():
    shapes, _ = param_shapes(TINY, ParallelConfig(dp=8, tp=1,
                                                  param_mode="dp"))
    # off by default; off for dp=1; off for fsdp (it reshapes gradient
    # flow itself); on only for pure DP with a byte budget
    assert overlap_buckets_for(
        shapes, ParallelConfig(dp=8, tp=1, param_mode="dp")) is None
    assert overlap_buckets_for(
        shapes, ParallelConfig(dp=1, tp=1, param_mode="dp",
                               overlap_bucket_bytes=1 << 20)) is None
    assert overlap_buckets_for(
        shapes, ParallelConfig(dp=8, tp=1, param_mode="fsdp",
                               overlap_bucket_bytes=1 << 20)) is None
    buckets = overlap_buckets_for(
        shapes, ParallelConfig(dp=8, tp=1, param_mode="dp",
                               overlap_bucket_bytes=32 << 10))
    assert buckets is not None and len(buckets) >= 2
    assert sorted(i for b in buckets for i in b) == \
        list(range(len(jax.tree.leaves(shapes))))


def test_make_train_step_rejects_unknown_dispatch():
    from jax.sharding import Mesh

    from repro.train.optimizer import OptConfig
    from repro.train.step import make_train_step
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1, overlap_dispatch="bogus")
    with pytest.raises(ValueError, match="overlap_dispatch"):
        make_train_step(TINY, pc, mesh, OptConfig(lr=1e-3))


# ---------------------------------------------------------------------------
#  exposed-comm roofline
# ---------------------------------------------------------------------------

def test_overlap_tick_costs_invariants():
    sched = build_generalized(8, 1)
    for n_buckets in (1, 3):
        base = ragged_tick_costs(sched, 1 << 16, PAPER_10GE, n_buckets)
        total = sum(t["total_s"] for t in base)
        for compute_us in (0.0, total * 0.5e6, total * 10e6):
            rows = overlap_tick_costs(sched, 1 << 16, PAPER_10GE,
                                      n_buckets, compute_us=compute_us)
            assert len(rows) == len(base)
            for r, b in zip(rows, base):
                # overlay never changes the underlying tick timeline
                assert r["total_s"] == b["total_s"]
                assert r["hidden_s"] + r["exposed_s"] == \
                    pytest.approx(r["total_s"])
                assert 0.0 <= r["hidden_s"] <= r["total_s"]
            exposed = sum(r["exposed_s"] for r in rows)
            want = max(0.0, total - compute_us * 1e-6)
            assert exposed == pytest.approx(want)
            assert overlap_exposed_cost(
                sched, 1 << 16, PAPER_10GE, n_buckets,
                compute_us=compute_us) == pytest.approx(want)


def test_overlap_drains_budget_in_tick_order():
    # a budget that covers exactly the first tick hides it fully and
    # leaves every later tick fully exposed
    sched = build_ring(8)
    base = ragged_tick_costs(sched, 1 << 20, PAPER_10GE)
    first_us = base[0]["total_s"] * 1e6
    rows = overlap_tick_costs(sched, 1 << 20, PAPER_10GE,
                              compute_us=first_us)
    assert rows[0]["exposed_s"] == pytest.approx(0.0)
    assert rows[1]["hidden_s"] == pytest.approx(0.0)


# ---------------------------------------------------------------------------
#  overlap-hinted choose()
# ---------------------------------------------------------------------------

def test_choose_hint_none_is_identical_to_default():
    a = choose(8, 1 << 20, HOST_CPU, tune=False)
    b = choose(8, 1 << 20, HOST_CPU, tune=False, compute_overlap_us=None)
    assert a == b


def test_choose_hint_cost_is_exposed_and_monotone():
    raw = choose(8, 1 << 22, HOST_CPU, tune=False).cost
    prev = None
    for budget_us in (0.1, raw * 0.25e6, raw * 0.75e6, raw * 100e6):
        ch = choose(8, 1 << 22, HOST_CPU, tune=False,
                    compute_overlap_us=budget_us)
        assert 0.0 <= ch.cost <= raw + 1e-12
        if prev is not None:
            assert ch.cost <= prev + 1e-12   # more budget, less exposed
        prev = ch.cost
    assert prev == 0.0                       # everything hides eventually


# ---------------------------------------------------------------------------
#  predicted-vs-measured overlay
# ---------------------------------------------------------------------------

def test_validate_overlap_fit_ratio_golden():
    sched = build_generalized(8, 2)
    rows = []
    for compute_us in (0.0, 20.0, 200.0):
        pred = overlap_exposed_cost(sched, 1 << 16, PAPER_10GE,
                                    compute_us=compute_us) * 1e6
        if pred <= 0:
            continue
        rows.append(validate_overlap(sched, 1 << 16, PAPER_10GE,
                                     compute_us=compute_us,
                                     measured_exposed_us=pred))
    assert rows and fit_ratio(rows) == pytest.approx(1.0)
    # 2x-miscalibrated measurements reduce to a 2x fit ratio
    rows2 = [validate_overlap(sched, 1 << 16, PAPER_10GE,
                              compute_us=r["compute_us"],
                              measured_exposed_us=2 *
                              r["predicted_exposed_us"])
             for r in rows]
    assert fit_ratio(rows2) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
#  8-device subprocess gates
# ---------------------------------------------------------------------------

_WORKER = os.path.join(os.path.dirname(__file__), "_multidevice_worker.py")


def _spawn(which, timeout):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, _WORKER, which], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, \
        f"{which} failed:\n{res.stdout[-4000:]}\n{res.stderr[-4000:]}"
    return res.stdout


@pytest.mark.slow
@pytest.mark.xdist_group("subprocess")
def test_overlap_bit_exact_8dev():
    """backward-vs-post bit-identical fp32 params over 3 steps for
    dense + scan-stacked + MoE archs; allclose vs the whole-tree path
    (see check_overlap in _multidevice_worker.py)."""
    out = _spawn("overlap", timeout=1200)
    for arch in ("dense", "scan", "moe"):
        assert f"ok overlap {arch}" in out, out


@pytest.mark.xdist_group("subprocess")
def test_grad_sync_fsdp_interleaved_8dev():
    """Satellite regression: sync_grads_dp's fsdp hybrid re-assembly on
    a tree whose flatten order interleaves sharded and replicated
    leaves (see check_grad_interleave in _multidevice_worker.py)."""
    out = _spawn("grad_interleave", timeout=600)
    assert "ok grad_interleave" in out, out
