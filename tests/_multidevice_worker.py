"""Worker executed in a subprocess with XLA_FLAGS forcing N host devices.

Runs a batch of multi-device checks and prints "ALL-OK" on success.
Keeping everything in one process amortizes JAX startup (~seconds).
"""
import os
import sys

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), \
    "must be launched with XLA_FLAGS=--xla_force_host_platform_device_count=N"

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.compat import shard_map  # noqa: E402
from repro.core.allreduce import (all_gather_flat, all_to_all_flat,  # noqa: E402
                                  allreduce_flat, allreduce_tree,
                                  hierarchical_allreduce,
                                  hierarchical_allreduce_flat, psum_tree,
                                  reduce_scatter_flat, tree_all_gather,
                                  tree_reduce_scatter)
from repro.core.schedule import (build_dual_root, build_generalized,  # noqa: E402
                                 build_ring, build_sorted_generalized,
                                 build_traff_rounds, max_r)
from repro.topology import Level, Topology, build_hierarchical  # noqa: E402
from repro.topology.fabric import TPU_DCN  # noqa: E402
from repro.core.cost_model import TPU_V5E_ICI  # noqa: E402


def check_allreduce_flat():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    for m in [1, 5, n, 3 * n + 1, 257]:
        x = rng.standard_normal((n, m)).astype(np.float32)
        want = x.sum(0)
        scheds = [build_generalized(n, r) for r in range(max_r(n) + 1)]
        scheds.append(build_ring(n))
        if n & (n - 1) == 0:
            scheds.append(build_generalized(n, 0, "hypercube"))
            scheds.append(build_generalized(n, max_r(n), "hypercube"))
        for sched in scheds:
            f = jax.jit(shard_map(
                lambda v: allreduce_flat(v[0], "data", sched)[None],
                mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
            out = np.asarray(f(x))
            for d in range(n):
                np.testing.assert_allclose(out[d], want, rtol=2e-5, atol=2e-5)
    print("ok allreduce_flat")


def check_vs_psum():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(1)
    tree = {"w": rng.standard_normal((n, 33)).astype(np.float32),
            "b": rng.standard_normal((n, 7, 3)).astype(np.float32)}
    def ours(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = allreduce_tree(loc, "data", mean=True)
        return jax.tree.map(lambda v: v[None], out)
    def theirs(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = psum_tree(loc, "data", mean=True)
        return jax.tree.map(lambda v: v[None], out)
    fo = jax.jit(shard_map(ours, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
    ft = jax.jit(shard_map(theirs, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
    a, b = fo(tree), ft(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=2e-5, atol=2e-5)
    print("ok vs_psum")


def check_rs_ag():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(2)
    m = 4 * n
    x = rng.standard_normal((n, m)).astype(np.float32)
    want = x.sum(0)

    def f(v):
        shard = reduce_scatter_flat(v[0], "data")
        return shard[None]
    out = np.asarray(jax.jit(shard_map(
        f, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))(x))
    u = m // n
    for d in range(n):
        np.testing.assert_allclose(out[d], want[d*u:(d+1)*u], rtol=2e-5, atol=2e-5)

    def g(v):
        shard = reduce_scatter_flat(v[0], "data")
        return all_gather_flat(shard, "data")[None]
    out = np.asarray(jax.jit(shard_map(
        g, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))(x))
    for d in range(n):
        np.testing.assert_allclose(out[d], want, rtol=2e-5, atol=2e-5)
    print("ok rs_ag")


def check_multiaxis():
    devs = len(jax.devices())
    if devs % 2:
        print("ok multiaxis (skipped)")
        return
    n0, n1 = 2, devs // 2
    mesh = jax.make_mesh((n0, n1), ("pod", "data"))
    n = n0 * n1
    rng = np.random.default_rng(3)
    x = rng.standard_normal((n, 11)).astype(np.float32)
    want = x.sum(0)
    sched = build_generalized(n, 1)
    f = jax.jit(shard_map(
        lambda v: allreduce_flat(v.reshape(-1), ("pod", "data"), sched)[None],
        mesh=mesh, in_specs=P(("pod", "data"), None),
        out_specs=P(("pod", "data"), None)))
    out = np.asarray(f(x))
    for d in range(n):
        np.testing.assert_allclose(out[d], want, rtol=2e-5, atol=2e-5)
    print("ok multiaxis")


def check_tree_zero():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(4)
    tree = {"a": rng.standard_normal((n, 13)).astype(np.float32),
            "b": rng.standard_normal((n, 2, 5)).astype(np.float32)}
    def f(t):
        loc = jax.tree.map(lambda v: v[0], t)
        shard, spec = tree_reduce_scatter(loc, "data", mean=True)
        back = tree_all_gather(shard, spec, "data")
        return jax.tree.map(lambda v: v[None], back)
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data")))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k])[0], tree[k].mean(0),
                                   rtol=2e-5, atol=2e-5)
    print("ok tree_zero")


def check_hierarchical():
    """Hierarchical allreduce over a ("pod", "data") mesh vs the numpy sum,
    for every outer r and several message sizes (incl. sizes that need
    padding), plus the autotuned pytree path."""
    devs = len(jax.devices())
    if devs % 2:
        print("ok hierarchical (skipped)")
        return
    shape = (2, devs // 2)
    names = ("pod", "data")
    mesh = jax.make_mesh(shape, names)
    n = devs
    topo = Topology((Level("pod", shape[0], TPU_DCN),
                     Level("ici", shape[1], TPU_V5E_ICI)),
                    name=f"test-{shape[0]}x{shape[1]}")
    rng = np.random.default_rng(5)
    for m in [1, 7, n, 3 * n + 1, 257]:
        x = rng.standard_normal((n, m)).astype(np.float32)
        want = x.sum(0)
        for r in range(max_r(shape[0]) + 1):
            hs = build_hierarchical(topo, r)
            f = jax.jit(shard_map(
                lambda v, h=hs: hierarchical_allreduce_flat(
                    v.reshape(-1), names, h)[None],
                mesh=mesh, in_specs=P(names, None),
                out_specs=P(names, None)))
            out = np.asarray(f(x))
            for d in range(n):
                np.testing.assert_allclose(out[d], want, rtol=2e-5,
                                           atol=2e-5)
    # autotuned pytree path (plan may resolve to flat or hierarchical)
    tree = {"w": rng.standard_normal((n, 33)).astype(np.float32),
            "b": rng.standard_normal((n, 7, 3)).astype(np.float32)}

    def g(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = hierarchical_allreduce(loc, names, topo, mean=True)
        return jax.tree.map(lambda v: v[None], out)

    out = jax.jit(shard_map(g, mesh=mesh, in_specs=P(names),
                            out_specs=P(names)))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k])[0], tree[k].mean(0),
                                   rtol=2e-5, atol=2e-5)
    print("ok hierarchical")


def check_ragged():
    """Ragged (uneven-shard) collectives on real devices.

    1. ``dp_grad_allreduce`` of an int32 pytree whose fused flat size is
       coprime with the device count must match ``psum`` bit-exactly
       (sums stay far below 2^24, so the f32 accumulation is exact).
    2. The ragged reduce-scatter owns the exact balanced chunk, and the
       allgatherv inverse reassembles the exact vector.
    3. A schedule compiled for the wrong P raises ShapeError (typed, not
       a stripped-under-``-O`` assert).
    """
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(11)
    from repro.core.allreduce import psum_tree
    from repro.core.schedule import ShapeError, ragged_offsets, ragged_sizes
    from repro.parallel.api import ParallelConfig, dp_grad_allreduce

    pc = ParallelConfig(dp_axes=("data",), dp=n)
    # leaf sizes chosen so the fused flat buffer (13 + 2*5 = 23 elems
    # at n=8, 23 % 8 != 0) rides the ragged split
    tree = {"a": rng.integers(-1000, 1000, (n, 13)).astype(np.int32),
            "b": rng.integers(-1000, 1000, (n, 2, 5)).astype(np.int32)}

    def ours(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = dp_grad_allreduce(loc, pc, mean=False)
        return jax.tree.map(lambda v: v[None], out)

    def theirs(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = psum_tree(loc, "data")
        return jax.tree.map(lambda v: v[None], out)

    a = jax.jit(shard_map(ours, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))(tree)
    b = jax.jit(shard_map(theirs, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))(tree)
    for k in tree:
        assert (np.asarray(a[k]) == np.asarray(b[k])).all(), k
        assert (np.asarray(a[k])[0] == tree[k].sum(0)).all(), k

    # ragged reduce-scatter + allgatherv round trip, exact shard contents
    for m in (1, n - 1, n + 1, 3 * n + 5, 257):
        x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)
        want = x.sum(0)
        sizes = ragged_sizes(m, n)
        offs = ragged_offsets(sizes)

        def rs(v):
            return reduce_scatter_flat(v[0], "data")[None]
        shards = np.asarray(jax.jit(shard_map(
            rs, mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(x))
        for d in range(n):
            assert (shards[d][:sizes[d]]
                    == want[offs[d]:offs[d] + sizes[d]]).all(), (m, d)
            assert (shards[d][sizes[d]:] == 0).all(), (m, d)

        def rt(v):
            shard = reduce_scatter_flat(v[0], "data")
            return all_gather_flat(shard, "data", sizes=sizes)[None]
        out = np.asarray(jax.jit(shard_map(
            rt, mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(x))
        for d in range(n):
            assert (out[d] == want).all(), (m, d)

    # typed shape errors fire at trace time
    wrong = build_generalized(n + 1, 0)
    try:
        jax.jit(shard_map(
            lambda v: allreduce_flat(v[0], "data", wrong)[None],
            mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(np.zeros((n, 8), np.float32))
    except ShapeError as e:
        assert e.expected == n + 1 and e.actual == n
    else:
        raise AssertionError("wrong-P schedule did not raise ShapeError")
    print("ok ragged")


def check_execplan():
    """The ExecPlan executor on real forced-host devices: integer inputs
    must reproduce the numpy sum *bit-exactly* for every bucket count,
    and the Pallas combine_n-routed path must match the chained-add path
    (same fp32 pairwise sums, one fused kernel call per pipeline tick).
    """
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(7)
    from repro.core.execplan import compile_plan
    for m in [1, 13, 257]:
        x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)
        want = x.sum(0)
        scheds = [build_generalized(n, r) for r in range(max_r(n) + 1)]
        scheds.append(build_ring(n))
        for sched in scheds:
            for nb in (1, 2, 4):
                f = jax.jit(shard_map(
                    lambda v, s=sched, b=nb: allreduce_flat(
                        v[0], "data", s, n_buckets=b)[None],
                    mesh=mesh, in_specs=P("data", None),
                    out_specs=P("data", None)))
                out = np.asarray(f(x))
                for d in range(n):
                    assert (out[d] == want).all(), \
                        (m, sched.kind, sched.r, nb, d)
    # combine_n-routed steps (check_vma=False: old-JAX replication
    # checkers have no pallas rule) == chained jnp.add, bit for bit.
    # The latency-optimal schedule batches several combines per tick into
    # one kernel call; ring additionally covers add-free (recv-only)
    # ticks in its all-gather half -- pallas must skip those, not crash.
    lat_opt = build_generalized(n, max_r(n))
    assert any(st.n_adds > 1 for st in compile_plan(lat_opt).steps)
    for sched in (lat_opt, build_ring(n)):
        x = rng.integers(-1000, 1000, (n, 257)).astype(np.int32)
        outs = {}
        for comb in ("pallas", "add"):
            f = jax.jit(shard_map(
                lambda v, s=sched, c=comb: allreduce_flat(
                    v[0], "data", s, n_buckets=2, combine=c)[None],
                mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None), check_vma=False))
            outs[comb] = np.asarray(f(x))
        assert (outs["pallas"] == outs["add"]).all()
        assert (outs["add"][0] == x.sum(0)).all()
    print("ok execplan")


def check_a2a():
    """Schedule-driven all-to-all on real devices: both plan kinds (and
    the cost-model "auto" pick) bit-equal to ``lax.all_to_all`` on int
    data, pipelined buckets included; non-divisible lengths raise the
    typed ShapeError instead of mis-permuting."""
    from jax import lax

    from repro.core.execplan import simulate_a2a
    from repro.core.schedule import ShapeError
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(21)
    for mult in (1, 3, 32):
        m = n * mult
        x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)
        ref = None
        for kind in ("direct", "bruck", "auto"):
            for nb in (1, 2):
                f = jax.jit(shard_map(
                    lambda v, k=kind, b=nb: all_to_all_flat(
                        v[0], "data", kind=k, n_buckets=b)[None],
                    mesh=mesh, in_specs=P("data", None),
                    out_specs=P("data", None)))
                out = np.asarray(f(x))
                if ref is None:
                    g = jax.jit(shard_map(
                        lambda v: lax.all_to_all(
                            v[0].reshape(n, -1), "data", 0, 0).reshape(1, -1),
                        mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None)))
                    ref = np.asarray(g(x))
                assert (out == ref).all(), (mult, kind, nb)
        sim = simulate_a2a([x[d] for d in range(n)], "direct")
        for d in range(n):
            assert (ref[d] == sim[d]).all(), (mult, d)
    try:
        jax.jit(shard_map(
            lambda v: all_to_all_flat(v[0], "data")[None],
            mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(np.zeros((n, n + 1), np.int32))
    except ShapeError as e:
        assert e.actual == n + 1
    else:
        raise AssertionError("non-divisible all-to-all did not raise")
    print("ok a2a")


def check_maxreduce():
    """Non-sum monoids on real devices: max/min allreduce bit-exact vs
    lax.pmax/pmin on int32 (incl. values past 2**24, which an f32
    accumulation cast would corrupt), Pallas-vs-elementwise parity for
    the max kernel, mean == psum / P bit-exact on int-valued f32, and
    the dp_grad_allreduce(op="max") + grads_all_finite wiring."""
    from jax import lax

    from repro.parallel.api import (ParallelConfig, dp_grad_allreduce,
                                    grads_all_finite)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(22)
    scheds = [build_generalized(n, r) for r in range(max_r(n) + 1)]
    scheds.append(build_ring(n))
    for m in (1, 13, 257):
        # values straddle 2**24 so any f32 round-trip would be caught
        x = rng.integers(-(1 << 28), 1 << 28, (n, m)).astype(np.int32)
        refs = {"max": x.max(0), "min": x.min(0)}
        for sched in scheds:
            for comb in ("max", "min"):
                for nb in (1, 2):
                    f = jax.jit(shard_map(
                        lambda v, s=sched, c=comb, b=nb: allreduce_flat(
                            v[0], "data", s, combine=c, n_buckets=b)[None],
                        mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None)))
                    out = np.asarray(f(x))
                    assert (out == refs[comb][None]).all(), \
                        (m, sched.kind, sched.r, comb, nb)
        g = jax.jit(shard_map(
            lambda v: lax.pmax(v[0], "data")[None], mesh=mesh,
            in_specs=P("data", None), out_specs=P("data", None)))
        assert (np.asarray(g(x)) == refs["max"][None]).all()

    # pallas-routed max == elementwise max, bit for bit (check_vma=False:
    # old-JAX replication checkers have no pallas rule)
    x = rng.integers(-1000, 1000, (n, 257)).astype(np.int32)
    sched = build_generalized(n, max_r(n))
    outs = {}
    for comb in ("max:pallas", "max"):
        f = jax.jit(shard_map(
            lambda v, c=comb: allreduce_flat(
                v[0], "data", sched, combine=c, n_buckets=2)[None],
            mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None), check_vma=False))
        outs[comb] = np.asarray(f(x))
    assert (outs["max:pallas"] == outs["max"]).all()
    assert (outs["max"][0] == x.max(0)).all()

    # mean == psum / P bit-exact on integer-valued f32
    xf = x.astype(np.float32)
    f = jax.jit(shard_map(
        lambda v: jnp_stack_pair(
            allreduce_flat(v[0], "data", sched, combine="mean"),
            lax.psum(v[0], "data") / n),
        mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
    got = np.asarray(f(xf))
    assert (got[0] == got[1]).all()

    # dp_grad_allreduce(op=) + the max-allreduce non-finite detector
    pc = ParallelConfig(dp_axes=("data",), dp=n)
    tree = {"a": rng.integers(-(1 << 28), 1 << 28, (n, 13)).astype(np.int32)}

    def ours(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = dp_grad_allreduce(loc, pc, mean=False, op="max")
        return jax.tree.map(lambda v: v[None], out)

    a = jax.jit(shard_map(ours, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))(tree)
    assert (np.asarray(a["a"])[0] == tree["a"].max(0)).all()

    grads = {"w": rng.standard_normal((n, 7)).astype(np.float32)}
    bad = {"w": grads["w"].copy()}
    bad["w"][n - 1, 3] = np.inf     # one non-finite value on ONE rank

    def finite(t):
        loc = jax.tree.map(lambda v: v[0], t)
        return grads_all_finite(loc, pc)[None]

    f = jax.jit(shard_map(finite, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))
    assert np.asarray(f(grads)).all()          # every rank: all finite
    assert not np.asarray(f(bad)).any()        # every rank saw the inf

    # affine bookends act ONCE over the hierarchical composition: premul
    # scales by f (never f^n_levels) and mean divides by the full P
    if n % 2 == 0:
        from repro.core.monoid import premul_sum
        from repro.topology import Level, Topology
        from repro.topology.fabric import TPU_DCN

        names = ("pod", "data")
        hmesh = jax.make_mesh((2, n // 2), names)
        topo = Topology((Level("pod", 2, TPU_DCN),
                         Level("ici", n // 2, TPU_V5E_ICI)),
                        name=f"maxreduce-2x{n // 2}")
        xf = rng.integers(-1000, 1000, (n, 37)).astype(np.float32)
        # premul by 0.5 is exact in f32 -> compare against numpy; mean's
        # divide-by-P is compiled by XLA as a reciprocal multiply (not
        # correctly rounded for non-power-of-two P), so its reference is
        # the in-program lax.psum(v)/P -- the same divide lax users get
        from jax import lax

        def hier(flat, c):
            return hierarchical_allreduce(flat, names, topo, r=0,
                                          mean=False, combine=c)

        def both(v):
            flat = v.reshape(-1)
            s = lax.psum(flat, names)
            import jax.numpy as jnp
            return jnp.stack([hier(flat, premul_sum(0.5)), 0.5 * s,
                              hier(flat, "mean"), s / n])[None]

        got = np.asarray(jax.jit(shard_map(
            both, mesh=hmesh, in_specs=P(names, None),
            out_specs=P(names, None, None)))(xf))
        for d in range(n):
            assert (got[d, 0] == 0.5 * xf.sum(0)).all(), d  # np-exact
            assert (got[d, 0] == got[d, 1]).all(), d        # == 0.5*psum
            assert (got[d, 2] == got[d, 3]).all(), d        # == psum / P
    print("ok maxreduce")


def jnp_stack_pair(a, b):
    import jax.numpy as jnp
    return jnp.stack([a, b])[None]


def check_moe_dispatch():
    """MoE forward under the three dispatch modes: the schedule-driven
    all-to-all path must match the GShard (lax.all_to_all) oracle
    bit-exactly (both are pure permutations of the same blocks), and
    the TP-sharded local path to fp32 exactness."""
    import jax.numpy as jnp  # noqa: F401

    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.moe import ep_group_size, moe_apply
    from repro.parallel.api import ParallelConfig

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(33)
    E, d, ff, k = 2 * n, 32, 48, 2    # experts split evenly for any n
    cfg = ModelConfig(name="t", family="moe", d_model=d, n_layers=1,
                      n_heads=4, n_kv_heads=4, d_ff=ff, vocab=128,
                      moe=MoEConfig(n_experts=E, top_k=k, d_expert=ff))
    p = {"router": {"w": rng.standard_normal((d, E)).astype(np.float32)},
         "experts": {
             "w1": 0.1 * rng.standard_normal((E, d, ff)).astype(np.float32),
             "w3": 0.1 * rng.standard_normal((E, d, ff)).astype(np.float32),
             "w2": 0.1 * rng.standard_normal((E, ff, d)).astype(np.float32)}}
    x = rng.standard_normal((n, 24, d)).astype(np.float32)

    outs = {}
    for disp in ("tp", "gshard", "schedule"):
        pc = ParallelConfig(dp_axes=("data",), dp=n, tp=1,
                            moe_dispatch=disp)
        assert ep_group_size(pc, E) == (1 if disp == "tp" else n)

        def f(xv, pp, pc=pc):
            y, aux = moe_apply(pp, xv, cfg, pc)
            return y, aux[None]

        g = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("data", None, None), P()),
            out_specs=(P("data", None, None), P("data"))))
        y, aux = g(x, p)
        outs[disp] = np.asarray(y)
    assert (outs["gshard"] == outs["schedule"]).all(), \
        "schedule-driven dispatch != GShard oracle"
    np.testing.assert_allclose(outs["tp"], outs["gshard"],
                               rtol=1e-6, atol=1e-6)
    print("ok moe_dispatch")


def check_elastic_resize():
    """Elastic resize across prime dp counts (8 -> 7 -> 5): non-power-of
    -two survivor meshes are first-class for the generalized allreduce,
    so shrinking never pads or waits for spares.  Checks the zero1
    opt-state reset on layout change (the flat moment buffers are
    ``(dp * ceil(N/dp),)`` -- dp-dependent) and ``restore_latest`` both
    across a layout change and after a post-resize checkpoint."""
    import tempfile

    from repro.checkpoint.checkpoint import latest_steps
    from repro.data.pipeline import DataConfig
    from repro.models.config import ModelConfig
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    from repro.train.optimizer import OptConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                      head_dim=16, act="swiglu")
    d = tempfile.mkdtemp(prefix="repro_elastic_resize_")
    runner = ElasticRunner(
        cfg, OptConfig(lr=1e-3, warmup_steps=2, total_steps=60),
        ElasticConfig(ckpt_dir=d, ckpt_every=4, param_mode="zero1"),
        DataConfig(seq_len=16, global_batch=8), mesh_shape=(8, 1))
    runner.run(4)                       # checkpoint lands at step 4 (dp=8)
    m8 = np.asarray(jax.device_get(runner.opt["m"]))
    assert m8.any(), "moments should be warm after 4 steps"

    # ---- shrink to a prime: dp=7 --------------------------------------
    runner.dc = DataConfig(seq_len=16, global_batch=14)
    runner.resize((7, 1), devices=jax.devices()[:7])
    assert runner.pc.dp == 7
    m7 = np.asarray(jax.device_get(runner.opt["m"]))
    assert m7.shape != m8.shape, "zero1 flat layout must change with dp"
    assert not m7.any(), "dp-dependent zero1 moments must reset on resize"
    assert int(runner.opt["step"]) > 0, "scalar step count survives"

    # ---- restore_latest across the layout change ----------------------
    # the newest checkpoint was written at dp=8: params (global arrays)
    # restore exactly; the incompatible zero1 buffers stay fresh.
    runner.ckpt.wait()
    step = runner.restore_latest()
    assert step == 4, step
    assert not np.asarray(jax.device_get(runner.opt["m"])).any()
    logs = runner.run(2)
    assert all(np.isfinite(r["loss"]) for r in logs)
    print("ok elastic_resize 8->7")

    # ---- shrink again: dp=5, then checkpoint + restore at dp=5 --------
    runner.dc = DataConfig(seq_len=16, global_batch=10)
    runner.resize((5, 1), devices=jax.devices()[:5])
    assert runner.pc.dp == 5
    logs = runner.run(2)                # steps 6,7; checkpoint at step 8
    logs += runner.run(1)
    runner.ckpt.wait()
    assert 8 in latest_steps(d), latest_steps(d)
    assert runner.restore_latest() == 8
    logs = runner.run(2)
    assert all(np.isfinite(r["loss"]) for r in logs)
    print("ok elastic_resize 8->7->5")


def check_serve():
    """Continuous-batching engine on a dp=2 x tp=2 mesh: batched decode
    on the paged cache is bit-identical to the single-request path, and
    the TP decode collectives route through ``autotune.choose()``
    against a measured tuning table -- the trace-time picks must report
    ``source="measured"`` and prefer the family the table says is
    faster (``traff_rounds`` in the fabricated ladder below)."""
    import tempfile

    from repro.launch.mesh import make_mesh, parallel_config_for
    from repro.models.config import ModelConfig
    from repro.models.model import init_params
    from repro.serve.engine import Engine, Request
    from repro.tuning import policy
    from repro.tuning.cache import (Measurement, TuningCache,
                                    current_fingerprint)

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                      head_dim=16, act="swiglu")
    # fabricate a measured ladder (64 B .. ~4 MiB, x4 spacing) where
    # traff_rounds always beats generalized(0): every decode-size query
    # interpolates in-range, so choose() must answer from the table
    fp = current_fingerprint()
    cache = TuningCache()
    for k in range(9):
        nb = 64 * 4 ** k
        cache.record(fp, Measurement(2, nb, "generalized", 0, 1, 9.0))
        cache.record(fp, Measurement(2, nb, "traff_rounds", 0, 1, 5.0))
    path = cache.save(os.path.join(
        tempfile.mkdtemp(prefix="repro_serve_tuning_"), "tuning.json"))
    os.environ["REPRO_TUNING_CACHE"] = str(path)
    policy.invalidate()
    try:
        mesh = make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices()[:4])
        pc = parallel_config_for(mesh, param_mode="dp", tuning=True)
        params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))
        eng = Engine(cfg, pc, mesh, params, batch_slots=2, max_len=32,
                     prefill_chunk=8, block_size=4)
        rng = np.random.default_rng(3)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, n)
                        .astype(np.int32), max_new_tokens=4)
                for n in (3, 9, 5, 12, 7)]
        eng.generate(reqs)
        for r in reqs:
            assert r.done and len(r.out_tokens) == 4, r
        for m in eng.kv:
            m.check()
            assert m.n_used == 0
        choices = eng.decode_choices
        assert choices, "decode collectives must trace through choose()"
        ops = {op for op, _, _ in choices}
        assert ops == {"psum", "all_gather"}, ops
        for op, nbytes, c in choices:
            assert c.source == "measured", (op, nbytes, c)
        psum_kinds = {c.kind for op, _, c in choices if op == "psum"}
        assert psum_kinds == {"traff_rounds"}, psum_kinds
        # batched continuous decode == solo B=1 path, same compiled step
        solo = Engine(cfg, pc, mesh, params, batch_slots=1, max_len=32,
                      prefill_chunk=8, block_size=4, bundle=eng.bundle)
        for r in reqs:
            r2 = Request(prompt=r.prompt, max_new_tokens=4)
            solo.generate([r2])
            assert r2.out_tokens == r.out_tokens, \
                (len(r.prompt), r.out_tokens, r2.out_tokens)
    finally:
        os.environ.pop("REPRO_TUNING_CACHE", None)
        policy.invalidate()
    print("ok serve")


def check_conformance():
    """Acceptance sweep vs the real lax references, P in {2,3,5,6,7,8,16}
    on meshes over the first P of 16 forced host devices: max/min/mean
    allreduce (the traff_rounds and dual_root families included) and
    both all-to-all kinds, divisible and ragged sizes, each bit-exact vs
    lax.pmax / lax.pmin / lax.psum / lax.all_to_all."""
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < 16:
        print("ok conformance (skipped: needs 16 devices)")
        return
    rng = np.random.default_rng(42)
    for n in (2, 3, 5, 6, 7, 8, 16):
        mesh = Mesh(np.array(devs[:n]), ("data",))
        for m in (3 * n, 3 * n + 1, 1, max(n - 1, 1)):
            x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)
            r = max_r(n) if m % n else 0
            sched = build_generalized(n, r)
            # the skew-sorted kind under an adversarial relabeling: same
            # compiled structure replayed on permuted devices, must stay
            # bit-exact vs lax.psum on the real mesh
            order = tuple(np.roll(np.arange(n)[::-1], 1).tolist())
            sorted_sched = build_sorted_generalized(n, r, order)
            traff = build_traff_rounds(n)
            dual = build_dual_root(n)
            nb = 2 if m > n else 1
            a2a = m % n == 0

            def f(v, s=sched, ss=sorted_sched, tr=traff, du=dual,
                  nb=nb, n=n, a2a=a2a):
                vi = v[0]
                vf = vi.astype(jnp.float32)
                outs = [
                    allreduce_flat(vi, "data", s, combine="sum",
                                   n_buckets=nb),
                    lax.psum(vi, "data"),
                    allreduce_flat(vi, "data", s, combine="max"),
                    lax.pmax(vi, "data"),
                    allreduce_flat(vi, "data", s, combine="min"),
                    lax.pmin(vi, "data"),
                    allreduce_flat(vf, "data", s, combine="mean"),
                    lax.psum(vf, "data") / n,
                    allreduce_flat(vi, "data", ss, combine="sum",
                                   n_buckets=nb),
                    allreduce_flat(vi, "data", tr, combine="sum",
                                   n_buckets=nb),
                    allreduce_flat(vi, "data", du, combine="sum",
                                   n_buckets=nb),
                    allreduce_flat(vi, "data", tr, combine="max"),
                    allreduce_flat(vi, "data", du, combine="min",
                                   n_buckets=1),
                ]
                if a2a:
                    outs += [
                        all_to_all_flat(vi, "data", kind="direct"),
                        all_to_all_flat(vi, "data", kind="bruck"),
                        lax.all_to_all(vi.reshape(n, -1), "data", 0,
                                       0).reshape(-1),
                    ]
                return [o[None] for o in outs]

            n_out = 16 if a2a else 13
            g = jax.jit(shard_map(
                f, mesh=mesh, in_specs=P("data", None),
                out_specs=[P("data", None)] * n_out))
            outs = [np.asarray(o) for o in g(x)]
            pairs = [("sum", 0, 1), ("max", 2, 3), ("min", 4, 5),
                     ("mean", 6, 7), ("sorted_sum", 8, 1),
                     ("traff_sum", 9, 1), ("dual_sum", 10, 1),
                     ("traff_max", 11, 3), ("dual_min", 12, 5)]
            if a2a:
                pairs += [("a2a_direct", 13, 15), ("a2a_bruck", 14, 15)]
            for name, i, j in pairs:
                assert (outs[i] == outs[j]).all(), (n, m, name)
            assert (outs[0][0] == x.sum(0)).all(), (n, m)
            assert (outs[2][0] == x.max(0)).all(), (n, m)
        print(f"ok conformance P={n}")
    print("ok conformance")


def check_overlap():
    """Backward-overlapped bucketed gradient sync: the bit-exactness
    gate of the overlap design.  For dense, scan-stacked (deep cycles)
    and MoE archs on the 8-device mesh, three train-step arms run 3
    steps from identical initial state:

    * ``whole``    -- overlap off (one post-backward tree allreduce);
    * ``post``     -- reverse-layer buckets synced after the backward;
    * ``backward`` -- the same buckets dispatched in-backward via the
      ``custom_vjp`` markers.

    post and backward run *identical* per-bucket collectives over
    identical leaf lists -- only dispatch timing differs -- so their
    fp32 params must match bit-for-bit every step.  whole-vs-bucketed
    changes the element->chunk assignment (different fp32 association),
    so it is held to allclose, not bit equality."""
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.model import init_params
    from repro.parallel.api import ParallelConfig
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.step import make_train_step

    n = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(n, 1), ("data", "model"))
    configs = {
        "dense": ModelConfig(name="t", family="dense", n_layers=2,
                             d_model=64, n_heads=4, n_kv_heads=2,
                             d_ff=160, vocab=256, head_dim=16,
                             act="swiglu"),
        "scan": ModelConfig(name="t", family="dense", n_layers=6,
                            d_model=48, n_heads=4, n_kv_heads=4,
                            d_ff=96, vocab=128, head_dim=12,
                            act="swiglu"),
        "moe": ModelConfig(name="t", family="moe", n_layers=2,
                           d_model=32, n_heads=4, n_kv_heads=4,
                           d_ff=48, vocab=128,
                           moe=MoEConfig(n_experts=2 * n, top_k=2,
                                         d_expert=48)),
    }
    oc = OptConfig(lr=1e-3)
    rng = np.random.default_rng(7)
    for arch, cfg in configs.items():
        tok = rng.integers(0, cfg.vocab, (n, 16)).astype(np.int32)
        lab = rng.integers(0, cfg.vocab, (n, 16)).astype(np.int32)
        batch = {"tokens": tok, "labels": lab}
        arms = {"whole": dict(overlap_bucket_bytes=None),
                "post": dict(overlap_bucket_bytes=32 << 10,
                             overlap_dispatch="post"),
                "backward": dict(overlap_bucket_bytes=32 << 10,
                                 overlap_dispatch="backward")}
        state = {}
        for name, kw in arms.items():
            pc = ParallelConfig(dp=n, tp=1, param_mode="dp", **kw)
            bundle = make_train_step(cfg, pc, mesh, oc, donate=False)
            params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))
            opt = init_opt_state(params, pc=pc, specs=bundle.specs)
            losses = []
            for _ in range(3):
                params, opt, metrics = bundle.train_step(params, opt,
                                                         batch)
                losses.append(float(metrics["loss"]))
            state[name] = (jax.device_get(params), losses)
        p_bwd, l_bwd = state["backward"]
        p_post, l_post = state["post"]
        p_whole, _ = state["whole"]
        for (pa, pb) in zip(jax.tree.leaves(p_bwd),
                            jax.tree.leaves(p_post)):
            assert pa.dtype == jnp.float32, pa.dtype
            assert (np.asarray(pa) == np.asarray(pb)).all(), \
                f"{arch}: backward vs post params not bit-identical"
        assert l_bwd == l_post, (arch, l_bwd, l_post)
        for (pa, pw) in zip(jax.tree.leaves(p_bwd),
                            jax.tree.leaves(p_whole)):
            np.testing.assert_allclose(np.asarray(pa), np.asarray(pw),
                                       rtol=2e-5, atol=2e-5)
        print(f"ok overlap {arch}")
    print("ok overlap")


def check_grad_interleave():
    """Satellite regression for the fsdp hybrid re-assembly in
    sync_grads_dp: a grads tree whose *tree-flatten order interleaves*
    fsdp-sharded and dp-replicated leaves must come back with every
    leaf matched to its own ParamSpec -- sharded leaves divided by dp
    (their VJP already reduce-scattered a DP sum), replicated leaves
    allreduced to the DP mean, and no cross-pairing between the two."""
    from jax.sharding import Mesh

    from repro.parallel.api import ParallelConfig, ParamSpec
    from repro.train.step import sync_grads_dp

    n = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    pc = ParallelConfig(dp_axes=("data",), dp=n, tp=1, param_mode="fsdp")
    rng = np.random.default_rng(11)
    # alphabetical flatten order a,b,c,d,e interleaves the two kinds
    specs = {"a": ParamSpec(),                 # replicated
             "b": ParamSpec(fsdp_dim=0),       # sharded
             "c": {"w": ParamSpec(),           # replicated (nested)
                   "x": ParamSpec(fsdp_dim=1)},
             "d": ParamSpec(fsdp_dim=0),
             "e": ParamSpec()}
    shapes = {"a": (3,), "b": (2, 5), "c": {"w": (4,), "x": (2, 2)},
              "d": (6,), "e": (2, 3)}
    full = jax.tree.map(
        lambda shp: rng.standard_normal((n,) + shp).astype(np.float32),
        shapes, is_leaf=lambda x: isinstance(x, tuple))

    def f(g):
        g = jax.tree.map(lambda v: v[0], g)
        out = sync_grads_dp(g, specs, pc)
        return jax.tree.map(lambda v: v[None], out)

    pspecs = jax.tree.map(lambda _: P("data"), shapes,
                          is_leaf=lambda x: isinstance(x, tuple))
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=(pspecs,),
                          out_specs=pspecs))
    out = jax.device_get(g(full))
    flat_out, _ = jax.tree.flatten(out)
    flat_in, _ = jax.tree.flatten(full)
    flat_specs = [specs["a"], specs["b"], specs["c"]["w"],
                  specs["c"]["x"], specs["d"], specs["e"]]
    for got, x, sp in zip(flat_out, flat_in, flat_specs):
        if sp.fsdp_dim is not None:
            want = x / n                  # per-device sum -> mean
        else:
            want = np.broadcast_to(x.mean(0), x.shape)  # DP mean
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    print("ok grad_interleave")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    checks = dict(allreduce=check_allreduce_flat, psum=check_vs_psum,
                  rsag=check_rs_ag, multiaxis=check_multiaxis,
                  zero=check_tree_zero, hier=check_hierarchical,
                  execplan=check_execplan, ragged=check_ragged,
                  a2a=check_a2a, maxreduce=check_maxreduce,
                  moe=check_moe_dispatch, conformance=check_conformance,
                  elastic_resize=check_elastic_resize, serve=check_serve,
                  overlap=check_overlap,
                  grad_interleave=check_grad_interleave)
    if which == "all":
        for fn in checks.values():
            fn()
    else:
        checks[which]()
    print("ALL-OK")
