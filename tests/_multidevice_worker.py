"""Worker executed in a subprocess with XLA_FLAGS forcing N host devices.

Runs a batch of multi-device checks and prints "ALL-OK" on success.
Keeping everything in one process amortizes JAX startup (~seconds).
"""
import os
import sys

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), \
    "must be launched with XLA_FLAGS=--xla_force_host_platform_device_count=N"

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.compat import shard_map  # noqa: E402
from repro.core.allreduce import (all_gather_flat, allreduce_flat,  # noqa: E402
                                  allreduce_tree, hierarchical_allreduce,
                                  hierarchical_allreduce_flat, psum_tree,
                                  reduce_scatter_flat, tree_all_gather,
                                  tree_reduce_scatter)
from repro.core.schedule import build_generalized, build_ring, max_r  # noqa: E402
from repro.topology import Level, Topology, build_hierarchical  # noqa: E402
from repro.topology.fabric import TPU_DCN  # noqa: E402
from repro.core.cost_model import TPU_V5E_ICI  # noqa: E402


def check_allreduce_flat():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    for m in [1, 5, n, 3 * n + 1, 257]:
        x = rng.standard_normal((n, m)).astype(np.float32)
        want = x.sum(0)
        scheds = [build_generalized(n, r) for r in range(max_r(n) + 1)]
        scheds.append(build_ring(n))
        if n & (n - 1) == 0:
            scheds.append(build_generalized(n, 0, "hypercube"))
            scheds.append(build_generalized(n, max_r(n), "hypercube"))
        for sched in scheds:
            f = jax.jit(shard_map(
                lambda v: allreduce_flat(v[0], "data", sched)[None],
                mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
            out = np.asarray(f(x))
            for d in range(n):
                np.testing.assert_allclose(out[d], want, rtol=2e-5, atol=2e-5)
    print("ok allreduce_flat")


def check_vs_psum():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(1)
    tree = {"w": rng.standard_normal((n, 33)).astype(np.float32),
            "b": rng.standard_normal((n, 7, 3)).astype(np.float32)}
    def ours(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = allreduce_tree(loc, "data", mean=True)
        return jax.tree.map(lambda v: v[None], out)
    def theirs(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = psum_tree(loc, "data", mean=True)
        return jax.tree.map(lambda v: v[None], out)
    fo = jax.jit(shard_map(ours, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
    ft = jax.jit(shard_map(theirs, mesh=mesh, in_specs=P("data"), out_specs=P("data")))
    a, b = fo(tree), ft(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=2e-5, atol=2e-5)
    print("ok vs_psum")


def check_rs_ag():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(2)
    m = 4 * n
    x = rng.standard_normal((n, m)).astype(np.float32)
    want = x.sum(0)

    def f(v):
        shard = reduce_scatter_flat(v[0], "data")
        return shard[None]
    out = np.asarray(jax.jit(shard_map(
        f, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))(x))
    u = m // n
    for d in range(n):
        np.testing.assert_allclose(out[d], want[d*u:(d+1)*u], rtol=2e-5, atol=2e-5)

    def g(v):
        shard = reduce_scatter_flat(v[0], "data")
        return all_gather_flat(shard, "data")[None]
    out = np.asarray(jax.jit(shard_map(
        g, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))(x))
    for d in range(n):
        np.testing.assert_allclose(out[d], want, rtol=2e-5, atol=2e-5)
    print("ok rs_ag")


def check_multiaxis():
    devs = len(jax.devices())
    if devs % 2:
        print("ok multiaxis (skipped)")
        return
    n0, n1 = 2, devs // 2
    mesh = jax.make_mesh((n0, n1), ("pod", "data"))
    n = n0 * n1
    rng = np.random.default_rng(3)
    x = rng.standard_normal((n, 11)).astype(np.float32)
    want = x.sum(0)
    sched = build_generalized(n, 1)
    f = jax.jit(shard_map(
        lambda v: allreduce_flat(v.reshape(-1), ("pod", "data"), sched)[None],
        mesh=mesh, in_specs=P(("pod", "data"), None),
        out_specs=P(("pod", "data"), None)))
    out = np.asarray(f(x))
    for d in range(n):
        np.testing.assert_allclose(out[d], want, rtol=2e-5, atol=2e-5)
    print("ok multiaxis")


def check_tree_zero():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(4)
    tree = {"a": rng.standard_normal((n, 13)).astype(np.float32),
            "b": rng.standard_normal((n, 2, 5)).astype(np.float32)}
    def f(t):
        loc = jax.tree.map(lambda v: v[0], t)
        shard, spec = tree_reduce_scatter(loc, "data", mean=True)
        back = tree_all_gather(shard, spec, "data")
        return jax.tree.map(lambda v: v[None], back)
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data")))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k])[0], tree[k].mean(0),
                                   rtol=2e-5, atol=2e-5)
    print("ok tree_zero")


def check_hierarchical():
    """Hierarchical allreduce over a ("pod", "data") mesh vs the numpy sum,
    for every outer r and several message sizes (incl. sizes that need
    padding), plus the autotuned pytree path."""
    devs = len(jax.devices())
    if devs % 2:
        print("ok hierarchical (skipped)")
        return
    shape = (2, devs // 2)
    names = ("pod", "data")
    mesh = jax.make_mesh(shape, names)
    n = devs
    topo = Topology((Level("pod", shape[0], TPU_DCN),
                     Level("ici", shape[1], TPU_V5E_ICI)),
                    name=f"test-{shape[0]}x{shape[1]}")
    rng = np.random.default_rng(5)
    for m in [1, 7, n, 3 * n + 1, 257]:
        x = rng.standard_normal((n, m)).astype(np.float32)
        want = x.sum(0)
        for r in range(max_r(shape[0]) + 1):
            hs = build_hierarchical(topo, r)
            f = jax.jit(shard_map(
                lambda v, h=hs: hierarchical_allreduce_flat(
                    v.reshape(-1), names, h)[None],
                mesh=mesh, in_specs=P(names, None),
                out_specs=P(names, None)))
            out = np.asarray(f(x))
            for d in range(n):
                np.testing.assert_allclose(out[d], want, rtol=2e-5,
                                           atol=2e-5)
    # autotuned pytree path (plan may resolve to flat or hierarchical)
    tree = {"w": rng.standard_normal((n, 33)).astype(np.float32),
            "b": rng.standard_normal((n, 7, 3)).astype(np.float32)}

    def g(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = hierarchical_allreduce(loc, names, topo, mean=True)
        return jax.tree.map(lambda v: v[None], out)

    out = jax.jit(shard_map(g, mesh=mesh, in_specs=P(names),
                            out_specs=P(names)))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k])[0], tree[k].mean(0),
                                   rtol=2e-5, atol=2e-5)
    print("ok hierarchical")


def check_ragged():
    """Ragged (uneven-shard) collectives on real devices.

    1. ``dp_grad_allreduce`` of an int32 pytree whose fused flat size is
       coprime with the device count must match ``psum`` bit-exactly
       (sums stay far below 2^24, so the f32 accumulation is exact).
    2. The ragged reduce-scatter owns the exact balanced chunk, and the
       allgatherv inverse reassembles the exact vector.
    3. A schedule compiled for the wrong P raises ShapeError (typed, not
       a stripped-under-``-O`` assert).
    """
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(11)
    from repro.core.allreduce import psum_tree
    from repro.core.schedule import ShapeError, ragged_offsets, ragged_sizes
    from repro.parallel.api import ParallelConfig, dp_grad_allreduce

    pc = ParallelConfig(dp_axes=("data",), dp=n)
    # leaf sizes chosen so the fused flat buffer (13 + 2*5 = 23 elems
    # at n=8, 23 % 8 != 0) rides the ragged split
    tree = {"a": rng.integers(-1000, 1000, (n, 13)).astype(np.int32),
            "b": rng.integers(-1000, 1000, (n, 2, 5)).astype(np.int32)}

    def ours(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = dp_grad_allreduce(loc, pc, mean=False)
        return jax.tree.map(lambda v: v[None], out)

    def theirs(t):
        loc = jax.tree.map(lambda v: v[0], t)
        out = psum_tree(loc, "data")
        return jax.tree.map(lambda v: v[None], out)

    a = jax.jit(shard_map(ours, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))(tree)
    b = jax.jit(shard_map(theirs, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data")))(tree)
    for k in tree:
        assert (np.asarray(a[k]) == np.asarray(b[k])).all(), k
        assert (np.asarray(a[k])[0] == tree[k].sum(0)).all(), k

    # ragged reduce-scatter + allgatherv round trip, exact shard contents
    for m in (1, n - 1, n + 1, 3 * n + 5, 257):
        x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)
        want = x.sum(0)
        sizes = ragged_sizes(m, n)
        offs = ragged_offsets(sizes)

        def rs(v):
            return reduce_scatter_flat(v[0], "data")[None]
        shards = np.asarray(jax.jit(shard_map(
            rs, mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(x))
        for d in range(n):
            assert (shards[d][:sizes[d]]
                    == want[offs[d]:offs[d] + sizes[d]]).all(), (m, d)
            assert (shards[d][sizes[d]:] == 0).all(), (m, d)

        def rt(v):
            shard = reduce_scatter_flat(v[0], "data")
            return all_gather_flat(shard, "data", sizes=sizes)[None]
        out = np.asarray(jax.jit(shard_map(
            rt, mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(x))
        for d in range(n):
            assert (out[d] == want).all(), (m, d)

    # typed shape errors fire at trace time
    wrong = build_generalized(n + 1, 0)
    try:
        jax.jit(shard_map(
            lambda v: allreduce_flat(v[0], "data", wrong)[None],
            mesh=mesh, in_specs=P("data", None),
            out_specs=P("data", None)))(np.zeros((n, 8), np.float32))
    except ShapeError as e:
        assert e.expected == n + 1 and e.actual == n
    else:
        raise AssertionError("wrong-P schedule did not raise ShapeError")
    print("ok ragged")


def check_execplan():
    """The ExecPlan executor on real forced-host devices: integer inputs
    must reproduce the numpy sum *bit-exactly* for every bucket count,
    and the Pallas combine_n-routed path must match the chained-add path
    (same fp32 pairwise sums, one fused kernel call per pipeline tick).
    """
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(7)
    from repro.core.execplan import compile_plan
    for m in [1, 13, 257]:
        x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)
        want = x.sum(0)
        scheds = [build_generalized(n, r) for r in range(max_r(n) + 1)]
        scheds.append(build_ring(n))
        for sched in scheds:
            for nb in (1, 2, 4):
                f = jax.jit(shard_map(
                    lambda v, s=sched, b=nb: allreduce_flat(
                        v[0], "data", s, n_buckets=b)[None],
                    mesh=mesh, in_specs=P("data", None),
                    out_specs=P("data", None)))
                out = np.asarray(f(x))
                for d in range(n):
                    assert (out[d] == want).all(), \
                        (m, sched.kind, sched.r, nb, d)
    # combine_n-routed steps (check_vma=False: old-JAX replication
    # checkers have no pallas rule) == chained jnp.add, bit for bit.
    # The latency-optimal schedule batches several combines per tick into
    # one kernel call; ring additionally covers add-free (recv-only)
    # ticks in its all-gather half -- pallas must skip those, not crash.
    lat_opt = build_generalized(n, max_r(n))
    assert any(st.n_adds > 1 for st in compile_plan(lat_opt).steps)
    for sched in (lat_opt, build_ring(n)):
        x = rng.integers(-1000, 1000, (n, 257)).astype(np.int32)
        outs = {}
        for comb in ("pallas", "add"):
            f = jax.jit(shard_map(
                lambda v, s=sched, c=comb: allreduce_flat(
                    v[0], "data", s, n_buckets=2, combine=c)[None],
                mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None), check_vma=False))
            outs[comb] = np.asarray(f(x))
        assert (outs["pallas"] == outs["add"]).all()
        assert (outs["add"][0] == x.sum(0)).all()
    print("ok execplan")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    checks = dict(allreduce=check_allreduce_flat, psum=check_vs_psum,
                  rsag=check_rs_ag, multiaxis=check_multiaxis,
                  zero=check_tree_zero, hier=check_hierarchical,
                  execplan=check_execplan, ragged=check_ragged)
    if which == "all":
        for fn in checks.values():
            fn()
    else:
        checks[which]()
    print("ALL-OK")
