"""Cost model: closed forms (paper eqs 15/25/36/44/37) vs compiled schedules."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost_model import (PAPER_10GE, optimal_r_analytic,
                                   optimal_r_search, schedule_cost,
                                   tau_best_sota, tau_bw_optimal,
                                   tau_intermediate, tau_latency_optimal,
                                   tau_openmpi_policy, tau_recursive_doubling,
                                   tau_recursive_halving, tau_ring)
from repro.core.schedule import build_generalized, max_r


def test_closed_forms_match_paper_numbers():
    f = PAPER_10GE
    P, m = 127, 425.0
    # latency-optimal must take ceil(lg 127) = 7 alpha terms
    t = tau_latency_optimal(P, m, f)
    assert t >= 7 * f.alpha
    # bandwidth-optimal has 14 steps
    assert tau_bw_optimal(P, m, f) >= 14 * f.alpha


@settings(max_examples=40, deadline=None)
@given(P=st.integers(2, 64), mexp=st.integers(5, 24))
def test_schedule_cost_bounded_by_closed_form(P, mexp):
    """The compiled schedule never exceeds the paper's worst-case formula."""
    f = PAPER_10GE
    m = float(2 ** mexp)
    for r in range(max_r(P) + 1):
        sc = schedule_cost(build_generalized(P, r), m, f)
        cf = tau_intermediate(P, m, r, f)
        assert sc <= cf * (1 + 1e-9), (P, m, r)


@settings(max_examples=30, deadline=None)
@given(P=st.integers(3, 200), mexp=st.integers(4, 26))
def test_analytic_r_near_optimal(P, mexp):
    """Eq (37) should be within one step of the exact argmin, and its cost
    within 25% of the optimum (the paper uses it as the runtime heuristic)."""
    f = PAPER_10GE
    m = float(2 ** mexp)
    ra = optimal_r_analytic(P, m, f)
    rs = optimal_r_search(P, m, f)
    ta = tau_intermediate(P, m, ra, f)
    ts = tau_intermediate(P, m, rs, f)
    assert ta <= ts * 1.25 or abs(ra - rs) <= 1


def test_proposed_beats_sota_nonpower2_small():
    """Fig 7/11 claim: for P=127 and small m the proposed algorithm beats
    the best of RD/RH/Ring."""
    f = PAPER_10GE
    P = 127
    for m in [128.0, 425.0, 1024.0, 4096.0]:
        r = optimal_r_search(P, m, f)
        assert tau_intermediate(P, m, r, f) < tau_best_sota(P, m, f)


def test_ring_wins_for_huge_messages():
    """Fig 8: for very large m the advantage over Ring becomes negligible
    (the model converges; Ring's cache behaviour is out of model scope)."""
    f = PAPER_10GE
    P = 127
    m = 2.0 ** 28
    r = optimal_r_search(P, m, f)
    ratio = tau_intermediate(P, m, r, f) / tau_ring(P, m, f)
    assert 0.9 < ratio < 1.1


def test_power_of_two_specials_agree():
    """For P=2^k, r=0 matches Recursive Halving and r=L matches Recursive
    Doubling cost exactly (no workaround overhead)."""
    f = PAPER_10GE
    P, m = 128, 65536.0
    assert tau_bw_optimal(P, m, f) == pytest.approx(
        tau_recursive_halving(P, m, f), rel=1e-12)
    # RD sends the whole vector each step; our latency-optimal sends
    # P chunks of size u = m/P per step -- identical volume.
    assert tau_latency_optimal(P, m, f) >= tau_recursive_doubling(P, m, f)


def test_openmpi_policy_switch():
    f = PAPER_10GE
    P = 127
    assert tau_openmpi_policy(P, 1024.0, f) == tau_recursive_doubling(P, 1024.0, f)
    assert tau_openmpi_policy(P, 1 << 20, f) == tau_ring(P, float(1 << 20), f)


def test_monotonic_step_tradeoff():
    """More removed steps -> fewer alpha terms, more beta terms (the paper's
    central trade-off), so cost curves in r are U-shaped (unimodal-ish):
    the argmin moves to smaller r as m grows."""
    f = PAPER_10GE
    P = 127
    rs = [optimal_r_search(P, float(m), f)
          for m in [64, 1024, 16384, 262144, 1 << 22]]
    assert all(a >= b for a, b in zip(rs, rs[1:]))
