"""Multi-device JAX collective tests.

The main pytest process must keep the default single CPU device (smoke
tests / benches depend on that), so multi-device checks run in a
subprocess with XLA_FLAGS forcing 8 host devices.
"""
import os
import subprocess
import sys

import pytest

# every test here spawns a forced-host-device worker process; under
# pytest-xdist they all pin to one worker (--dist loadgroup) so the
# heavyweight subprocesses never run concurrently with each other
pytestmark = pytest.mark.xdist_group("subprocess")

_WORKER = os.path.join(os.path.dirname(__file__), "_multidevice_worker.py")


def _run(which: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    # forced host devices are CPU devices: pin the platform so jax never
    # probes for accelerators (the TPU metadata probe retries for minutes
    # on non-TPU hosts)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, _WORKER, which], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, f"worker failed:\n{res.stdout}\n{res.stderr}"
    assert "ALL-OK" in res.stdout, res.stdout
    return res.stdout


@pytest.mark.slow
def test_allreduce_all_r_and_ring_8dev():
    _run("allreduce")


def test_matches_psum_8dev():
    _run("psum")


def test_reduce_scatter_all_gather_8dev():
    _run("rsag")


def test_multiaxis_pod_data_8dev():
    _run("multiaxis")


def test_zero_style_roundtrip_8dev():
    _run("zero")


@pytest.mark.slow
def test_allreduce_nonpower2_6dev():
    _run("allreduce", devices=6)


def test_hierarchical_pod_data_8dev():
    _run("hier")


def test_execplan_8dev():
    """ExecPlan executor: bit-exact integer allreduce for every r and
    ring, n_buckets in {1, 2, 4}, plus the Pallas combine_n-routed path
    matching chained adds."""
    _run("execplan")


@pytest.mark.slow
def test_execplan_nonpower2_6dev():
    _run("execplan", devices=6)


@pytest.mark.slow
def test_hierarchical_nonpower2_6dev():
    # (2, 3): non-power-of-two inner level
    _run("hier", devices=6)


def test_ragged_dp_allreduce_8dev():
    """Ragged dp_grad_allreduce == psum bit-exactly on int dtypes, exact
    ragged reduce-scatter shards + allgatherv inverse, typed ShapeError."""
    _run("ragged")


def test_ragged_dp_allreduce_6dev():
    # non-power-of-two device count: every size in the check is uneven
    _run("ragged", devices=6)


def test_all_to_all_8dev():
    """Schedule-driven all-to-all (direct/bruck/auto, pipelined buckets)
    bit-equal to lax.all_to_all on int data; ShapeError on P ∤ m."""
    _run("a2a")


@pytest.mark.slow
def test_all_to_all_nonpower2_6dev():
    # Bruck's bit-decomposition shifts must also close over Z6
    _run("a2a", devices=6)


def test_maxreduce_8dev():
    """max/min/mean monoids through every schedule: int-exact vs numpy
    and lax.pmax, Pallas-vs-elementwise parity, dp_grad_allreduce(op=),
    and the max-allreduce loss-scale finiteness detector."""
    _run("maxreduce")


@pytest.mark.slow
def test_maxreduce_nonpower2_6dev():
    _run("maxreduce", devices=6)


def test_moe_schedule_dispatch_8dev():
    """MoE forward with schedule-driven all-to-all dispatch == the
    GShard lax.all_to_all oracle bit-exactly; == TP-local to fp32."""
    _run("moe")


@pytest.mark.slow
def test_moe_schedule_dispatch_6dev():
    _run("moe", devices=6)
