"""Property + unit tests for the schedule compiler (the paper's algorithm)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.group import CyclicGroup, HypercubeGroup, MixedRadixGroup
from repro.core.schedule import (InvalidScheduleError, build_all_gather,
                                 build_generalized, build_reduce_scatter,
                                 build_ring, max_r, n_steps_log,
                                 result_multiplicity, vector_counts)
from repro.core.simulator import simulate, simulate_reduce_scatter


# ----------------------------------------------------------------- groups
def test_group_axioms_cyclic():
    g = CyclicGroup(7)
    for a in range(7):
        assert g.compose(a, g.inverse(a)) == 0
        for b in range(7):
            assert g.compose(a, b) == g.compose(b, a)  # abelian


def test_group_axioms_hypercube():
    g = HypercubeGroup(8)
    for a in range(8):
        assert g.inverse(a) == a          # self-inverse (Table 1.b)
        assert g.compose(a, a) == 0


@given(st.lists(st.integers(2, 5), min_size=1, max_size=4))
def test_mixed_radix_transitive(radices):
    g = MixedRadixGroup(tuple(radices))
    P = g.order
    # transitivity: for each pair (x, y) there is exactly one t_g: g(x)=y
    for x in range(min(P, 8)):
        images = [g.apply(e, x) for e in range(P)]
        assert sorted(images) == list(range(P))


# ------------------------------------------------------- schedule structure
@pytest.mark.parametrize("P", [2, 3, 4, 5, 7, 8, 12, 16, 31, 127])
def test_bw_optimal_matches_eq25(P):
    """r=0: 2*ceil(lg P) steps, 2(P-1) units sent, (P-1) combines."""
    s = build_generalized(P, 0)
    L = n_steps_log(P)
    assert s.n_steps == 2 * L
    assert s.units_sent == 2 * (P - 1)
    assert s.units_reduced == P - 1


@pytest.mark.parametrize("P", [2, 3, 5, 7, 8, 13, 16, 127])
def test_latency_optimal_matches_eq44(P):
    """r=L: ceil(lg P) steps, <= P*ceil(lg P) units, <= P(2L-2) combines."""
    L = n_steps_log(P)
    s = build_generalized(P, L)
    assert s.n_steps == L
    assert s.units_sent <= P * L
    # eq (44)'s worst-case gamma term, which degenerates at L=1 (P=2): there
    # each device still performs one add per result copy.
    assert s.units_reduced <= P * max(2 * L - 2, L)


@pytest.mark.parametrize("P", [3, 5, 7, 12, 127])
def test_intermediate_matches_eq36_bounds(P):
    """0<r<L: 2L-r steps; extra traffic bounded by (2^r-1)(L-1)."""
    L = n_steps_log(P)
    for r in range(1, L):
        s = build_generalized(P, r)
        assert s.n_steps == 2 * L - r
        extra = s.units_sent - 2 * (P - 1)
        assert 0 <= extra <= (2 ** r - 1) * max(L - 1, 1)


@pytest.mark.parametrize("P", [2, 4, 8, 16, 32])
def test_recursive_halving_special_case(P):
    """With the hypercube group and r=0 the schedule is Recursive Halving:
    every shift is self-inverse (pairwise exchange)."""
    s = build_generalized(P, 0, group_kind="hypercube")
    g = s.group
    for step in s.steps:
        assert g.inverse(step.shift) == step.shift
    assert s.n_steps == 2 * int(math.log2(P))
    assert s.units_sent == 2 * (P - 1)


@pytest.mark.parametrize("P", [2, 4, 8, 16, 32])
def test_recursive_doubling_special_case(P):
    """Hypercube group, r=L: log P steps of pairwise exchanges, all devices
    finish with the full result (no distribution phase)."""
    L = int(math.log2(P))
    s = build_generalized(P, L, group_kind="hypercube")
    assert s.n_steps == L
    for step in s.steps:
        assert s.group.inverse(step.shift) == step.shift
    assert s.units_sent == P * L  # each of P live vectors sent every step


def test_ring_structure():
    P = 7
    s = build_ring(P)
    comm = [st for st in s.steps if st.n_tx]
    assert len(comm) == 2 * (P - 1)
    assert all(st.shift == 1 for st in comm)          # single generator t
    assert all(st.n_tx == 1 for st in comm)           # one row at a time
    assert s.units_sent == 2 * (P - 1)
    assert s.units_reduced == P - 1


def test_result_multiplicity():
    assert result_multiplicity(7, 0) == 1
    assert result_multiplicity(7, 3) == 7
    assert vector_counts(7) == [7, 4, 2, 1]
    with pytest.raises(InvalidScheduleError):
        result_multiplicity(7, 4)


def test_incompatible_group_rejected():
    with pytest.raises(ValueError):
        build_generalized(6, 0, group_kind="hypercube")


# ------------------------------------------------------- numeric correctness
@settings(max_examples=60, deadline=None)
@given(P=st.integers(1, 48), data=st.data())
def test_generalized_allreduce_correct_any_P_r(P, data):
    """THE paper claim: the algorithm is correct for *any* P and any step
    count between ceil(lg P) and 2 ceil(lg P)."""
    r = data.draw(st.integers(0, max_r(P)))
    rng = np.random.default_rng(P * 100 + r)
    m = data.draw(st.integers(1, 3 * P + 5))
    vecs = [rng.standard_normal(m) for _ in range(P)]
    want = np.sum(vecs, axis=0)
    res = simulate(build_generalized(P, r), vecs)
    for d in range(P):
        np.testing.assert_allclose(res[d], want, rtol=1e-10, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(P=st.integers(2, 24))
def test_ring_correct(P):
    rng = np.random.default_rng(P)
    vecs = [rng.standard_normal(2 * P + 3) for _ in range(P)]
    want = np.sum(vecs, axis=0)
    res = simulate(build_ring(P), vecs)
    for d in range(P):
        np.testing.assert_allclose(res[d], want, rtol=1e-10, atol=1e-10)


@settings(max_examples=25, deadline=None)
@given(P=st.integers(2, 32))
def test_reduce_scatter_correct(P):
    rng = np.random.default_rng(P)
    u = 3
    vecs = [rng.standard_normal(u * P) for _ in range(P)]
    want = np.sum(vecs, axis=0)
    chunks, owners = simulate_reduce_scatter(build_reduce_scatter(P), vecs)
    assert sorted(owners) == list(range(P))
    for d in range(P):
        np.testing.assert_allclose(chunks[d], want[owners[d]*u:(owners[d]+1)*u],
                                   rtol=1e-10)
    # canonical layout: device d owns chunk d
    assert owners == list(range(P))


@pytest.mark.parametrize("P", [2, 4, 8, 16])
def test_hypercube_numeric(P):
    rng = np.random.default_rng(P)
    vecs = [rng.standard_normal(P) for _ in range(P)]
    want = np.sum(vecs, axis=0)
    for r in [0, n_steps_log(P)]:
        res = simulate(build_generalized(P, r, group_kind="hypercube"), vecs)
        for d in range(P):
            np.testing.assert_allclose(res[d], want, rtol=1e-10)


@pytest.mark.parametrize("P", [2, 3, 5, 7, 12, 16, 31])
def test_bruck_allgather_comparison(P):
    """Paper section 7: the Bruck-based allgather has the same step count
    and traffic as the generalized distribution phase, but leaves each
    device's chunks in a rotated order (the 'additional data shift' the
    proposed algorithm avoids)."""
    from repro.core.schedule import build_bruck_all_gather
    br = build_bruck_all_gather(P)
    ag = build_all_gather(P)
    assert br.n_steps == ag.n_steps == n_steps_log(P)
    assert br.units_sent == ag.units_sent == P - 1
    # our distribution phase: device d's rows, read in place order,
    # start at chunk d and step contiguously (no reorder needed).
    for d in range(P):
        ours = [ag.final_chunk_index(k, d) for k in range(P)]
        assert ours == [(d - e) % P for e in range(P)]
    # at the slot level both produce the same logical result -- the
    # executor's gather map absorbs Bruck's buffer rotation (that map IS
    # the "additional data shift" of the paper's section 7).  The
    # schedules are genuinely different, visible in the shift pattern:
    # Bruck doubles (1, 2, 4, ...), ours follows floor(N_i/2).
    br_shifts = [s.shift for s in br.steps]
    ag_shifts = [s.shift for s in ag.steps]
    assert br_shifts == [2 ** i for i in range(len(br_shifts))]
    if P in (7, 12, 31):
        assert br_shifts != ag_shifts, (P, br_shifts, ag_shifts)


@pytest.mark.parametrize("radices,compatible", [
    ("2,3", True), ("2,2,3", True), ("4,2", True), ("2,5", True),
    ("2,2,2,2", True), ("3,2", False), ("3,3", False)])
def test_mixed_radix_group_suitability(radices, compatible):
    """Paper section 7: 'any suitable group T_P' may drive the algorithm.
    The compiler decides suitability: the enumeration must be
    digit-borrow-free at every halving boundary.  Suitable groups compile
    + verify + simulate correctly; unsuitable ones are rejected (never
    miscompiled)."""
    P = 1
    for x in radices.split(","):
        P *= int(x)
    if not compatible:
        with pytest.raises(InvalidScheduleError):
            build_generalized(P, 0, group_kind=f"mixed:{radices}")
        return
    s = build_generalized(P, 0, group_kind=f"mixed:{radices}")
    assert s.units_sent == 2 * (P - 1)
    rng = np.random.default_rng(P)
    vecs = [rng.standard_normal(P + 1) for _ in range(P)]
    res = simulate(s, vecs)
    for d in range(P):
        np.testing.assert_allclose(res[d], np.sum(vecs, axis=0), rtol=1e-10)


def test_non_commutative_op_supported():
    """The generalized algorithm preserves combination order enough for
    non-commutative-but-associative ops when the group is cyclic (the paper
    notes dissemination-based algorithms need commutativity; ours doesn't
    for r=0).  We verify with string concatenation as the op."""
    P = 5
    vecs = [np.array([f"{d}"], dtype=object) for d in range(P)]
    res = simulate(build_generalized(P, 0), vecs,
                   op=lambda a, b: a + b)  # object-array elementwise concat
    # every device must end with a permutation-consistent full combination
    for d in range(P):
        got = res[d][0]
        assert sorted(got) == [str(i) for i in range(P)]
