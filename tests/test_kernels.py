"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_combine import combine_n, fused_combine
from repro.kernels.rmsnorm import rmsnorm


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# -------------------------------------------------------------- fused_combine
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [1, 127, 4096, 130_000])
def test_fused_combine(n, dtype):
    rng = np.random.default_rng(n)
    a = _rand(rng, (n,), dtype)
    b = _rand(rng, (n,), dtype)
    got = fused_combine(a, b, interpret=True, block=8 * 1024)
    want = ref.fused_combine_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-6)


@pytest.mark.parametrize("k", [2, 3, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_n(k, dtype):
    rng = np.random.default_rng(k)
    s = _rand(rng, (k, 9_001), dtype)
    got = combine_n(s, interpret=True, block=2 * 1024)
    want = ref.combine_n_ref(s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_combine_fp32_accum_beats_bf16_chain():
    """The kernel accumulates in fp32: summing many near-cancelling bf16
    values must be more accurate than a bf16 chain."""
    rng = np.random.default_rng(0)
    k, n = 7, 1024
    s = (rng.standard_normal((k, n)) * 100).astype(np.float32)
    sb = jnp.asarray(s, jnp.bfloat16)
    got = np.asarray(combine_n(sb, interpret=True, block=1024), np.float32)
    exact = s.astype(np.float64).sum(0)
    chain = sb[0]
    for i in range(1, k):
        chain = (chain + sb[i]).astype(jnp.bfloat16)
    err_kernel = np.abs(got - exact).mean()
    err_chain = np.abs(np.asarray(chain, np.float32) - exact).mean()
    assert err_kernel <= err_chain * 1.05


# -------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (3, 5, 256), (1, 384), (1000, 64)])
def test_rmsnorm(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = _rand(rng, shape, dtype)
    w = _rand(rng, shape[-1:], dtype)
    got = rmsnorm(x, w, interpret=True, block_rows=16)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


# -------------------------------------------------------------- flash attn
def _attn_case(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype,
               bq=16, bk=32, seed=0):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (B, Hq, Sq, D), dtype)
    k = _rand(rng, (B, Hkv, Skv, D), dtype)
    v = _rand(rng, (B, Hkv, Skv, D), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    rtol, atol = (4e-2, 4e-2) if dtype == jnp.bfloat16 else (2e-5, 2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_selfattn(dtype):
    _attn_case(2, 4, 4, 64, 64, 32, True, None, dtype)


def test_flash_gqa():
    _attn_case(1, 8, 2, 48, 48, 16, True, None, jnp.float32)


def test_flash_mqa():
    _attn_case(2, 4, 1, 33, 33, 16, True, None, jnp.float32)


def test_flash_sliding_window():
    _attn_case(1, 2, 2, 96, 96, 16, True, 17, jnp.float32)


def test_flash_decode_offset():
    """Sq=1 decode against a long cache."""
    _attn_case(2, 4, 2, 1, 95, 16, True, None, jnp.float32, bq=1, bk=32)


def test_flash_decode_window():
    _attn_case(1, 2, 1, 1, 130, 16, True, 24, jnp.float32, bq=1, bk=32)


def test_flash_noncausal_encoder():
    _attn_case(2, 4, 4, 40, 40, 16, False, None, jnp.float32)


def test_flash_ragged_blocks():
    """Sequence lengths that don't divide the block sizes."""
    _attn_case(1, 2, 2, 37, 37, 16, True, None, jnp.float32, bq=16, bk=16)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_flash_property(data):
    B = data.draw(st.integers(1, 2))
    Hkv = data.draw(st.sampled_from([1, 2]))
    g = data.draw(st.sampled_from([1, 2, 4]))
    S = data.draw(st.integers(2, 70))
    D = data.draw(st.sampled_from([8, 16]))
    causal = data.draw(st.booleans())
    window = data.draw(st.sampled_from([None, 5, 16]))
    if not causal:
        window = None
    _attn_case(B, Hkv * g, Hkv, S, S, D, causal, window, jnp.float32,
               bq=16, bk=16, seed=S * D)
