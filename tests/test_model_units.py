"""Unit/property tests for model internals: MoE routing invariants,
RoPE, vocab-parallel CE, embeddings."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import rope, vocab_parallel_ce
from repro.models.moe import capacity, dispatch_indices, route
from repro.parallel.api import ParallelConfig


# ------------------------------------------------------------------ MoE
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_moe_dispatch_invariants(data):
    E = data.draw(st.sampled_from([4, 8, 16]))
    k = data.draw(st.integers(1, min(4, E)))
    T = data.draw(st.integers(5, 200))
    m = MoEConfig(n_experts=E, top_k=k, d_expert=8,
                  capacity_factor=data.draw(st.sampled_from([1.0, 1.25, 2.0])))
    rng = np.random.default_rng(T * E + k)
    # lax.top_k yields DISTINCT experts per token -- honour that contract
    top_e = np.stack([rng.permutation(E)[:k] for _ in range(T)])
    top_e = jnp.asarray(top_e, jnp.int32)
    eq, pos, keep = jax.jit(
        lambda te: dispatch_indices(te, m, T))(top_e)
    eq, pos, keep = np.asarray(eq), np.asarray(pos), np.asarray(keep)
    C = capacity(T, m)
    assert eq.shape == (E, C)
    # every queue entry is a valid token id or the sentinel T
    assert ((eq >= 0) & (eq <= T)).all()
    # no token appears twice in the same expert's queue
    for e in range(E):
        toks = eq[e][eq[e] < T]
        assert len(set(toks.tolist())) == len(toks)
    # kept assignments are exactly the in-capacity ones
    assert (keep == (pos < C)).all()
    # each kept (t, j) is present in expert top_e[t, j]'s queue
    for t in range(min(T, 30)):
        for j in range(k):
            if keep[t, j]:
                assert t in eq[top_e[t, j]]


def test_moe_router_probs_normalized():
    m = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((40, 16)), jnp.float32)
    p_router = {"w": jnp.asarray(rng.standard_normal((16, 8)) * 0.1,
                                 jnp.float32)}
    top_e, top_p, aux = route(p_router, x, m)
    np.testing.assert_allclose(np.asarray(top_p).sum(-1), 1.0, rtol=1e-5)
    assert float(aux) >= 0.0


# ------------------------------------------------------------------ RoPE
@settings(max_examples=15, deadline=None)
@given(S=st.integers(1, 33), D=st.sampled_from([8, 16, 64]))
def test_rope_preserves_norm_and_relativity(S, D):
    rng = np.random.default_rng(S * D)
    q = jnp.asarray(rng.standard_normal((1, 2, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, S, D)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    qr, kr = rope(q, k, pos, theta=10_000.0)
    # rotations preserve norms
    np.testing.assert_allclose(np.linalg.norm(np.asarray(qr), axis=-1),
                               np.linalg.norm(np.asarray(q), axis=-1),
                               rtol=2e-3, atol=2e-3)
    # relative property: <rot_i q, rot_j k> depends only on i - j
    if S >= 3:
        qr2, kr2 = rope(q, k, pos + 7, theta=10_000.0)
        a = np.einsum("bhd,bhd->bh", np.asarray(qr)[:, :, 2],
                      np.asarray(kr)[:, :, 0])
        b = np.einsum("bhd,bhd->bh", np.asarray(qr2)[:, :, 2],
                      np.asarray(kr2)[:, :, 0])
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


# ------------------------------------------------------- vocab-parallel CE
def test_ce_matches_dense_softmax_xent():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=50,
                      head_dim=8)
    pc = ParallelConfig(dp=1, tp=1)
    rng = np.random.default_rng(0)
    B, S = 2, 9
    x = jnp.asarray(rng.standard_normal((B, S, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 50)) * 0.3, jnp.float32)
    labels = rng.integers(0, 50, (B, S)).astype(np.int32)
    labels[0, :3] = -1  # masked
    total, count = vocab_parallel_ce({"w": w}, x, jnp.asarray(labels),
                                     cfg, pc, chunk=4)
    logits = np.asarray(x @ w, np.float64)
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) \
        + logits.max(-1)
    picked = np.take_along_axis(
        logits, np.maximum(labels, 0)[..., None], -1)[..., 0]
    mask = labels >= 0
    want = ((lse - picked) * mask).sum()
    assert int(count) == mask.sum()
    np.testing.assert_allclose(float(total), want, rtol=1e-4)


def test_ce_ignores_all_masked():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                      n_heads=1, n_kv_heads=1, d_ff=16, vocab=20,
                      head_dim=8)
    pc = ParallelConfig(dp=1, tp=1)
    x = jnp.ones((1, 4, 8), jnp.float32)
    w = jnp.ones((8, 20), jnp.float32)
    labels = jnp.full((1, 4), -1, jnp.int32)
    total, count = vocab_parallel_ce({"w": w}, x, labels, cfg, pc, chunk=2)
    assert int(count) == 0
    assert float(total) == 0.0
