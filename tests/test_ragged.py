"""Ragged (uneven-shard) collectives: property grid over every schedule
kind for sizes that do not divide the process count.

The oracle chain is the same as PR 2's: the symbolic simulator
(:mod:`repro.core.simulator`) runs *true* variable-width chunks (the
ideal ragged fabric an MPI implementation would use), the lowered
:func:`repro.core.execplan.simulate_plan` runs the padded physical
layout the JAX executor uses, and the two must agree bit-exactly on
integer inputs for every (P, r, kind, size, n_buckets).  The JAX side is
covered on real forced-host devices by
``tests/_multidevice_worker.py ragged``.
"""
import numpy as np
import pytest

from repro.core.autotune import choose
from repro.core.cost_model import (HOST_CPU, PAPER_10GE,
                                   ragged_pipelined_schedule_cost,
                                   ragged_schedule_cost, schedule_cost)
from repro.core.execplan import simulate_plan
from repro.core.schedule import (ShapeError, build_all_gather,
                                 build_bruck_all_gather, build_generalized,
                                 build_reduce_scatter, build_ring, max_r,
                                 ragged_offsets, ragged_sizes,
                                 ragged_step_units)
from repro.core.simulator import (simulate, simulate_all_gather,
                                  simulate_reduce_scatter)

PS = [2, 3, 5, 6, 7, 8]


def _sizes_grid(P):
    """Uneven sizes: below P, equal to 1, coprime with P, off-by-one."""
    grid = {1, 2, max(P - 1, 1), P, P + 1, 17, 29, 3 * P + 5}
    return sorted(m for m in grid if m >= 1)


def _ivecs(rng, P, m):
    return [rng.integers(-1000, 1000, m).astype(np.int64) for _ in range(P)]


# ---------------------------------------------------------------- geometry
def test_ragged_sizes_properties():
    for P in PS:
        for m in (0, 1, P - 1, P, P + 1, 1000003):
            sizes = ragged_sizes(m, P)
            assert len(sizes) == P
            assert sum(sizes) == m
            assert max(sizes) - min(sizes) <= 1
            assert sizes == tuple(sorted(sizes, reverse=True))
            offs = ragged_offsets(sizes)
            assert offs[0] == 0
            assert all(offs[c + 1] == offs[c] + sizes[c]
                       for c in range(P - 1))


def test_ragged_sizes_shape_errors():
    with pytest.raises(ShapeError) as ei:
        ragged_sizes(10, 0)
    assert ei.value.actual == 0
    with pytest.raises(ShapeError) as ei:
        ragged_sizes(-1, 4)
    assert ei.value.actual == -1
    err = ShapeError("boom", expected=8, actual=6)
    assert (err.expected, err.actual) == (8, 6)
    assert "expected 8" in str(err) and "got 6" in str(err)


def test_chunk_sizes_on_schedule():
    s = build_generalized(6, 1)
    assert s.chunk_sizes(20) == ragged_sizes(20, 6) == (4, 4, 3, 3, 3, 3)


# ----------------------------------------------- full ragged grid, exact
@pytest.mark.parametrize("P", PS)
def test_generalized_ragged_bit_exact(P):
    rng = np.random.default_rng(P)
    for r in range(max_r(P) + 1):
        sched = build_generalized(P, r)
        for m in _sizes_grid(P):
            vecs = _ivecs(rng, P, m)
            want = np.sum(vecs, axis=0)
            for out in simulate(sched, vecs):
                assert np.array_equal(out, want), (P, r, m)
            for out in simulate_plan(sched, vecs):
                assert np.array_equal(out, want), (P, r, m)


@pytest.mark.parametrize("P", PS)
def test_ring_ragged_bit_exact(P):
    rng = np.random.default_rng(P + 100)
    sched = build_ring(P)
    for m in _sizes_grid(P):
        vecs = _ivecs(rng, P, m)
        want = np.sum(vecs, axis=0)
        for out in simulate(sched, vecs):
            assert np.array_equal(out, want), (P, m)
        for out in simulate_plan(sched, vecs):
            assert np.array_equal(out, want), (P, m)


@pytest.mark.parametrize("P", PS)
def test_reduce_scatter_ragged_bit_exact(P):
    """The symbolic oracle returns the exact ragged chunk; the lowered
    plan returns it zero-filled to the physical width."""
    rng = np.random.default_rng(P + 200)
    sched = build_reduce_scatter(P)
    for m in _sizes_grid(P):
        vecs = _ivecs(rng, P, m)
        want = np.sum(vecs, axis=0)
        sizes = ragged_sizes(m, P)
        offs = ragged_offsets(sizes)
        chunks, owners = simulate_reduce_scatter(sched, vecs)
        got = simulate_plan(sched, vecs)
        assert owners == list(range(P))
        for d in range(P):
            exact = want[offs[d]:offs[d] + sizes[d]]
            assert np.array_equal(chunks[d], exact), (P, m, d)
            assert np.array_equal(got[d][:sizes[d]], exact), (P, m, d)
            assert (got[d][sizes[d]:] == 0).all(), (P, m, d)


@pytest.mark.parametrize("P", PS)
@pytest.mark.parametrize("builder", [build_all_gather,
                                     build_bruck_all_gather])
def test_all_gatherv_ragged_bit_exact(P, builder):
    """allgatherv: per-rank chunks whose lengths differ by one."""
    rng = np.random.default_rng(P + 300)
    sched = builder(P)
    for m in _sizes_grid(P):
        sizes = ragged_sizes(m, P)
        chunks = [rng.integers(-1000, 1000, sizes[d]).astype(np.int64)
                  for d in range(P)]
        want = np.concatenate(chunks)
        for out in simulate_all_gather(sched, chunks):
            assert np.array_equal(out, want), (P, m)
        for out in simulate_plan(sched, chunks):
            assert np.array_equal(out, want), (P, m)


@pytest.mark.parametrize("n_buckets", [2, 3, 4])
def test_bucketed_ragged_replay_identical(n_buckets):
    """Pipelined bucket splits must not change a bit on ragged sizes."""
    for P in (3, 6, 8):
        rng = np.random.default_rng(P * 10 + n_buckets)
        for r in (0, max_r(P)):
            sched = build_generalized(P, r)
            for m in (1, P + 1, 29):
                vecs = _ivecs(rng, P, m)
                want = np.sum(vecs, axis=0)
                for out in simulate_plan(sched, vecs, n_buckets=n_buckets):
                    assert np.array_equal(out, want), (P, r, m)


# ----------------------------------------------------- true-byte pricing
def test_ragged_cost_equals_uniform_when_divisible():
    for P in (4, 6, 8):
        for r in range(max_r(P) + 1):
            s = build_generalized(P, r)
            m = 64 * P
            assert ragged_schedule_cost(s, m, PAPER_10GE) == \
                schedule_cost(s, m, PAPER_10GE)


def test_ragged_cost_charges_no_padding_bytes():
    """The old executor padded every chunk to ceil(m/P); the ragged price
    must be strictly below that padded-uniform price and at least the
    ideal continuous m/P price."""
    for P in (5, 6, 7, 8):
        for r in range(max_r(P) + 1):
            s = build_generalized(P, r)
            m = 1024 * P + 1
            padded = P * (-(-m // P))
            c = ragged_schedule_cost(s, m, PAPER_10GE)
            assert c < schedule_cost(s, padded, PAPER_10GE), (P, r)
            assert c >= schedule_cost(s, m, PAPER_10GE) - 1e-12, (P, r)


def test_ragged_step_units_bounds():
    """Per-step maxima: between the floor-width and ceil-width uniform
    counts, and exactly n_tx * u for divisible sizes."""
    for P in (5, 8):
        s = build_reduce_scatter(P)
        m = 7 * P
        tx, _ = ragged_step_units(s, m)
        assert list(tx) == [st.n_tx * (m // P) for st in s.steps]
        m = 7 * P + 3
        lo, hi = m // P, -(-m // P)
        tx, add = ragged_step_units(s, m)
        for t, st in zip(tx, s.steps):
            assert st.n_tx * lo <= t <= st.n_tx * hi
        for a, st in zip(add, s.steps):
            assert st.n_adds * lo <= a <= st.n_adds * hi


def test_ragged_pipelined_degenerates_to_serial():
    s = build_generalized(8, 1)
    m = 8 * 4096 + 5
    assert ragged_pipelined_schedule_cost(s, m, HOST_CPU, 1) == \
        ragged_schedule_cost(s, m, HOST_CPU)
    # more buckets never beat the serial cost by more than the overlap
    # bound (total alpha grows with fill/drain ticks)
    c4 = ragged_pipelined_schedule_cost(s, m, HOST_CPU, 4)
    assert c4 > 0


def test_choose_prices_ragged_sizes_exactly():
    """The autotuner's model path must report the ragged cost for
    non-divisible sizes (not the uniform approximation)."""
    from repro.core.autotune import schedule_for
    from repro.core.cost_model import (ragged_choose_n_buckets,
                                       ragged_pipelined_schedule_cost)
    P, nbytes = 8, (1 << 16) + 36
    ch = choose(P, nbytes, HOST_CPU, tune=False)
    sched = schedule_for(ch, P)
    b = ragged_choose_n_buckets(sched, nbytes, HOST_CPU)
    want = (ragged_schedule_cost(sched, nbytes, HOST_CPU) if b == 1
            else ragged_pipelined_schedule_cost(sched, nbytes, HOST_CPU, b))
    assert ch.n_buckets == b
    assert ch.cost == pytest.approx(want, rel=1e-12)


def test_choose_classifies_raggedness_by_elements_not_bytes():
    """An f32 message of 16394 elements is 65576 bytes: the bytes divide
    P=8 but the elements do not -- the executor runs the ragged split,
    so the model must price it raggedly; and a byte count that is not a
    multiple of P can still be a *uniform* element split."""
    from repro.core.autotune import schedule_for
    from repro.core.cost_model import ragged_pipelined_schedule_cost as rpc
    P = 8
    # ragged elements, divisible bytes
    nbytes = 16394 * 4
    assert nbytes % P == 0 and (nbytes // 4) % P != 0
    ch = choose(P, nbytes, HOST_CPU, tune=False, itemsize=4)
    sched = schedule_for(ch, P)
    want = (ragged_schedule_cost(sched, nbytes, HOST_CPU, itemsize=4)
            if ch.n_buckets == 1
            else rpc(sched, nbytes, HOST_CPU, ch.n_buckets, 4))
    assert ch.cost == pytest.approx(want, rel=1e-12)
    # scaling check: pricing 16393 f32 elements must charge 4x the
    # element units, not the byte-granular split of 65572 bytes
    s = build_generalized(P, 0)
    tx_el, add_el = ragged_step_units(s, 16393)
    manual = sum(PAPER_10GE.alpha + 4 * tx * PAPER_10GE.beta
                 + 4 * add * PAPER_10GE.gamma
                 for tx, add, st in zip(tx_el, add_el, s.steps)
                 if st.n_tx or st.n_adds)
    c = ragged_schedule_cost(s, 16393 * 4, PAPER_10GE, itemsize=4)
    assert c == pytest.approx(manual, rel=1e-12)


def test_measured_grid_contains_ragged_sizes():
    from repro.tuning.measure import FULL_SIZES, SMOKE_SIZES
    for sizes in (SMOKE_SIZES, FULL_SIZES):
        assert any((nbytes // 4) % 8 for _, nbytes in sizes), \
            "tuning grid lost its ragged datapoints"
