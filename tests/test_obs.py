"""Observability layer: Chrome-trace golden schema, metrics snapshots,
predicted-vs-measured model-error exactness, structured logging, engine
request accounting, and arrival-skew telemetry."""
import io
import json
import math

import pytest

from repro.core.cost_model import (HOST_CPU, PAPER_10GE,
                                   ragged_pipelined_schedule_cost,
                                   ragged_tick_costs)
from repro.core.execplan import compile_plan, tick_structure
from repro.core.schedule import build_generalized, build_ring
from repro.obs import log as obs_log
from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram, Metrics
from repro.obs.skew import ArrivalRecorder, device_arrival_probe
from repro.obs.trace import Tracer
from repro.obs.validate import (fit_ratio, model_error_table,
                                predicted_ticks_us, report_markdown,
                                validate_ticks)


# ---------------------------------------------------------------------------
#  trace: Chrome trace-event golden schema
# ---------------------------------------------------------------------------

def test_trace_golden_schema(tmp_path):
    t = Tracer(enabled=True)
    with t.span("outer", cat="exec", kind="generalized", r=1):
        with t.span("inner", cat="exec"):
            t.counter("tx_bytes", 4096)
        t.counter("tx_bytes", 8192)
    t.instant("mark", cat="exec", step=3)
    path = t.save(str(tmp_path / "trace.json"), process_name="test-proc")
    doc = json.loads(open(path).read())

    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in meta} >= {"process_name", "thread_name"}
    assert meta[0]["args"]["name"] == "test-proc"

    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in spans} == {"outer", "inner"}
    for e in spans:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0
    # span args survive export
    outer = next(e for e in spans if e["name"] == "outer")
    assert outer["args"] == {"kind": "generalized", "r": 1}

    counters = [e for e in evs if e["ph"] == "C"]
    assert [c["args"]["tx_bytes"] for c in counters] == [4096, 8192]
    instants = [e for e in evs if e["ph"] == "i"]
    assert instants[0]["args"] == {"step": 3}
    # counter samples of a monotonic source must be non-decreasing
    vals = [c["args"]["tx_bytes"] for c in counters]
    assert vals == sorted(vals)


def test_trace_nesting_balanced():
    """Every child span's [ts, ts+dur] interval nests inside its parent's
    (same thread), and depth returns to zero when all spans close."""
    t = Tracer(enabled=True)
    with t.span("a"):
        assert t.depth == 1
        with t.span("b"):
            assert t.depth == 2
            with t.span("c"):
                assert t.depth == 3
    assert t.depth == 0
    evs = {e["name"]: e for e in t.export()["traceEvents"]
           if e["ph"] == "X"}
    for child, parent in (("c", "b"), ("b", "a")):
        c, p = evs[child], evs[parent]
        assert p["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-6


def test_trace_disabled_is_noop_and_allocation_free():
    t = Tracer(enabled=False)
    with t.span("never", cat="x"):
        t.counter("n", 1)
        t.instant("m")
    assert t.n_events == 0
    # the module-level fast path returns one shared null span object
    prev = obs_trace.set_tracer(Tracer(enabled=False))
    try:
        s1, s2 = obs_trace.span("a"), obs_trace.span("b", cat="c", k=1)
        assert s1 is s2
        with s1 as sp:
            assert sp.set(result=42) is sp
    finally:
        obs_trace.set_tracer(prev)


def test_trace_enable_disable_roundtrip():
    prev = obs_trace.set_tracer(Tracer(enabled=False))
    try:
        tr = obs_trace.enable(clear=True)
        with obs_trace.span("live", cat="t"):
            pass
        obs_trace.counter("c", 7)
        assert tr.n_events == 2
        obs_trace.disable()
        with obs_trace.span("dead"):
            pass
        assert tr.n_events == 2
        tr.clear()
        assert tr.n_events == 0
    finally:
        obs_trace.set_tracer(prev)


# ---------------------------------------------------------------------------
#  metrics
# ---------------------------------------------------------------------------

def test_counter_monotonic():
    m = Metrics()
    c = m.counter("tx")
    c.inc(5)
    c.inc(0)
    c.inc(3)
    assert c.value == 8
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 8  # rejected increment left no trace


def test_histogram_percentiles_and_moments():
    h = Histogram("lat")
    h.record_many(float(v) for v in range(1, 101))  # 1..100
    assert h.count == 100 and h.sum == 5050.0
    assert h.percentile(0) == 1.0 and h.percentile(100) == 100.0
    assert h.percentile(50) == pytest.approx(50.5)
    s = h.summary()
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert s["p90"] == pytest.approx(90.1)
    # moments stay exact past the sample cap
    h2 = Histogram("capped", cap=4)
    h2.record_many([1.0, 2.0, 3.0, 4.0, 1000.0])
    s2 = h2.summary()
    assert s2["count"] == 5
    assert s2["max"] == 1000.0
    assert s2["sum"] == 1010.0


def test_metrics_snapshot_and_save(tmp_path):
    m = Metrics()
    m.counter("replays").inc(3)
    m.gauge("depth").set(7)
    m.histogram("us").record_many([10.0, 20.0])
    snap = m.snapshot(extra={"model_error": [{"ratio": 1.0}]})
    assert snap["schema"] == "repro-metrics-v1"
    assert snap["counters"] == {"replays": 3}
    assert snap["gauges"] == {"depth": 7}
    assert snap["histograms"]["us"]["count"] == 2
    assert snap["model_error"] == [{"ratio": 1.0}]
    path = m.save(str(tmp_path / "m.json"))
    assert json.load(open(path))["counters"] == {"replays": 3}
    m.reset()
    assert m.snapshot()["counters"] == {}


# ---------------------------------------------------------------------------
#  validate: predicted-vs-measured exactness (the golden property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,r,n_buckets", [
    ("generalized", 1, 1), ("generalized", 2, 3), ("ring", 0, 1),
    ("ring", 0, 4)])
def test_model_error_exact_on_synthetic_time(kind, r, n_buckets):
    """Feeding the model's own per-tick timeline back as 'measured' must
    produce ratio exactly 1.0 -- the report is pure arithmetic."""
    P, nbytes = 8, 1 << 20
    sched = build_generalized(P, r) if kind == "generalized" \
        else build_ring(P)
    pred = predicted_ticks_us(sched, nbytes, PAPER_10GE,
                              n_buckets=n_buckets)
    row = validate_ticks(sched, nbytes, PAPER_10GE,
                         measured_ticks_us=pred, n_buckets=n_buckets)
    assert row["ratio"] == 1.0
    assert row["log2_ratio"] == 0.0
    assert row["max_tick_ratio"] == 1.0
    assert row["n_ticks"] == len(pred)


def test_validate_rejects_tick_count_mismatch():
    sched = build_ring(8)
    with pytest.raises(ValueError, match="ticks"):
        validate_ticks(sched, 4096, PAPER_10GE,
                       measured_ticks_us=[1.0, 2.0], n_buckets=1)


def test_model_error_table_and_fit_ratio():
    sched = build_generalized(8, 1)
    pred = predicted_ticks_us(sched, 4096, PAPER_10GE)
    # measured = 2x predicted everywhere -> every ratio 2, geomean 2
    report = {"kind": "generalized", "r": 1, "P": 8, "n_buckets": 1,
              "itemsize": 1, "nbytes": 4096,
              "ticks": [{"total_us": 2 * p} for p in pred]}
    rows = model_error_table([report, report], PAPER_10GE)
    assert [r["ratio"] for r in rows] == pytest.approx([2.0, 2.0])
    assert fit_ratio(rows) == pytest.approx(2.0)
    md = report_markdown(rows, title="t", fabric_name="paper-10ge")
    assert "| generalized | 1 | 1 | 4096 |" in md
    assert "Geometric-mean ratio: **2.000**" in md


def test_tick_costs_consistent_with_scalar_cost():
    """The per-tick breakdown is the single source of truth: its sum IS
    the pipelined scalar cost, and its length follows tick_structure."""
    for P, r, nb in [(8, 1, 2), (8, 2, 3), (12, 1, 4)]:
        sched = build_generalized(P, r)
        ticks = ragged_tick_costs(sched, 1 << 20, HOST_CPU, nb)
        plan = compile_plan(sched)
        assert len(ticks) == len(tick_structure(plan, nb))
        total = ragged_pipelined_schedule_cost(sched, 1 << 20, HOST_CPU, nb)
        assert sum(t["total_s"] for t in ticks) == total


def test_tick_structure_covers_every_step_once():
    plan = compile_plan(build_generalized(8, 1))
    B = 3
    ticks = tick_structure(plan, B)
    S = len(plan.steps)
    assert len(ticks) == S + B - 1
    seen = [(b, s) for tick in ticks for b, s in tick]
    assert len(seen) == len(set(seen)) == S * B
    for t, tick in enumerate(ticks):
        for b, s in tick:
            assert t == s + b  # bucket b runs step t-b at tick t


# ---------------------------------------------------------------------------
#  log: leveled logfmt diagnostics + unfiltered protocol rows
# ---------------------------------------------------------------------------

def test_logger_levels_via_env(monkeypatch):
    buf = io.StringIO()
    lg = obs_log.Logger("t", stream=buf)
    monkeypatch.setenv("REPRO_LOG", "warn")
    lg.info("dropped", a=1)
    lg.warn("kept", path="/tmp/x y")  # space forces quoting
    monkeypatch.setenv("REPRO_LOG", "debug")  # lazily re-read
    lg.debug("now_visible")
    lines = buf.getvalue().strip().splitlines()
    assert len(lines) == 2
    assert "event=kept" in lines[0] and 'path="/tmp/x y"' in lines[0]
    assert "level=warn" in lines[0]
    assert "event=now_visible" in lines[1]


def test_data_rows_bypass_level_filter(monkeypatch, capsys):
    monkeypatch.setenv("REPRO_LOG", "error")
    obs_log.data("executor,256KiB,pipelined,812.4")
    out = capsys.readouterr()
    assert out.out == "executor,256KiB,pipelined,812.4\n"
    assert out.err == ""


def test_get_logger_cached():
    assert obs_log.get_logger("same") is obs_log.get_logger("same")


# ---------------------------------------------------------------------------
#  skew: arrival-pattern telemetry
# ---------------------------------------------------------------------------

def test_arrival_recorder_stats():
    rec = ArrivalRecorder()
    for rank, ts in [(2, 12.0), (0, 10.0), (1, 10.5)]:
        rec.record(rank, ts_us=ts)
    st = rec.stats()
    assert st.n == 3
    assert st.deltas_us == (0.0, 0.5, 2.0)  # rank order, not record order
    assert st.skew_us == 2.0
    assert st.mean_delta_us == pytest.approx(2.5 / 3, abs=1e-3)
    rec.record(2, ts_us=10.0)  # re-record overwrites
    assert rec.stats().skew_us == 0.5
    rec.clear()
    empty = rec.stats()
    assert empty.n == 0 and empty.skew_us == 0.0 and empty.deltas_us == ()
    assert empty.to_dict()["deltas_us"] == []


def test_device_arrival_probe_runs():
    import jax
    st = device_arrival_probe(nbytes=1 << 10, reps=2)
    assert st.n == len(jax.devices())
    assert st.skew_us >= 0.0
    assert len(st.deltas_us) == st.n
    assert math.isfinite(st.mean_delta_us)


# ---------------------------------------------------------------------------
#  engine: always-on request accounting (tracing off)
# ---------------------------------------------------------------------------

def test_engine_stats_without_tracing():
    import jax
    import numpy as np

    from repro.configs import ARCHS, get_config, get_reduced
    from repro.launch.mesh import make_mesh
    from repro.models.model import init_params
    from repro.parallel.api import ParallelConfig
    from repro.serve.engine import Engine, Request

    assert not obs_trace.get_tracer().enabled
    arch = next(a for a in ARCHS if get_config(a).is_decoder)
    cfg = get_reduced(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))
    eng = Engine(cfg, pc, mesh, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (5,)).astype(np.int32),
                    max_new_tokens=3) for _ in range(3)]
    done = eng.generate(reqs)

    for r in done:
        assert r.done and len(r.out_tokens) == 3
        assert r.t_enqueue_us is not None
        assert r.t_first_token_us is not None
        assert r.t_done_us is not None
        assert r.ttft_us >= 0.0
        assert r.latency_us >= r.ttft_us
    st = eng.stats()
    assert st["requests"] == 3
    assert st["tokens"] == 9
    assert st["ticks"] > 0 and st["ticks"] >= st["prefill_ticks"]
    assert st["live"] == 0 and st["queued"] == 0
    assert st["ttft_us"]["count"] == 3
    assert st["request_latency_us"]["count"] == 3
    assert st["request_latency_us"]["p50"] >= st["ttft_us"]["min"]
