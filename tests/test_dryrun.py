"""Dry-run smoke: one real cell compiles on the production mesh in a
subprocess (512 virtual devices), producing memory/cost/collective
records.  The full 80-cell sweep is `python -m repro.launch.dryrun --all`
(results archived in results/dryrun/)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
@pytest.mark.xdist_group("subprocess")
def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # dryrun sets its own
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "hubert_xlarge", "--shape", "train_4k",
         "--singlepod-only", "--out", str(tmp_path)],
        env={**env, "PYTHONPATH": "src"},
        capture_output=True, text=True, timeout=900, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stdout + res.stderr
    rec = json.load(open(tmp_path / "hubert_xlarge__train_4k__16x16.json"))
    assert rec["status"] == "ok"
    assert rec["memory"]["temp_size_in_bytes"] > 0
    assert rec["cost"].get("flops", 0) > 0
    kinds = {c["kind"] for c in rec["collectives"]["summary"]}
    # TP sequence-parallel boundaries must show up as real collectives
    assert kinds & {"all-gather", "reduce-scatter", "all-reduce"}


def test_skip_rules_against_assignment():
    """The 40-cell grid resolves to the documented 33 runnable cells."""
    from repro.configs import ARCHS, get_config
    from repro.models.config import SHAPES, shape_applicable
    runnable, skipped = 0, []
    for a in ARCHS:
        for s in SHAPES.values():
            ok, why = shape_applicable(get_config(a), s)
            if ok:
                runnable += 1
            else:
                skipped.append((a, s.name, why))
    assert runnable == 33
    assert len(skipped) == 7
    assert ("hubert_xlarge" not in {a for a, _, _ in skipped}) is False
