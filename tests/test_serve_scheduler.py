"""Property-based harness for the continuous-batching scheduler.

Randomized workloads (prompt lengths, max_new_tokens, slot counts, block
pressure) drive :class:`repro.serve.engine.Engine` tick by tick while
checking the scheduler invariants:

  * at most one live request per slot, and no request on two slots;
  * no KV block owned by two slots / leaked (``KVBlockManager.check()``);
  * token conservation: every request gets exactly ``max_new_tokens``
    and ``Engine.stats()`` counts them exactly, under slot recycling;
  * admission is strict FIFO under equal priority;
  * greedy continuous-batch output is bit-identical to a B=1 solo run.

Runs under real ``hypothesis`` when installed and under the bundled
fallback engine (``tests/_hypothesis_compat``) otherwise.
"""
import jax
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.parallel.api import ParallelConfig
from repro.launch.mesh import make_mesh
from repro.serve.engine import Engine, Request

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                   head_dim=16, act="swiglu")
MAX_LEN = 32
CHUNK = 8

_CTX = {}


def _ctx():
    """One params/mesh/bundle shared by every engine in this module so
    the jitted serve step compiles once per (B, S) shape, not per
    hypothesis example."""
    if not _CTX:
        mesh = make_mesh((1, 1), ("data", "model"))
        pc = ParallelConfig(dp=1, tp=1)
        params, _ = init_params(TINY, pc, jax.random.PRNGKey(0))
        eng = Engine(TINY, pc, mesh, params, batch_slots=1,
                     max_len=MAX_LEN, prefill_chunk=CHUNK)
        _CTX.update(mesh=mesh, pc=pc, params=params, bundle=eng.bundle)
    return _CTX


def _engine(batch_slots, n_blocks=None, block_size=4, **kw):
    c = _ctx()
    return Engine(TINY, c["pc"], c["mesh"], c["params"],
                  batch_slots=batch_slots, max_len=MAX_LEN,
                  prefill_chunk=CHUNK, block_size=block_size,
                  n_blocks=n_blocks, bundle=c["bundle"], **kw)


def _requests(seed, lengths, max_new):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, TINY.vocab, n).astype(np.int32),
                    max_new_tokens=m)
            for n, m in zip(lengths, max_new)]


def _record_admissions(eng, admitted):
    """Wrap ``_admit`` to log the FIFO-pop order of admitted uids."""
    orig = eng._admit

    def wrapped():
        before = list(eng.queue)
        orig()
        n = len(before) - len(eng.queue)
        admitted.extend(r.uid for r in before[:n])
    eng._admit = wrapped


@settings(max_examples=12, deadline=None)
@given(batch_slots=st.integers(1, 3), tight=st.booleans(),
       data=st.data())
def test_scheduler_invariants(batch_slots, tight, data):
    n_req = data.draw(st.integers(1, 6))
    lengths = [data.draw(st.integers(1, 20)) for _ in range(n_req)]
    max_new = [data.draw(st.integers(1, 5)) for _ in range(n_req)]
    seed = data.draw(st.integers(0, 10**6))
    nb_max = -(-MAX_LEN // 4)
    # tight: roughly one resident request's worth of blocks -> queueing
    # and slot recycling under block pressure
    n_blocks = 1 + nb_max if tight else None
    eng = _engine(batch_slots, n_blocks=n_blocks)
    admitted = []
    _record_admissions(eng, admitted)
    reqs = _requests(seed, lengths, max_new)
    for r in reqs:
        eng.submit(r)

    guard = 0
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        guard += 1
        assert guard < 10_000, "scheduler did not make progress"
        # one request per slot, and never the same request on two slots
        live = [s.req.uid for s in eng.slots if s is not None]
        assert len(live) == len(set(live))
        # block-table consistency: no sharing, no leaks, rows in sync
        for m in eng.kv:
            m.check()
        # a live row never outgrows its reserved footprint
        for b, s in enumerate(eng.slots):
            if s is not None:
                total = len(s.req.prompt) + s.req.max_new_tokens
                assert s.fed <= total

    # FIFO admission: uids are assigned in submit order, so admission
    # order must be exactly the submission order
    assert admitted == [r.uid for r in reqs]

    # token conservation + exact stats under slot recycling
    for r in reqs:
        assert r.done and len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < TINY.vocab for t in r.out_tokens)
    st_ = eng.stats()
    assert st_["requests"] == n_req
    assert st_["tokens"] == sum(len(r.out_tokens) for r in reqs)
    assert st_["tokens"] == sum(max_new)
    assert st_["live"] == 0 and st_["queued"] == 0
    assert st_["ticks"] >= st_["prefill_ticks"] >= 0
    assert st_["ttft_us"]["count"] == n_req
    assert st_["request_latency_us"]["count"] == n_req
    for m in eng.kv:
        assert m.n_used == 0
        m.check()


@settings(max_examples=6, deadline=None)
@given(batch_slots=st.integers(2, 3), data=st.data())
def test_scheduler_greedy_matches_solo(batch_slots, data):
    n_req = data.draw(st.integers(2, 4))
    lengths = [data.draw(st.integers(1, 16)) for _ in range(n_req)]
    seed = data.draw(st.integers(0, 10**6))
    reqs = _requests(seed, lengths, [4] * n_req)
    _engine(batch_slots).generate(reqs)
    solo = _engine(1)
    for r in reqs:
        r2 = Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)
        solo.generate([r2])
        assert r2.out_tokens == r.out_tokens, \
            (len(r.prompt), r.out_tokens, r2.out_tokens)


def test_stats_wellformed_before_any_request_completes():
    """Satellite regression: Engine.stats() must return a well-formed,
    JSON-serializable snapshot with zeroed counts on a fresh engine and
    mid-flight -- the serving benchmark snapshots stats() around every
    QPS level, including before the first request finishes."""
    import json

    eng = _engine(2)
    s0 = eng.stats()
    json.dumps(s0)                       # plain JSON, no exception
    assert s0["requests"] == 0 and s0["tokens"] == 0
    assert s0["ticks"] == 0 and s0["prefill_ticks"] == 0
    assert s0["queued"] == 0 and s0["live"] == 0
    for hist in (s0["ttft_us"], s0["request_latency_us"]):
        assert hist["count"] == 0 and hist["sum"] == 0.0
        assert hist["mean"] is None and hist["p99"] is None

    # submitted but not yet stepped: the submission counter and gauges
    # move, finished-request distributions stay empty
    [r] = _requests(3, [6], [4])
    eng.submit(r)
    s1 = eng.stats()
    json.dumps(s1)
    assert s1["queued"] + s1["live"] == 1
    assert s1["requests"] == 1
    assert s1["request_latency_us"]["count"] == 0

    # one tick in (request still unfinished): still well-formed
    eng.step()
    s2 = eng.stats()
    json.dumps(s2)
    assert s2["ticks"] >= 1
    assert s2["request_latency_us"]["count"] == 0
