"""Multi-device parallelism checks (subprocess; 8 host devices).

Verifies the manual-SPMD model stack end to end: a train step under
(dp, tp) sharding with each param mode must produce the same loss and the
same updated parameters as the single-device reference.
"""
import os
import sys

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.launch.mesh import make_mesh, parallel_config_for
from repro.models.model import init_caches, init_params
from repro.parallel.api import ParallelConfig
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_serve_step, make_train_step

OC = OptConfig(lr=1e-3, warmup_steps=0, total_steps=100, grad_clip=None)

# Per-arch loss tolerance vs the single-device reference.  xlstm's mLSTM
# recurrence chains bf16 matmul outputs through an exponential-gated
# cumulative scan, so the dp=2 batch split (different device boundaries
# -> different reassociation of the same bf16 sums) compounds through the
# sequence dimension instead of averaging out; on jax 0.4.37's CPU
# backend the resulting loss drift is ~0.11 (absolute, at loss ~6.05)
# while every attention arch stays < 5e-3.  The updated-parameter check
# below stays at the tight default -- it would catch a genuine gradient
# sync bug that a loss-level gate this loose could hide.
_LOSS_TOL = {"xlstm_1_3b": 0.2}
_DEFAULT_LOSS_TOL = 5e-2


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        }
    if cfg.frontend == "vision":
        s_text = max(S - cfg.n_patches, 8)
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, s_text)), jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
                jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, s_text)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }


def _reference(cfg, batch):
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))
    opt = init_opt_state(params, pc)
    b = make_train_step(cfg, pc, mesh, OC, donate=False)
    p1, o1, m1 = b.train_step(params, opt, batch)
    return params, p1, float(m1["loss"])


def check_mode(arch: str, mode: str, mesh_shape, seed=0):
    cfg = get_reduced(arch)
    B, S = 4, 32
    batch = _batch(cfg, B, S, seed)
    params0, p_ref, loss_ref = _reference(cfg, batch)

    mesh = make_mesh(mesh_shape, ("data", "model"))
    pc = parallel_config_for(mesh, param_mode=mode)
    b = make_train_step(cfg, pc, mesh, OC, donate=False)
    # identical initial params: reuse the single-device init (global arrays)
    opt = init_opt_state(params0, pc, b.specs)
    p1, o1, m1 = b.train_step(params0, opt, batch)
    loss = float(m1["loss"])
    tol = _LOSS_TOL.get(arch, _DEFAULT_LOSS_TOL)
    assert abs(loss - loss_ref) < tol, (arch, mode, loss, loss_ref)
    # updated params must match the reference update
    err = max(np.max(np.abs(np.asarray(a, np.float32)
                            - np.asarray(b_, np.float32)))
              for a, b_ in zip(jax.tree.leaves(jax.device_get(p_ref)),
                               jax.tree.leaves(jax.device_get(p1))))
    assert err < 5e-2, (arch, mode, err)
    print(f"ok {arch} {mode} mesh={mesh_shape} loss={loss:.4f} "
          f"ref={loss_ref:.4f} param_err={err:.2e}")


def check_decode_tp(arch: str, mesh_shape):
    cfg = get_reduced(arch)
    mesh1 = make_mesh((1, 1), ("data", "model"))
    pc1 = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(cfg, pc1, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    B = 2
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)

    def run(mesh, pc):
        bundle = make_serve_step(cfg, pc, mesh)
        # cache arrays are GLOBAL (batch dim sharded over dp by in_specs)
        caches = init_caches(cfg, pc, B, 32)
        lg, caches = bundle.serve_step(params, toks, caches, jnp.int32(0))
        lg2, _ = bundle.serve_step(
            params, jnp.argmax(lg[:, -1:], -1).astype(jnp.int32), caches,
            jnp.int32(8))
        return np.asarray(lg, np.float32), np.asarray(lg2, np.float32)

    a1, a2 = run(mesh1, pc1)
    mesh2 = make_mesh(mesh_shape, ("data", "model"))
    pc2 = parallel_config_for(mesh2, param_mode="dp")
    b1, b2 = run(mesh2, pc2)
    # scale-aware: bf16 accumulation-order changes across TP shards scale
    # with the logit magnitude (recurrentgemma's tied-embed logits ~ +-15)
    for a, b in [(a1, b1), (a2, b2)]:
        scale = max(np.abs(a).max(), 1.0)
        assert np.abs(a - b).max() / scale < 3e-2, (arch, np.abs(a-b).max())
    print(f"ok decode {arch} mesh={mesh_shape}")


def check_multipod():
    """(pod, data, model) = (2, 2, 2): hierarchical DP over (pod, data)."""
    arch = "granite_8b"
    cfg = get_reduced(arch)
    B, S = 4, 32
    batch = _batch(cfg, B, S)
    params0, p_ref, loss_ref = _reference(cfg, batch)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    pc = parallel_config_for(mesh, param_mode="zero1")
    b = make_train_step(cfg, pc, mesh, OC, donate=False)
    opt = init_opt_state(params0, pc, b.specs)
    p1, _, m1 = b.train_step(params0, opt, batch)
    assert abs(float(m1["loss"]) - loss_ref) < 5e-2
    err = max(np.max(np.abs(np.asarray(a, np.float32)
                            - np.asarray(c, np.float32)))
              for a, c in zip(jax.tree.leaves(jax.device_get(p_ref)),
                              jax.tree.leaves(jax.device_get(p1))))
    assert err < 5e-2, err
    print(f"ok multipod zero1 loss={float(m1['loss']):.4f} err={err:.2e}")


def check_group_collectives():
    """Training with the paper's schedule executors at the TP boundary
    (collective_impl="group") must match the XLA-native collectives."""
    from dataclasses import replace
    arch = "granite_8b"
    cfg = get_reduced(arch)
    B, S = 4, 32
    batch = _batch(cfg, B, S)
    params0, p_ref, loss_ref = _reference(cfg, batch)
    mesh = make_mesh((2, 4), ("data", "model"))
    pc = replace(parallel_config_for(mesh, param_mode="dp"),
                 collective_impl="group")
    b = make_train_step(cfg, pc, mesh, OC, donate=False)
    opt = init_opt_state(params0, pc, b.specs)
    p1, _, m1 = b.train_step(params0, opt, batch)
    assert abs(float(m1["loss"]) - loss_ref) < 5e-2
    err = max(np.max(np.abs(np.asarray(a, np.float32)
                            - np.asarray(c, np.float32)))
              for a, c in zip(jax.tree.leaves(jax.device_get(p_ref)),
                              jax.tree.leaves(jax.device_get(p1))))
    assert err < 5e-2, err
    print(f"ok group_collectives loss={float(m1['loss']):.4f} err={err:.2e}")


def check_seq_shard_decode():
    """TP-sequence-sharded KV cache (flash-decoding LSE merge) must match
    the replicated-cache single-device decode (MQA arch)."""
    cfg = get_reduced("granite_34b")        # MQA: kv=1
    pc1 = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(cfg, pc1, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    B = 2
    toks = rng.integers(0, cfg.vocab, (B, 6)).astype(np.int32)

    def run(mesh, pc, seq_shard):
        bundle = make_serve_step(cfg, pc, mesh, seq_shard=seq_shard)
        caches = init_caches(cfg, pc, B, 32, seq_shard=seq_shard)
        pos, outs = 0, []
        for t in range(6):
            lg, caches = bundle.serve_step(
                params, jnp.asarray(toks[:, t:t+1]), caches,
                jnp.int32(pos))
            pos += 1
            outs.append(np.asarray(lg, np.float32))
        return np.concatenate(outs, axis=1)

    ref = run(make_mesh((1, 1), ("data", "model")), pc1, False)
    mesh2 = make_mesh((2, 4), ("data", "model"))
    got = run(mesh2, parallel_config_for(mesh2, param_mode="dp"), True)
    scale = max(np.abs(ref).max(), 1.0)
    assert np.abs(ref - got).max() / scale < 3e-2
    print("ok seq_shard_decode")


if __name__ == "__main__":
    which = sys.argv[1]
    if which == "modes":
        for mode in ["dp", "zero1", "fsdp"]:
            check_mode("granite_8b", mode, (2, 4))
    elif which == "archs_tp":
        for arch in ["granite_34b", "mixtral_8x7b", "recurrentgemma_2b",
                     "xlstm_1_3b", "hubert_xlarge", "pixtral_12b",
                     "deepseek_moe_16b", "command_r_plus_104b"]:
            check_mode(arch, "dp", (2, 2))
    elif which == "decode":
        for arch in ["granite_8b", "recurrentgemma_2b"]:
            check_decode_tp(arch, (2, 4))
    elif which == "multipod":
        check_multipod()
    elif which == "seqshard":
        check_seq_shard_decode()
    elif which == "groupcoll":
        check_group_collectives()
    else:
        raise SystemExit(f"unknown {which}")
    print("ALL-OK")
