"""Multi-process runtime: coordinator/worker mesh, deterministic fault
injection, recovery to P-1 with bit-exact loss continuity, skew-aware
rescheduling.  Workers are real OS processes talking TCP; every fault is
a REPRO_FAULTS-style spec, so each scenario is exactly reproducible."""
import glob
import math
import os
import shutil

import numpy as np
import pytest

from repro.checkpoint.checkpoint import latest_steps
from repro.runtime.coordinator import Coordinator, CoordinatorConfig
from repro.runtime.faults import FaultPlan, parse_faults

# the Coordinator spawns real worker OS processes; serialize the module
# under pytest-xdist so meshes never fight for cores or ports
pytestmark = pytest.mark.xdist_group("subprocess")

TIMEOUT_S = 60.0  # generous per-barrier budget: CI boxes stall


def _cfg(tmp_path, name="ck", **kw):
    kw.setdefault("P", 3)
    kw.setdefault("dim", 8)
    kw.setdefault("batch", 4)
    kw.setdefault("lr", 0.2)
    kw.setdefault("ckpt_every", 2)
    kw.setdefault("step_timeout_s", TIMEOUT_S)
    return CoordinatorConfig(ckpt_dir=str(tmp_path / name), **kw)


def test_mesh_trains_and_checkpoints(tmp_path):
    cfg = _cfg(tmp_path, ckpt_every=3)
    with Coordinator(cfg) as c:
        recs = c.run(6)
    assert [r["step"] for r in recs] == list(range(6))
    assert all(r["P"] == 3 for r in recs)
    assert recs[-1]["loss"] < recs[0]["loss"]  # it actually learns
    assert latest_steps(cfg.ckpt_dir) == [3, 6]
    assert c.recoveries == []


def test_kill_recovery_bit_exact_vs_clean_run(tmp_path):
    """The acceptance arc: kill a worker mid-run; the mesh restores the
    last checkpoint, re-ranks the survivors, recompiles for P-1 (prime)
    and resumes -- with losses bit-identical to a clean coordinator
    launched at P-1 from the same checkpoint."""
    cfg = _cfg(tmp_path, P=4, faults="kill:rank=2,step=5")
    with Coordinator(cfg) as c:
        c.run(8)
        chaos = c.final_losses()
    [rec] = c.recoveries
    assert rec.failed_wids == (2,)
    assert rec.at_step == 5 and rec.restored_step == 4
    assert rec.new_P == 3  # prime survivor count: no padding, no spares
    assert rec.recovery_steps == 1

    # clean run: fresh mesh at P-1 restoring the same checkpoint
    clean_dir = tmp_path / "clean"
    os.makedirs(clean_dir)
    shutil.copytree(os.path.join(cfg.ckpt_dir, "step_00000004"),
                    clean_dir / "step_00000004")
    cfg2 = _cfg(tmp_path, name="clean", P=3, resume=True)
    with Coordinator(cfg2) as c2:
        c2.run(8)
        clean = c2.final_losses()
    assert c2.step == 8 and c2.recoveries == []
    for s in range(4, 8):
        assert chaos[s] == clean[s], (s, chaos[s], clean[s])  # bit-exact


def test_recovery_skips_torn_checkpoint(tmp_path):
    """A checkpoint torn after commit must not be restored: recovery
    quarantines it and falls back to the previous valid step."""
    cfg = _cfg(tmp_path, faults="ckpt_torn:step=4;kill:rank=1,step=5")
    with Coordinator(cfg) as c:
        recs = c.run(8)
    [rec] = c.recoveries
    assert rec.restored_step == 2  # step-4 ckpt was torn: fell back
    assert rec.new_P == 2 and rec.recovery_steps == 3
    assert glob.glob(os.path.join(cfg.ckpt_dir, "step_00000004.corrupt"))
    assert all(math.isfinite(r["loss"]) for r in recs)
    assert c.final_losses().keys() == set(range(8))


def test_death_before_first_checkpoint_restarts_from_zero(tmp_path):
    cfg = _cfg(tmp_path, faults="kill:rank=0,step=1", ckpt_every=50)
    with Coordinator(cfg) as c:
        c.run(3)
    [rec] = c.recoveries
    assert rec.restored_step == 0 and rec.new_P == 2
    assert c.final_losses().keys() == set(range(3))


def test_delay_fault_surfaces_in_skew_telemetry(tmp_path):
    cfg = _cfg(tmp_path, faults="delay:rank=1,step=2,us=40000",
               ckpt_every=50)
    with Coordinator(cfg) as c:
        recs = c.run(4)
    assert recs[2]["skew_us"] > 5000.0  # 40ms straggler dwarfs noise
    assert c.recoveries == []  # a straggler is not a death


def test_skew_reschedule_flips_to_latency_leaning(tmp_path):
    """sort_on_skew: a heavy measured straggler re-runs schedule
    selection with the live arrival deltas; the pinned bandwidth-optimal
    r=0 is overridden by the skew timeline's pick -- traff_rounds, whose
    final power-of-two rounds move the fewest bytes after the last
    arrival (robust winner across a swept delta neighborhood) -- and the
    new spec ships with the next step barrier and runs on the wire."""
    cfg = _cfg(tmp_path, ckpt_every=50,
               schedule_kind="generalized", schedule_r=0,
               sort_on_skew=True, skew_threshold_us=5000.0,
               faults="delay:rank=1,step=1,us=40000")
    with Coordinator(cfg) as c:
        recs = c.run(4)
    assert recs[0]["schedule"].startswith("generalized,r=0")
    assert recs[1]["skew_us"] > 5000.0
    assert recs[-1]["schedule"] == "traff_rounds,r=0"  # re-chosen
    assert recs[-1]["loss"] < recs[0]["loss"]


def test_sorted_schedule_runs_the_mesh(tmp_path):
    """The arrival-sorted relabeled schedule drives the real multi-
    process wire path end to end (routing permutations conjugated by the
    relabel) and matches the plain generalized run to reduction
    tolerance."""
    losses = {}
    for name, kind, order in [("base", "generalized", None),
                              ("sorted", "sorted", (3, 1, 0, 2))]:
        cfg = _cfg(tmp_path, name=name, P=4, dim=10, ckpt_every=50,
                   schedule_kind=kind, schedule_r=1, schedule_order=order)
        with Coordinator(cfg) as c:
            recs = c.run(4)
        losses[name] = [r["loss"] for r in recs]
        if order:
            assert all(r["schedule"] == "sorted,r=1,order=3-1-0-2"
                       for r in recs)
    np.testing.assert_allclose(losses["base"], losses["sorted"],
                               rtol=1e-9)


def test_fault_plan_fires_once():
    plan = FaultPlan(parse_faults("kill:rank=1,step=3;delay:rank=1,step=3,us=5"))
    assert plan.fire("delay", 3, 1).us == 5
    assert plan.fire("delay", 3, 1) is None
    assert plan.fire("kill", 3, 2) is None  # wrong rank
    assert plan.fire("kill", 3, 1).kind == "kill"
    assert plan.pending == ()


def test_regression_gate_recovery_steps_is_lower_is_better():
    """The chaos rows gate as costs: recovery_steps regresses when it
    GROWS past base*(1+tol); speedup keys keep their floor semantics."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from check_regression import compare
    base = {"kill": {"label": "kill", "recovery_steps": 1.0,
                     "recovered": 1.0, "speedup_execplan": 1.0}}
    ok = {"kill": {"label": "kill", "recovery_steps": 1.0,
                   "recovered": 1.0, "speedup_execplan": 1.2}}
    worse = {"kill": {"label": "kill", "recovery_steps": 2.0,
                      "recovered": 1.0, "speedup_execplan": 1.0}}
    keys = ["recovery_steps", "recovered", "speedup_execplan"]
    _, regs = compare(ok, base, keys, tolerance=0.35)
    assert regs == []
    _, regs = compare(worse, base, keys, tolerance=0.35)
    assert [r["key"] for r in regs] == ["recovery_steps"]
    assert regs[0]["direction"] == "<="
    # and a *drop* in recovery_steps (faster recovery) must NOT regress
    better = {"kill": {"label": "kill", "recovery_steps": 0.0,
                       "recovered": 1.0, "speedup_execplan": 1.0}}
    _, regs = compare(better, base, keys, tolerance=0.35)
    assert regs == []
    # speedup floor unchanged by the direction plumbing
    slow = {"kill": {"label": "kill", "recovery_steps": 1.0,
                     "recovered": 1.0, "speedup_execplan": 0.5}}
    _, regs = compare(slow, base, keys, tolerance=0.35)
    assert [r["key"] for r in regs] == ["speedup_execplan"]


def test_bad_fault_specs_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_faults("boom:step=1")
    with pytest.raises(ValueError, match="requires rank"):
        parse_faults("delay:step=1,us=5")
    with pytest.raises(ValueError, match="bad fault argument"):
        parse_faults("kill:rank=1,step=2,color=red")
