"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.launch.mesh import make_mesh
from repro.models.model import init_caches, init_params
from repro.parallel.api import ParallelConfig
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_serve_step, make_train_step


def _batch_for(cfg, B, S, rng):
    if cfg.frontend == "audio":
        return {
            "embeds": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        }
    if cfg.frontend == "vision":
        s_text = max(S - cfg.n_patches, 8)
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, s_text)), jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
                jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, s_text)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, specs = init_params(cfg, pc, jax.random.PRNGKey(0))
    opt = init_opt_state(params, pc)
    bundle = make_train_step(cfg, pc, mesh,
                             OptConfig(warmup_steps=2, total_steps=10),
                             donate=False)
    rng = np.random.default_rng(42)
    batch = _batch_for(cfg, B=2, S=32, rng=rng)
    p1, o1, m1 = bundle.train_step(params, opt, batch)
    assert np.isfinite(float(m1["loss"])), (arch, m1)
    p2, o2, m2 = bundle.train_step(p1, o1, batch)
    assert np.isfinite(float(m2["loss"]))
    # same batch twice: the optimizer must make progress
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5
    # param shapes unchanged
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail(f"shape change {a.shape}->{b.shape}"),
                 params, p2)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).is_decoder])
def test_decode_step_smoke(arch):
    cfg = get_reduced(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(1))
    B, S_max = 2, 64
    bundle = make_serve_step(cfg, pc, mesh)
    caches = init_caches(cfg, pc, B, S_max)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
    # prefill 8 tokens (only the last position is scored), then decode 3
    logits, caches = bundle.serve_step(params, toks, caches, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    pos = 8
    for i in range(3):
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, caches = bundle.serve_step(params, nxt, caches,
                                           jnp.int32(pos))
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        pos += 1


@pytest.mark.parametrize("arch", ["h2o_danube3_4b", "recurrentgemma_2b",
                                  "xlstm_1_3b", "mixtral_8x7b"])
def test_rolling_decode_smoke(arch):
    """long_500k-style decode: rolling window caches / recurrent state."""
    cfg = get_reduced(arch)
    if not cfg.subquadratic:
        pytest.skip("not sub-quadratic")
    mesh = make_mesh((1, 1), ("data", "model"))
    pc = ParallelConfig(dp=1, tp=1)
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(2))
    B = 1
    bundle = make_serve_step(cfg, pc, mesh, rolling=True)
    caches = init_caches(cfg, pc, B, max_len=10_000, rolling=True)
    rng = np.random.default_rng(1)
    pos = 0
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    for i in range(cfg.window + 5 if cfg.window else 8):
        logits, caches = bundle.serve_step(params, tok, caches,
                                           jnp.int32(pos))
        pos += 1
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_decode_matches_full_forward():
    """Teacher-forced decode logits must match the training forward's
    next-token distribution (cache correctness)."""
    from repro.models.model import loss_and_metrics, decode_step
    cfg = get_reduced("granite_8b")
    pc = ParallelConfig(dp=1, tp=1)
    params, specs = init_params(cfg, pc, jax.random.PRNGKey(3))
    rng = np.random.default_rng(7)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    # full forward logits
    from repro.models.model import forward
    hidden, _, _ = forward(params, specs, {"tokens": toks}, cfg, pc, sp=False)
    head = params["head"]
    full_logits = np.asarray(hidden.astype(jnp.float32) @
                             head["w"].astype(jnp.float32))

    # incremental decode
    caches = init_caches(cfg, pc, B, S)
    got = []
    for t in range(S):
        lg, caches = decode_step(params, specs, toks[:, t:t+1], caches,
                                 jnp.int32(t), cfg, pc)
        got.append(np.asarray(lg[:, 0], np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, full_logits, rtol=3e-2, atol=3e-2)


def test_param_counts_sane():
    """Full configs land near their published sizes (coarse check)."""
    import math
    expected = {
        "h2o_danube3_4b": 4.0e9, "granite_8b": 8.1e9, "granite_34b": 34e9,
        "command_r_plus_104b": 104e9, "hubert_xlarge": 1.0e9,
        "pixtral_12b": 12.4e9, "mixtral_8x7b": 46.7e9,
        "deepseek_moe_16b": 16.4e9, "recurrentgemma_2b": 2.7e9,
        "xlstm_1_3b": 1.3e9,
    }
    for arch, want in expected.items():
        cfg = get_config(arch)
        got = cfg.param_count()
        assert 0.5 * want < got < 1.8 * want, (arch, got, want)
