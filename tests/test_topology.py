"""repro.topology: hierarchical composition correctness + cost model.

Correctness is proven against the numpy oracle, which replays the actual
compiled per-level steps: exact integer sums, every device ending with
every reduced chunk, for non-power-of-two sizes at every level.
"""
import numpy as np
import pytest

from repro.core.cost_model import TPU_V5E_ICI, schedule_cost
from repro.core.schedule import max_r
from repro.topology import (Level, MULTI_POD_2X256, Topology,
                            bottleneck_fabric, build_hierarchical,
                            choose_collective, flat_cost, gpu_cluster,
                            hierarchical_cost, schedules_for_plan,
                            simulate_hierarchical, v5e_multipod, v5e_pod)
from repro.topology.fabric import GPU_IB, TPU_DCN
from repro.topology.hierarchical import HierarchicalSchedule

# non-power-of-two at each level, plus a 3-level machine
LEVEL_SHAPES = [(2, 3), (3, 5), (2, 16), (4, 6), (3, 2, 4)]


def _topo(sizes):
    return Topology(tuple(
        Level(f"l{i}", s, TPU_DCN if i == 0 else TPU_V5E_ICI)
        for i, s in enumerate(sizes)), name="x".join(map(str, sizes)))


# ---------------------------------------------------------------------------
#  simulator-verified correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", LEVEL_SHAPES)
def test_hierarchical_exact_sum_all_r(sizes):
    topo = _topo(sizes)
    P = topo.P
    rng = np.random.default_rng(0)
    for r in range(max_r(sizes[0]) + 1):
        hs = build_hierarchical(topo, r)
        for m in [1, 7, P, 3 * P + 1]:
            vecs = [rng.integers(-50, 50, m).astype(np.int64)
                    for _ in range(P)]
            want = np.sum(vecs, axis=0)
            got = simulate_hierarchical(hs, vecs)
            assert len(got) == P
            for d in range(P):
                # exact: integer arithmetic, no tolerance
                assert got[d].shape == want.shape
                assert (got[d] == want).all(), (sizes, r, m, d)


def test_hierarchical_float_matches_sum():
    topo = _topo((2, 3))
    P = topo.P
    rng = np.random.default_rng(1)
    hs = build_hierarchical(topo, 0)
    vecs = [rng.standard_normal(17).astype(np.float32) for _ in range(P)]
    want = np.sum(vecs, axis=0)
    for g in simulate_hierarchical(hs, vecs):
        np.testing.assert_allclose(g, want, rtol=1e-5, atol=1e-5)


def test_single_level_topology_degenerates():
    topo = v5e_pod(5)
    hs = build_hierarchical(topo, 1)
    assert hs.rs == () and hs.ag == ()
    vecs = [np.full(10, d, np.int64) for d in range(5)]
    want = np.sum(vecs, axis=0)
    for g in simulate_hierarchical(hs, vecs):
        assert (g == want).all()


def test_invalid_r_raises():
    with pytest.raises(Exception):
        build_hierarchical(_topo((2, 3)), max_r(2) + 1)


# ---------------------------------------------------------------------------
#  topology plumbing
# ---------------------------------------------------------------------------

def test_rank_coord_roundtrip():
    topo = _topo((3, 2, 4))
    for rank in range(topo.P):
        assert topo.rank(topo.coords(rank)) == rank
    # innermost level fastest-varying
    assert topo.coords(1) == (0, 0, 1)
    assert topo.coords(4) == (0, 1, 0)


def test_presets():
    assert MULTI_POD_2X256.P == 512
    assert MULTI_POD_2X256.sizes == (2, 256)
    assert v5e_pod(256).n_levels == 1
    g = gpu_cluster(4)
    assert g.sizes == (4, 8)
    assert g.outer.fabric == GPU_IB


def test_bottleneck_fabric_is_worst_per_term():
    topo = v5e_multipod()
    f = bottleneck_fabric(topo)
    assert f.alpha == max(TPU_DCN.alpha, TPU_V5E_ICI.alpha)
    assert f.beta == max(TPU_DCN.beta, TPU_V5E_ICI.beta)


# ---------------------------------------------------------------------------
#  cost model + autotuner
# ---------------------------------------------------------------------------

def _best_flat(topo, m):
    best = min(flat_cost(topo, m, r) for r in range(max_r(topo.P) + 1))
    return min(best, flat_cost(topo, m, kind="ring"))


def test_hierarchical_beats_flat_large_messages_multipod():
    """Acceptance: fast-ICI/slow-DCN topology, >= 64 MiB gradients."""
    topo = MULTI_POD_2X256
    for m in [64 * 2**20, 256 * 2**20, 2**30]:
        hier = min(hierarchical_cost(build_hierarchical(topo, r), m)
                   for r in range(max_r(topo.outer.size) + 1))
        assert hier < _best_flat(topo, m), m


def test_hierarchical_beats_flat_gpu_cluster():
    topo = gpu_cluster(16)
    m = 128 * 2**20
    hier = hierarchical_cost(build_hierarchical(topo, 0), m)
    assert hier < _best_flat(topo, m)


def test_choose_collective_consistent_and_optimal():
    topo = MULTI_POD_2X256
    for m in [1024, 2**20, 64 * 2**20]:
        plan = choose_collective(topo, m)
        sched = schedules_for_plan(plan, topo)
        if plan.kind == "hierarchical":
            assert isinstance(sched, HierarchicalSchedule)
            assert hierarchical_cost(sched, m) == pytest.approx(plan.cost)
        else:
            assert schedule_cost(sched, m, bottleneck_fabric(topo)) == \
                pytest.approx(plan.cost)
        # the plan is no worse than either family's best
        assert plan.cost <= _best_flat(topo, m) * (1 + 1e-12)


def test_choose_collective_prefers_hierarchical_for_large_m():
    assert choose_collective(MULTI_POD_2X256, 64 * 2**20).kind == \
        "hierarchical"


def test_choose_collective_single_level_is_flat():
    plan = choose_collective(v5e_pod(8), 2**20)
    assert plan.kind.startswith("flat")


def test_hierarchical_cost_tracks_message_shrink():
    """DCN traffic must be ~1/inner_size of the message: doubling only the
    inner level size should cut the outer-phase cost roughly in half."""
    m = 2**26
    small = v5e_multipod(2, 16)
    big = v5e_multipod(2, 32)
    ar_small = schedule_cost(build_hierarchical(small, 0).ar,
                             m / small.inner_size, TPU_DCN)
    ar_big = schedule_cost(build_hierarchical(big, 0).ar,
                           m / big.inner_size, TPU_DCN)
    assert ar_big < ar_small
