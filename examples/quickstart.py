"""Quickstart: the generalized allreduce end to end.

1. Compile a schedule for an awkward process count (P = 7) and inspect it.
2. Verify it numerically with the numpy simulator.
3. Autotune the step count r for a fabric + message size (paper eq 37).
4. Run the real JAX executor on 8 virtual devices inside shard_map --
   including an *uneven* (ragged) message size that does not divide the
   device count, priced by true moved bytes.

Run:  PYTHONPATH=src python examples/quickstart.py
(no XLA_FLAGS needed -- this script forces 8 host devices itself)
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np


def main():
    from repro.core.schedule import (build_generalized, max_r,
                                     schedule_summary)
    from repro.core.simulator import simulate
    from repro.core.cost_model import (PAPER_10GE, TPU_V5E_ICI,
                                       optimal_r_analytic, optimal_r_search,
                                       tau_best_sota, tau_intermediate)

    # --- 1/2: compile + verify for prime-ish P -------------------------
    P = 7
    print(f"== schedules for P={P} (non-power-of-two) ==")
    for r in range(max_r(P) + 1):
        s = build_generalized(P, r)
        print(" ", schedule_summary(s))
    rng = np.random.default_rng(0)
    vecs = [rng.standard_normal(21) for _ in range(P)]
    res = simulate(build_generalized(P, 1), vecs)
    np.testing.assert_allclose(res[3], np.sum(vecs, axis=0), rtol=1e-12)
    print("  simulator: allreduce(P=7, r=1) == sum  OK")

    # --- 3: autotune r --------------------------------------------------
    print("\n== optimal step count r (paper eq. 37) ==")
    for fabric in (PAPER_10GE, TPU_V5E_ICI):
        for m in [425.0, 65536.0, 16.0 * 2**20]:
            ra = optimal_r_analytic(127, m, fabric)
            rs = optimal_r_search(127, m, fabric)
            t = tau_intermediate(127, m, rs, fabric)
            print(f"  {fabric.name:12s} m={m:>10.0f}B  r*={rs} "
                  f"(analytic {ra})  t={t*1e6:8.1f}us  "
                  f"best-SOTA={tau_best_sota(127, m, fabric)*1e6:8.1f}us")

    # --- 4: the real executor -------------------------------------------
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as Psp
    from repro.compat import shard_map
    from repro.core.allreduce import allreduce_tree

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    grads = {"w": rng.standard_normal((n, 40, 3)).astype(np.float32),
             "b": rng.standard_normal((n, 5)).astype(np.float32)}

    def sync(tree):
        local = jax.tree.map(lambda v: v[0], tree)
        out = allreduce_tree(local, "data", mean=True)  # autotuned r
        return jax.tree.map(lambda v: v[None], out)

    f = jax.jit(shard_map(sync, mesh=mesh, in_specs=Psp("data"),
                          out_specs=Psp("data")))
    out = f(grads)
    np.testing.assert_allclose(np.asarray(out["w"])[0],
                               grads["w"].mean(0), rtol=1e-4)
    print(f"\n== JAX executor on {n} devices: gradient-mean pytree "
          f"allreduce OK ==")

    # --- 5: uneven (ragged) sizes --------------------------------------
    from repro.core import ragged_sizes, ragged_step_units
    from repro.core.allreduce import all_gather_flat, reduce_scatter_flat
    from repro.core.schedule import build_generalized as bg

    m = 3 * n + 5                               # does not divide n
    sizes = ragged_sizes(m, n)
    print(f"\n== ragged: m={m} over P={n} splits as {sizes} ==")
    s = bg(n, 0)
    tx, _ = ragged_step_units(s, n + 1)         # m = P + 1: worst ratio
    padded = [st.n_tx * (-(-(n + 1) // n)) for st in s.steps]
    print(f"  per-step tx elements at m={n + 1}: true {list(tx)} vs "
          f"zero-padded {padded} -- the cost model charges the left")
    x = rng.integers(-1000, 1000, (n, m)).astype(np.int32)

    def rs_ag(v):
        shard = reduce_scatter_flat(v[0], "data")    # exact ragged shard
        return all_gather_flat(shard, "data", sizes=sizes)[None]

    g = jax.jit(shard_map(rs_ag, mesh=mesh, in_specs=Psp("data", None),
                          out_specs=Psp("data", None)))
    np.testing.assert_array_equal(np.asarray(g(x))[0], x.sum(0))
    print("  reduce-scatter -> allgatherv round trip == sum, bit-exact OK")


if __name__ == "__main__":
    main()
