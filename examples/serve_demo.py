"""Batched serving demo: chunked prefill + decode with wave batching.

Serves a small decoder with the production serve_step (KV caches, greedy
or temperature sampling) over more requests than cache slots.

Run:
  PYTHONPATH=src python examples/serve_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax
    from repro.launch.mesh import make_mesh, parallel_config_for
    from repro.models.config import ModelConfig
    from repro.models.model import init_params
    from repro.serve.engine import Engine, Request

    cfg = ModelConfig(name="demo-lm", family="dense", n_layers=3,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                      vocab=512, head_dim=32, act="swiglu")
    mesh = make_mesh((2, 4), ("data", "model"))
    pc = parallel_config_for(mesh, param_mode="dp")
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))

    eng = Engine(cfg, pc, mesh, params, batch_slots=4, max_len=96,
                 prefill_chunk=16, temperature=0.7, seed=0)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24))
                    .astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 12)))
            for _ in range(10)]

    t0 = time.perf_counter()
    eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    for i, r in enumerate(reqs):
        print(f"req {i}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    print(f"\n{len(reqs)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s, dp=2 x tp=4 mesh, wave batching)")


if __name__ == "__main__":
    main()
