"""Elastic failover demo: lose a node mid-training, keep going.

The scenario the paper's algorithm makes cheap: training on dp=8; two
nodes "fail"; the run resizes to dp=6 -- a non-power-of-two count that
breaks Recursive Halving/Doubling but is a first-class citizen of the
generalized allreduce (Z_6 cyclic group, ceil(lg 6)=3-step reduce-scatter,
zero protocol overhead).  Parameters restore exactly; training continues.

Run:
  PYTHONPATH=src python examples/elastic_failover.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    import jax
    from repro.data.pipeline import DataConfig
    from repro.models.config import ModelConfig
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    from repro.train.optimizer import OptConfig

    cfg = ModelConfig(name="tiny-lm", family="dense", n_layers=2,
                      d_model=96, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=384, head_dim=24, act="swiglu")
    ckpt = "/tmp/repro_elastic_demo"
    shutil.rmtree(ckpt, ignore_errors=True)
    runner = ElasticRunner(
        cfg, OptConfig(lr=1e-3, warmup_steps=5, total_steps=100),
        ElasticConfig(ckpt_dir=ckpt, ckpt_every=10, param_mode="dp"),
        DataConfig(seq_len=64, global_batch=24),
        mesh_shape=(8, 1))

    print("phase 1: dp=8 (power of two)")
    logs = runner.run(20)
    print(f"  step {logs[-1]['step']}  loss {logs[-1]['loss']:.4f}")

    print("\n!! simulated failure of 2 nodes -> resize to dp=6 "
          "(non-power-of-two; Z_6 cyclic schedules)")
    devices = jax.devices()[:6]
    runner.resize((6, 1), devices=devices)

    print("phase 2: dp=6, training continues from the same parameters")
    logs2 = runner.run(20)
    print(f"  step {logs2[-1]['step']}  loss {logs2[-1]['loss']:.4f}")
    assert logs2[-1]["loss"] < logs[0]["loss"], "loss should keep improving"

    print("\nphase 3: crash-recovery -- restore the last committed "
          "checkpoint")
    runner.ckpt.wait()
    step = runner.restore_latest()
    print(f"  restored step {step}; continuing 5 more steps on dp=6")
    logs3 = runner.run(5)
    print(f"  step {logs3[-1]['step']}  loss {logs3[-1]['loss']:.4f}")
    print("\nelastic failover OK: 8 -> 6 devices with exact state carry")


if __name__ == "__main__":
    main()
