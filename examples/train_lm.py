"""End-to-end training driver.

Trains a decoder LM with the full production stack -- manual-SPMD model,
sequence-parallel TP, the paper's generalized allreduce / reduce-scatter
for gradient sync, AdamW (dp | zero1 | fsdp layouts), synthetic data
pipeline, async checkpointing, straggler watch.

Presets:
  tiny  -- ~1M params, runs a few hundred steps in minutes on 1 CPU core
  100m  -- ~100M-param danube-style model (the assignment's e2e driver);
           on real hardware: dp x tp mesh of your choice

Examples:
  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 40
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_lm.py --preset tiny \
      --mesh 4x2 --param-mode zero1 --steps 40
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def build_cfg(preset: str):
    from repro.models.config import ModelConfig
    if preset == "tiny":
        return ModelConfig(
            name="tiny-lm", family="dense", n_layers=4, d_model=128,
            n_heads=4, n_kv_heads=2, d_ff=352, vocab=512, head_dim=32,
            act="swiglu"), 128, 8
    if preset == "100m":
        # danube-family ~100M: 12L, d=768, GQA 12/4, swiglu
        return ModelConfig(
            name="danube-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=64,
            act="swiglu", window=1024), 512, 8
    raise SystemExit(f"unknown preset {preset}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="1x1", help="DPxTP, e.g. 4x2")
    ap.add_argument("--param-mode", default="dp",
                    choices=["dp", "zero1", "fsdp"])
    ap.add_argument("--grad-r", type=int, default=None,
                    help="override allreduce step count (default autotune)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    from repro.data.pipeline import DataConfig, DataLoader
    from repro.launch.mesh import make_mesh, parallel_config_for
    from repro.models.model import init_params
    from repro.runtime.elastic import ElasticConfig, ElasticRunner
    from repro.train.optimizer import OptConfig

    cfg, seq, batch = build_cfg(args.preset)
    dpn, tpn = (int(x) for x in args.mesh.split("x"))
    assert dpn * tpn <= len(jax.devices()), \
        f"mesh {args.mesh} needs {dpn*tpn} devices, have {len(jax.devices())}"

    oc = OptConfig(lr=args.lr, warmup_steps=min(50, args.steps // 5 + 1),
                   total_steps=args.steps)
    ec = ElasticConfig(ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 4, 10),
                       param_mode=args.param_mode)
    dc = DataConfig(seq_len=seq, global_batch=batch)

    runner = ElasticRunner(cfg, oc, ec, dc, (dpn, tpn))
    n_params = sum(x.size for x in jax.tree.leaves(runner.params))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh=dp{dpn}xtp{tpn} mode={args.param_mode}")

    t0 = time.perf_counter()
    logs = runner.run(args.steps)
    dt = time.perf_counter() - t0
    for rec in logs[::args.log_every] + logs[-1:]:
        print(f"  step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"{rec['dt']*1e3:7.1f} ms")
    toks = args.steps * batch * seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"final loss {logs[-1]['loss']:.4f} "
          f"(start {logs[0]['loss']:.4f})")
    if runner.alerts:
        print(f"straggler alerts: {runner.alerts}")
    runner.ckpt.wait()


if __name__ == "__main__":
    main()
