"""CI perf-regression gate for the executor smoke benchmark.

Compares a freshly measured ``results/executor.json``-shaped file against
the committed baseline and fails (exit 1) when the executor got slower
*relative to the in-process legacy baseline*.

Why ratios, not microseconds: the committed baseline was measured on the
development container and CI runs on whatever runner GitHub hands out, so
absolute wallclock is meaningless across the two.  Every benchmark row
times the legacy per-row replay, the ExecPlan executor and the pipelined
executor on the *same* host in the same interleaved run, so the
dimensionless ``speedup_execplan`` / ``speedup_pipelined`` ratios are
hardware-normalized and comparable.

Noise tolerance: the executor benchmark's interleaved best-of-reps
timings move about +-15% run to run on a loaded shared host (measured
while committing the PR 2 baseline); a ratio of two such numbers moves up
to ~30%.  The default ``--tolerance 0.35`` fails only drops beyond that
envelope.  Override per-run with ``--tolerance`` or the
``REPRO_REGRESSION_TOL`` env var.

Only labels (message sizes) present in BOTH files are compared -- the
committed baseline is a full run, CI measures the smoke subset -- and at
least one overlapping label is required, so a mis-wired gate fails loudly
instead of green.  The same rule protects every *class* of datapoint the
baseline carries: ragged rows (``"ragged": true``), non-sum-operator
rows (``"op"`` other than "sum", e.g. the ``@max`` monoid rows), and
all-to-all rows (``"collective": "a2a"``).  Once the committed baseline
has a class, at least one of its labels must overlap with the current
run -- a size- or family-list edit cannot silently drop the ragged
split, the monoid combines, or the schedule-driven all-to-all out of
the gate.

The same gate also guards the chaos benchmark (results/chaos.json):
its ``recovery_steps`` key -- steps of training work re-executed after
an injected failure -- is *lower*-is-better and deterministic, so the
gate checks a ceiling (``cur <= base * (1 + tol)``) instead of a floor;
the companion ``recovered`` key (1.0 when the run finished every step)
gates as a normal floor.

Usage (what CI runs):
    python benchmarks/run.py executor --smoke --out results/executor_smoke.json
    python benchmarks/check_regression.py \
        --current results/executor_smoke.json \
        --baseline results/executor.json \
        --summary regression_summary.md \
        --json regression.json
    python benchmarks/run.py chaos --smoke --out results/chaos_smoke.json
    python benchmarks/check_regression.py \
        --current results/chaos_smoke.json \
        --baseline results/chaos.json \
        --keys recovery_steps,recovered

``--json PATH`` additionally writes the full machine-readable verdict
(every comparison plus the tolerance and exit status) for downstream
tooling; ``--json -`` writes it to stdout instead of the CSV rows.

``--families`` switches the gate to *coverage* mode for the measured
tuning grid (results/tuning.json-shaped payloads): instead of comparing
numbers, it collects the set of schedule families (``Measurement.kind``
values: "generalized", "ring", "traff_rounds", "dual_root", ...) each
payload measured and fails MISWIRED (exit 2) when any family the
committed baseline measured is absent from the regenerated smoke table.
Same philosophy as the ROW_CLASSES guard above: once a family is in the
committed competition, an edit to the candidate grid cannot silently
drop it out of CI.  Timings are deliberately NOT compared -- the smoke
table is regenerated on whatever runner CI lands on.

    python benchmarks/run.py tune --smoke --out results/tuning_smoke.json
    python benchmarks/check_regression.py --families \
        --current results/tuning_smoke.json \
        --baseline results/tuning.json \
        --json family_gate.json

The serving benchmark (results/serving.json) gates the same way as the
executor: its keys are dimensionless ratios against a same-host solo
baseline measured in the same process (``tokens_per_s_ratio`` floor;
``p99_ttft_ratio`` / ``p99_latency_ratio`` ceilings, being latencies),
and its rows carry ``"bench": "serve"`` so the ROW_CLASSES guard fails
MISWIRED if a grid edit drops every serving label out of the overlap.

    python benchmarks/run.py serve --smoke --out results/serving_smoke.json
    python benchmarks/check_regression.py \
        --current results/serving_smoke.json \
        --baseline results/serving.json \
        --keys tokens_per_s_ratio,p99_ttft_ratio,p99_latency_ratio

The overlap benchmark (results/overlap.json) gates the backward-
overlapped gradient sync: ``speedup_overlap`` (post-backward serialized
step time over in-backward dispatched step time, floor) and
``exposed_ratio`` (exposed comm over total comm, a cost, so ceiling).
Both are same-host ratios of interleaved measurements, hence
hardware-normalized like every other gated key.  Overlap rows carry
``"bench": "overlap"`` so the ROW_CLASSES guard trips MISWIRED when a
config edit drops every overlap label out of the baseline overlap.

    python benchmarks/run.py executor --overlap --smoke \
        --out results/overlap_smoke.json
    python benchmarks/check_regression.py \
        --current results/overlap_smoke.json \
        --baseline results/overlap.json \
        --keys speedup_overlap,exposed_ratio
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# a2a rows gate on bruck-vs-direct (both our own executors, measured
# interleaved); the vs-XLA a2a ratios stay informational because XLA
# CPU's all_to_all wallclock is bimodal across processes on the
# baseline host
DEFAULT_KEYS = ("speedup_execplan", "speedup_pipelined", "speedup_bruck_vs_direct")

# most gated keys are speedups, where bigger is better and the gate is a
# floor; these are costs, where the gate is a *ceiling* (cur > base *
# (1 + tol) regresses).  recovery_steps = steps of work re-executed
# after a failure (chaos benchmark): deterministic, so any growth is a
# real behavior change, not noise.  The serving benchmark's TTFT and
# request-latency ratios (p99 vs the same-host solo baseline's mean
# request latency, see benchmarks/serve_worker.py) are latencies:
# climbing is the regression.
LOWER_IS_BETTER = frozenset(
    {
        "recovery_steps",
        "p50_ttft_ratio",
        "p99_ttft_ratio",
        "p50_latency_ratio",
        "p99_latency_ratio",
        # exposed comm / total comm of the backward-overlapped gradient
        # sync (overlap benchmark): a cost fraction -- climbing toward
        # 1.0 means the in-backward dispatch stopped hiding anything
        "exposed_ratio",
    }
)


def is_ragged(row: dict) -> bool:
    """Ragged datapoint: flagged by the worker (older files: none are)."""
    return bool(row.get("ragged"))


def is_nonsum_op(row: dict) -> bool:
    """Non-sum monoid datapoint (e.g. the ``@max`` rows)."""
    return row.get("op", "sum") not in ("sum", "a2a")


def is_a2a(row: dict) -> bool:
    """Schedule-driven all-to-all datapoint."""
    return row.get("collective") == "a2a" or row.get("op") == "a2a"


def is_serving(row: dict) -> bool:
    """Continuous-batching serving datapoint (results/serving.json)."""
    return row.get("bench") == "serve"


def is_overlap(row: dict) -> bool:
    """Backward-overlapped grad-sync datapoint (results/overlap.json)."""
    return row.get("bench") == "overlap"


ROW_CLASSES = (
    ("ragged", is_ragged, "the exact-split executor path"),
    ("non-sum-op", is_nonsum_op, "the monoid (non-sum combine) path"),
    ("a2a", is_a2a, "the schedule-driven all-to-all path"),
    ("serving", is_serving, "the continuous-batching serving path"),
    ("overlap", is_overlap, "the backward-overlapped grad-sync path"),
)


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {row["label"]: row for row in payload["results"]}


def load_families(path: str) -> set:
    """Schedule families a tuning payload measured, as a set of kinds.

    Reads a ``results/tuning.json``-shaped payload and unions the
    ``kind`` of every measurement in every size row (the winner's kind
    is always among them, so it needs no special casing).
    """
    with open(path) as f:
        payload = json.load(f)
    kinds = set()
    for row in payload["results"]:
        for m in row.get("measurements", ()):
            kinds.add(m["kind"])
    return kinds


def check_families(args) -> int:
    """Family-coverage gate: every baseline family must still be measured.

    Exit 2 (MISWIRED, same contract as the ROW_CLASSES guard) when a
    family the committed baseline measured is missing from the current
    run -- or when the baseline itself measures nothing, which means the
    gate is pointed at the wrong file.
    """
    current = sorted(load_families(args.current))
    baseline = sorted(load_families(args.baseline))
    missing = sorted(set(baseline) - set(current))
    if not baseline:
        verdict, code = "MISWIRED", 2
        print(
            f"check_regression: baseline {args.baseline} measures no "
            "schedule families -- family gate is mis-wired",
            file=sys.stderr,
        )
    elif missing:
        verdict, code = "MISWIRED", 2
        print(
            f"check_regression: schedule families {missing} are measured "
            f"by the committed baseline ({args.baseline}) but absent from "
            f"the current run ({args.current}) -- a candidate-grid edit "
            "dropped them out of the tuning competition",
            file=sys.stderr,
        )
    else:
        verdict, code = "OK", 0
        print(
            f"check_regression,families,baseline={'+'.join(baseline)},"
            f"current={'+'.join(current)},OK"
        )
    if args.json:
        payload = {
            "verdict": verdict,
            "exit_code": code,
            "mode": "families",
            "current": args.current,
            "baseline": args.baseline,
            "baseline_families": baseline,
            "current_families": current,
            "missing_families": missing,
        }
        if args.json == "-":
            print(json.dumps(payload, indent=2))
        else:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
            print(f"check_regression,WROTE,{args.json}")
    return code


def compare(current: dict, baseline: dict, keys, tolerance: float):
    """Returns (comparisons, regressions); each comparison is a dict.

    Direction-aware: keys in LOWER_IS_BETTER (costs, e.g. the chaos
    benchmark's recovery_steps) regress when the current value climbs
    ABOVE ``base * (1 + tol)``; everything else (speedup ratios)
    regresses when it drops below ``base * (1 - tol)``.
    """
    overlap = sorted(
        set(current) & set(baseline),
        key=lambda lb: (baseline[lb].get("bytes", 0), lb),
    )
    comparisons, regressions = [], []
    for label in overlap:
        for key in keys:
            base, cur = baseline[label].get(key), current[label].get(key)
            if base is None or cur is None:
                continue
            if key in LOWER_IS_BETTER:
                bound = base * (1.0 + tolerance)
                regressed = cur > bound
                direction = "<="
            else:
                bound = base * (1.0 - tolerance)
                regressed = cur < bound
                direction = ">="
            entry = {
                "label": label,
                "key": key,
                "baseline": base,
                "current": cur,
                # bound supersedes the old floor field; floor is kept
                # (floor semantics) for downstream --json consumers
                "bound": round(bound, 3),
                "direction": direction,
                "floor": round(base * (1.0 - tolerance), 3),
                "regressed": regressed,
            }
            comparisons.append(entry)
            if entry["regressed"]:
                regressions.append(entry)
    return comparisons, regressions


def write_summary(
    path: str,
    comparisons,
    regressions,
    tolerance: float,
    current_path: str,
    baseline_path: str,
) -> None:
    lines = [
        "# Executor benchmark regression check",
        "",
        f"- current: `{current_path}`",
        f"- baseline: `{baseline_path}`",
        f"- tolerance: {tolerance:.0%} relative drop "
        "(documented benchmark noise envelope)",
        f"- verdict: {'REGRESSION' if regressions else 'OK'}",
        "",
        "| size | metric | baseline | current | bound | status |",
        "| --- | --- | --- | --- | --- | --- |",
    ]
    for c in comparisons:
        status = "**REGRESSED**" if c["regressed"] else "ok"
        lines.append(
            f"| {c['label']} | {c['key']} | {c['baseline']:.3f} "
            f"| {c['current']:.3f} | {c['direction']} {c['bound']:.3f} "
            f"| {status} |"
        )
    lines.append("")
    lines.append(
        "Ratios are executor-vs-legacy speedups measured interleaved on one "
        "host, so they stay comparable between the committed baseline "
        "machine and the CI runner; absolute microseconds are not."
    )
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when executor speedup ratios regress vs baseline"
    )
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument(
        "--summary", default=None, help="write a human-readable markdown diff here"
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("REPRO_REGRESSION_TOL", "0.35")),
        help="allowed relative drop before failing (default 0.35)",
    )
    ap.add_argument(
        "--keys",
        default=",".join(DEFAULT_KEYS),
        help="comma-separated dimensionless row keys to gate on",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write the machine-readable verdict here ('-' for stdout)",
    )
    ap.add_argument(
        "--families",
        action="store_true",
        help="gate schedule-family coverage of a tuning payload instead "
        "of numeric ratios (exit 2 when a baseline family disappears)",
    )
    args = ap.parse_args(argv)

    if args.families:
        return check_families(args)

    current, baseline = load_rows(args.current), load_rows(args.baseline)
    keys = [k for k in args.keys.split(",") if k]
    comparisons, regressions = compare(current, baseline, keys, args.tolerance)

    def emit_json(verdict: str, exit_code: int) -> None:
        """Machine-readable verdict (--json PATH, or '-' for stdout)."""
        if not args.json:
            return
        payload = {
            "verdict": verdict,
            "exit_code": exit_code,
            "tolerance": args.tolerance,
            "keys": keys,
            "current": args.current,
            "baseline": args.baseline,
            "n_comparisons": len(comparisons),
            "n_regressions": len(regressions),
            "comparisons": comparisons,
        }
        if args.json == "-":
            print(json.dumps(payload, indent=2))
        else:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
            print(f"check_regression,WROTE,{args.json}")

    if not comparisons:
        print(
            f"check_regression: no overlapping labels between "
            f"{args.current} ({sorted(current)}) and {args.baseline} "
            f"({sorted(baseline)}) -- gate is mis-wired",
            file=sys.stderr,
        )
        emit_json("MISWIRED", 2)
        return 2
    # the baseline is the source of truth for what must stay gated: for
    # every row class it carries (ragged sizes, non-sum monoids,
    # all-to-all), a current run with no overlapping label of that class
    # (e.g. the size or family silently dropped from the worker's lists)
    # must fail, not pass
    for cls_name, pred, what in ROW_CLASSES:
        if any(pred(r) for r in baseline.values()) and not any(
            pred(baseline[c["label"]]) for c in comparisons
        ):
            print(
                f"check_regression: the baseline carries {cls_name} "
                f"datapoints but no {cls_name} label overlaps with the "
                f"current run -- {what} dropped out of the gate",
                file=sys.stderr,
            )
            emit_json("MISWIRED", 2)
            return 2
    if args.json != "-":
        for c in comparisons:
            status = "REGRESSED" if c["regressed"] else "ok"
            print(
                f"check_regression,{c['label']},{c['key']},"
                f"base={c['baseline']:.3f},cur={c['current']:.3f},"
                f"bound={c['direction']}{c['bound']:.3f},{status}"
            )
    if args.summary:
        write_summary(
            args.summary,
            comparisons,
            regressions,
            args.tolerance,
            args.current,
            args.baseline,
        )
        print(f"check_regression,WROTE,{args.summary}")
    if regressions:
        print(
            f"check_regression: {len(regressions)} metric(s) regressed "
            f"beyond the {args.tolerance:.0%} noise tolerance",
            file=sys.stderr,
        )
        emit_json("REGRESSION", 1)
        return 1
    emit_json("OK", 0)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
