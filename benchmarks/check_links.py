"""CI markdown link checker for README.md and docs/.

Offline by design (CI must not flake on the network): relative links are
resolved against the containing file and must exist on disk, intra-file
and cross-file ``#anchors`` must match a real heading (GitHub slug
rules: lowercase, punctuation stripped, spaces to dashes), and
``http(s)://`` / ``mailto:`` targets are only syntax-checked.  Exits 1
listing every broken link.

Usage (what the CI lint job runs):
    python benchmarks/check_links.py README.md docs/*.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) -- skipping images is unnecessary: their paths must
# exist too.  Inline code spans are stripped first so `[i](x)` examples
# in code do not count.
LINK_RE = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
CODE_SPAN_RE = re.compile(r"`[^`]*`")
FENCE_RE = re.compile(r"^(```|~~~)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markup/punctuation, spaces to dashes."""
    text = CODE_SPAN_RE.sub(lambda m: m.group(0)[1:-1], heading)
    text = re.sub(r"[*_~]", "", text).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def markdown_lines(path: Path):
    """Lines outside fenced code blocks."""
    fenced = False
    for line in path.read_text().splitlines():
        if FENCE_RE.match(line.strip()):
            fenced = not fenced
            continue
        if not fenced:
            yield line


def anchors_of(path: Path) -> set:
    out = set()
    for line in markdown_lines(path):
        m = HEADING_RE.match(line)
        if m:
            out.add(github_slug(m.group(1)))
    return out


def check_file(path: Path) -> list:
    errors = []
    for line in markdown_lines(path):
        for m in LINK_RE.finditer(CODE_SPAN_RE.sub("", line)):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, anchor = target.partition("#")
            dest = (path.parent / base).resolve() if base else path
            if base and not dest.is_relative_to(Path.cwd().resolve()):
                # escapes the checkout (e.g. ../../actions badge URLs
                # resolved by the GitHub web UI) -- not checkable offline
                continue
            if base and not dest.exists():
                errors.append(f"{path}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if github_slug(anchor) not in anchors_of(dest):
                    errors.append(f"{path}: missing anchor -> {target}")
    return errors


def main(argv=None) -> int:
    paths = [Path(p) for p in (argv if argv is not None else sys.argv[1:])]
    if not paths:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors = []
    for p in paths:
        if not p.exists():
            errors.append(f"{p}: file not found")
            continue
        errors.extend(check_file(p))
    for e in errors:
        print(f"check_links,BROKEN,{e}", file=sys.stderr)
    print(f"check_links,{len(paths)} files,"
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
