"""Overlap benchmark: exposed vs hidden gradient-sync communication.

Runs on 8 forced host devices (launched by ``benchmarks/run.py executor
--overlap``).  Three *complete train steps* over the same comm-heavy
model differ only in ``ParallelConfig.overlap_dispatch``:

* ``skip``     -- no DP gradient sync at all: the pure compute baseline
  ``t_compute`` (forward + backward + optimizer, zero grad comm);
* ``post``     -- the reverse-layer buckets synced after the backward
  completes: every byte of gradient communication is serialized behind
  the compute, so ``t_post - t_compute`` measures the total comm cost;
* ``backward`` -- the same buckets dispatched from inside the backward
  pass via the ``custom_vjp`` markers (``attach_overlap_sync``):
  ``t_overlap - t_compute`` is the *exposed* comm -- what the dispatch
  interleaving failed to hide behind backward compute.

The three arms run identical collectives on identical buckets (post and
backward are bit-identical by construction, see
``tests/_multidevice_worker.py overlap``), so the derived ratios isolate
dispatch timing:

* ``speedup_overlap = t_post / t_overlap`` -- step-time win of moving
  the dispatches into the backward (gated as a floor);
* ``exposed_ratio = (t_overlap - t_compute) / (t_post - t_compute)``
  -- the fraction of comm left exposed (gated lower-is-better: 1.0
  means nothing hid, 0.0 means everything did).

XLA CPU executes collectives synchronously on the compute stream, so
the hidden fraction here comes from instruction-level interleaving, not
true async comm -- the ratios are still dispatch-structure-sensitive
(a regression that re-serializes every bucket behind the backward moves
both), which is what the gate guards.  The model-error overlay
(``overlap_fit_*``) prices the same buckets with the roofline
``exposed = max(0, comm - hidden_budget)`` of
``repro.core.cost_model.overlap_tick_costs`` under the HOST_CPU fabric,
mirroring the executor bench's informational fit ratio.

Prints ``overlap,<label>,<arm>,<us_per_step>`` rows and writes the JSON
summary (``results/overlap.json``) to ``--out``.
"""
import argparse
import json
import os
import sys
import time

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core.autotune import choose  # noqa: E402
from repro.core.cost_model import HOST_CPU  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import init_params, param_shapes  # noqa: E402
from repro.obs.log import data, get_logger  # noqa: E402
from repro.parallel.api import ParallelConfig  # noqa: E402
from repro.train.optimizer import OptConfig, init_opt_state  # noqa: E402
from repro.train.step import make_train_step, overlap_buckets_for  # noqa: E402

log = get_logger("benchmarks.overlap")

# comm-heavy, compute-light: wide embeddings + narrow blocks keep the
# gradient bytes large relative to the FLOPs of a short batch, so the
# exposed/hidden split is measured where it matters
CONFIGS = {
    "tiny": ModelConfig(name="ovl-tiny", family="dense", n_layers=2,
                        d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                        vocab=1024, head_dim=32, act="swiglu"),
    "base": ModelConfig(name="ovl-base", family="dense", n_layers=4,
                        d_model=256, n_heads=8, n_kv_heads=8, d_ff=512,
                        vocab=2048, head_dim=32, act="swiglu"),
}
BUCKET_BYTES = 1 << 20          # ~1 MiB reverse-layer buckets
BATCH, SEQ = 8, 16


def make_arm(cfg, mesh, dispatch):
    """One jitted train step + its state, differing only in dispatch."""
    pc = ParallelConfig(dp=8, tp=1, param_mode="dp",
                        overlap_bucket_bytes=BUCKET_BYTES,
                        overlap_dispatch=dispatch)
    oc = OptConfig(lr=1e-3)
    bundle = make_train_step(cfg, pc, mesh, oc, donate=False)
    params, _ = init_params(cfg, pc, jax.random.PRNGKey(0))
    opt = init_opt_state(params, pc=pc, specs=bundle.specs)
    tok = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ),
                             0, cfg.vocab)
    lab = jax.random.randint(jax.random.PRNGKey(2), (BATCH, SEQ),
                             0, cfg.vocab)
    batch = {"tokens": tok, "labels": lab}

    def step():
        return bundle.train_step(params, opt, batch)

    return step, pc, bundle


def bench_interleaved(arms, samples):
    """Time the arms round-robin, one fenced step per sample, and keep
    each arm's per-step MINIMUM.  The min is the noise-floor estimator:
    XLA CPU's collective rendezvous occasionally stalls for seconds (a
    logged false-positive "thread stuck" watchdog), and a single stall
    would poison any mean- or best-of-window figure, while the min only
    needs one clean sample per arm.  Round-robin keeps machine-load
    drift symmetric across dispatch modes."""
    for step in arms.values():              # compile + rendezvous warm-up
        jax.block_until_ready(step())
        jax.block_until_ready(step())
    best = {name: float("inf") for name in arms}
    for _ in range(samples):
        for name, step in arms.items():
            t0 = time.perf_counter()
            jax.block_until_ready(step())
            best[name] = min(best[name],
                             (time.perf_counter() - t0) * 1e6)
    return best


def bucket_model_costs_us(cfg, pc):
    """Per-bucket modeled comm cost (HOST_CPU) of the bench's buckets."""
    shapes, _ = param_shapes(cfg, pc)
    buckets = overlap_buckets_for(shapes, pc)
    leaves = jax.tree.leaves(shapes)
    costs = []
    for bucket in buckets:
        nbytes = sum(int(leaves[i].size) * jnp.dtype(leaves[i].dtype).itemsize
                     for i in bucket)
        ch = choose(pc.dp, nbytes, HOST_CPU, tune=False, itemsize=4)
        costs.append(ch.cost * 1e6)
    return costs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    n = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(n, 1), ("data", "model"))
    labels = ["tiny"] if args.smoke else ["tiny", "base"]
    samples = 6 if args.smoke else 12

    results = []
    for label in labels:
        cfg = CONFIGS[label]
        arms, pcs = {}, {}
        for dispatch in ("skip", "post", "backward"):
            step, pc, _ = make_arm(cfg, mesh, dispatch)
            arms[dispatch] = step
            pcs[dispatch] = pc
        timed = bench_interleaved(arms, samples)
        row = {"label": label, "bench": "overlap",
               "bucket_bytes": BUCKET_BYTES, "P": n}
        for name, us in timed.items():
            row[f"{name}_us"] = round(us, 1)
            data(f"overlap,{label},{name},{us:.1f}")
        eps = 1.0  # us; floors the denominators against timer jitter
        t_compute = timed["skip"]
        comm_us = max(timed["post"] - t_compute, eps)
        exposed_us = max(timed["backward"] - t_compute, 0.0)
        hidden_us = max(timed["post"] - timed["backward"], 0.0)
        row["speedup_overlap"] = round(timed["post"] / timed["backward"], 3)
        row["exposed_ratio"] = round(max(exposed_us, eps) / comm_us, 3)
        row["hidden_us"] = round(hidden_us, 1)
        row["exposed_us"] = round(exposed_us, 1)
        row["comm_us"] = round(comm_us, 1)
        # model-error overlay (informational, like the executor bench's
        # fit ratio): the roofline prices the same buckets under
        # HOST_CPU -- comm fit compares total modeled comm against the
        # serialized measurement, exposed fit applies the measured
        # hidden budget to the modeled comm
        # (exposed_model = max(0, comm_model - hidden))
        pc = pcs["backward"]
        bucket_costs = bucket_model_costs_us(cfg, pc)
        model_comm_us = sum(bucket_costs)
        model_exposed_us = max(model_comm_us - hidden_us, 0.0)
        row["n_buckets"] = len(bucket_costs)
        row["model_comm_us"] = round(model_comm_us, 1)
        row["model_exposed_us"] = round(model_exposed_us, 1)
        row["overlap_fit_comm"] = round(comm_us / model_comm_us, 3)
        # meaningless when the model predicts full hiding (exposed 0)
        row["overlap_fit_exposed"] = (
            None if model_exposed_us <= 0.0
            else round(max(exposed_us, eps) / model_exposed_us, 3))
        data(f"overlap,{label},exposed_ratio,{row['exposed_ratio']:.3f}")
        data(f"overlap,{label},speedup_overlap,"
             f"{row['speedup_overlap']:.3f}")
        results.append(row)
        log.info("overlap_row", label=label,
                 speedup=row["speedup_overlap"],
                 exposed_ratio=row["exposed_ratio"],
                 fit_comm=row["overlap_fit_comm"])

    # executor-bench-style informational overlay: geomean fabric
    # miscalibration of the comm model against the serialized (post -
    # skip) measurement; large on CPU because the step-level dispatch
    # overheads (shard_map entry, per-bucket jit regions) are not part
    # of the per-collective alpha-beta-gamma fabric
    fits = [r["overlap_fit_comm"] for r in results
            if r["overlap_fit_comm"] > 0]
    geo = (float(np.exp(np.mean(np.log(fits)))) if fits else None)
    payload = {
        "P": n, "platform": jax.default_backend(),
        "mode": "smoke" if args.smoke else "full",
        "autotune_fabric": HOST_CPU.name,
        "model_error_geomean_ratio":
            None if geo is None else round(geo, 3),
        "notes": ("Three full train steps differ only in "
                  "overlap_dispatch: skip (compute baseline), post "
                  "(bucketed sync after backward), backward (custom_vjp "
                  "markers dispatch each bucket inside the backward). "
                  "XLA CPU runs collectives synchronously, so hidden "
                  "time comes from instruction interleaving rather than "
                  "async comm; the gated ratios (speedup_overlap floor, "
                  "exposed_ratio ceiling) are dispatch-structure "
                  "sensitive either way.  overlap_fit_* are the "
                  "informational roofline-model overlays; their "
                  "geomean sits well below the ~103x fabric "
                  "miscalibration the executor trace bench commits "
                  "for host-cpu (results/model_error_smoke.md), i.e. "
                  "within the existing fit tolerance."),
        "results": results,
    }
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    data(f"overlap,WROTE,{args.out}")


if __name__ == "__main__":
    main()
