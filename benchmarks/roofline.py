"""Roofline table builder (EXPERIMENTS.md section Roofline).

Per (arch x shape x mesh) cell:
  compute_s    = FLOPs / (chip peak 197 TF bf16)
  memory_s     = HBM bytes / 819 GB/s
  collective_s = link-crossing bytes / 50 GB/s
all per-device (the mesh divides the global work), from the analytic model
(:mod:`benchmarks.analytic`), cross-checked against the dry-run's
``cost_analysis`` / HLO-parsed collectives (which count scan bodies once --
the JSON carries both raw numbers and the scan trip count).

Reports per cell: the three terms, the dominant one, MODEL_FLOPS = 6*N*D
(dense) or 6*N_active*D (MoE), the useful-compute ratio, and a one-line
"what would move the bottleneck".
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from analytic import PEAK_FLOPS, serve_cell, train_cell  # noqa: E402
from repro.configs import ARCHS, get_config  # noqa: E402
from repro.models.config import SHAPES, shape_applicable  # noqa: E402

MESHES = {"16x16": dict(dp=16, tp=16, pods=1),
          "2x16x16": dict(dp=32, tp=16, pods=2)}


def cell_row(arch: str, shape_name: str, mesh: str,
             dryrun_dir: str = "results/dryrun"):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh,
                "status": "skipped", "why": why}
    m = MESHES[mesh]
    dp, tp = m["dp"], m["tp"]
    if shape.kind == "train" or not cfg.is_decoder:
        cm = train_cell(cfg, shape, dp=dp, tp=tp)
        step_kind = "train"
    else:
        eff_dp = dp if shape.global_batch % dp == 0 else 1
        cm = serve_cell(cfg, shape, dp=eff_dp, tp=tp)
        step_kind = "serve"
    t = cm.terms()
    bound = cm.dominant
    useful = cm.model_flops / max(cm.flops, 1.0)
    roofline_frac = (cm.model_flops / PEAK_FLOPS) / max(
        t["compute_s"], t["memory_s"], t["collective_s"])

    row = {"arch": arch, "shape": shape_name, "mesh": mesh,
           "status": "ok", "kind": step_kind,
           "compute_s": t["compute_s"], "memory_s": t["memory_s"],
           "collective_s": t["collective_s"], "bound": bound,
           "model_flops": cm.model_flops, "hlo_flops_analytic": cm.flops,
           "useful_ratio": useful, "roofline_frac": roofline_frac}

    # cross-check against the dry-run record if present
    fn = os.path.join(dryrun_dir, f"{arch}__{shape_name}__{mesh}.json")
    if os.path.exists(fn):
        with open(fn) as f:
            rec = json.load(f)
        row["dryrun_status"] = rec.get("status")
        cost = rec.get("cost", {})
        row["hlo_flops_trace"] = cost.get("flops")
        mem = rec.get("memory", {})
        row["temp_gb_cpu"] = mem.get("temp_size_gb")
        row["args_gb"] = mem.get("argument_size_gb")
        colls = rec.get("collectives", {}).get("summary", [])
        row["coll_ops_trace"] = sum(c["count"] for c in colls)
    return row


def advice(row) -> str:
    if row.get("status") != "ok":
        return row.get("why", "")
    b = row["bound"]
    if b == "collective_s":
        return ("overlap TP boundary collectives with compute; or larger "
                "per-device batch to amortize (B,S,d) gathers")
    if b == "memory_s":
        return ("raise arithmetic intensity: fuse elementwise chains "
                "(Pallas), larger microbatch, or fewer remat re-reads")
    return "near compute roof: kernel-level MXU utilization is the lever"


def build_table(dryrun_dir: str = "results/dryrun"):
    rows = []
    for arch in ARCHS:
        for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            for mesh in ["16x16", "2x16x16"]:
                rows.append(cell_row(arch, shape, mesh, dryrun_dir))
    return rows


def main():
    rows = build_table()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'bound':13s} "
           f"{'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} "
           f"{'roofl%':>7s} {'useful%':>8s}")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            if r["mesh"] == "16x16":
                print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                      f"SKIP: {r['why']}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['bound']:13s} {r['compute_s']*1e3:8.2f} "
              f"{r['memory_s']*1e3:8.2f} {r['collective_s']*1e3:8.2f} "
              f"{r['roofline_frac']*100:6.1f}% "
              f"{r['useful_ratio']*100:7.1f}%")


if __name__ == "__main__":
    main()
