"""Benchmark harness: one function per paper figure + microbenchmarks.

Prints ``name,us_per_call,derived`` CSV rows (cost-model times are derived
quantities; wall-clock rows come from the 8-virtual-device microbench).

Figures reproduced from the paper (all cost-model driven, validated by the
schedule compiler's exact per-step accounting):
  fig1   -- ratio tau_proposed / tau_best_sota over (P, m)
  fig7   -- small messages,  P=127: proposed vs RD / RH / OpenMPI policy
  fig8   -- large messages,  P=127
  fig9   -- medium messages, P=127: proposed vs RH
  fig10  -- proposed r-sweep at P=127 (bw-opt .. lat-opt envelope)
  fig11  -- vs P at m=425 B (the profiling study's average message)
  fig12  -- vs P at m=9 KB
plus:
  sched  -- compiled-schedule step/traffic counts vs closed forms
  wall   -- real wall-clock of the JAX executor on 8 host devices

Modes (first positional arg): ``figures`` (default), ``executor
[--smoke] [--trace] [--out PATH] [--op sum|max|a2a ...]`` (executor
wallclock comparison incl. max-monoid and all-to-all rows ->
results/executor.json; ``--trace`` additionally runs the instrumented
per-tick replay and writes a Chrome trace + metrics snapshot +
predicted-vs-measured model-error report, see docs/observability.md;
``--overlap`` reroutes to the backward-overlap benchmark: full train
steps differing only in gradient-sync dispatch -> results/overlap.json
with gated ``speedup_overlap`` / ``exposed_ratio`` rows, see
docs/architecture.md "Overlap"),
``tune [--smoke] [--out PATH] [--cache PATH]`` (measured autotuning
grid, sum + max operators -> persistent tuning cache +
results/tuning.json), ``chaos [--smoke] [--trace] [--out PATH]``
(deterministic fault scenarios on the multi-process runtime mesh ->
results/chaos.json; exact recovery_steps rows, gated lower-is-better),
``serve [--smoke] [--trace] [--out PATH]`` (continuous-batching serving
on a dp=2 x tp=2 mesh of 8 simulated CPU devices -> results/serving.json;
p50/p99 TTFT/latency + tokens/sec per offered QPS, gated as
dimensionless ratios vs a same-host solo baseline, see docs/serving.md).

Protocol CSV rows go to stdout via ``repro.obs.log.data``; diagnostics
go to stderr as logfmt lines filtered by ``REPRO_LOG``.
"""
from __future__ import annotations

import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.cost_model import (PAPER_10GE, optimal_r_search,  # noqa: E402
                                   schedule_cost, tau_best_sota,
                                   tau_intermediate, tau_openmpi_policy,
                                   tau_recursive_doubling,
                                   tau_recursive_halving, tau_ring)
from repro.core.schedule import (build_generalized, build_ring,  # noqa: E402
                                 max_r, n_steps_log, schedule_summary)
from repro.obs.log import data, get_logger  # noqa: E402

F = PAPER_10GE

log = get_logger("benchmarks.run")


def _row(name, us, derived=1):
    data(f"{name},{us:.3f},{derived}")


def fig1_ratio_heatmap():
    """Expected tau_proposed / tau_best over P and m (paper Fig. 1)."""
    for P in [15, 31, 63, 127, 255, 511, 1000]:
        for m in [64, 425, 4096, 65536, 1 << 20, 1 << 24]:
            r = optimal_r_search(P, float(m), F)
            ratio = tau_intermediate(P, float(m), r, F) / \
                tau_best_sota(P, float(m), F)
            _row(f"fig1,P={P},m={m},ratio={ratio:.3f}",
                 tau_intermediate(P, float(m), r, F) * 1e6)


def fig7_small_msgs():
    P = 127
    for m in [16, 64, 256, 425, 1024, 4096, 10240]:
        m = float(m)
        r = optimal_r_search(P, m, F)
        _row(f"fig7,m={m:.0f},proposed(r={r})",
             tau_intermediate(P, m, r, F) * 1e6)
        _row(f"fig7,m={m:.0f},openmpi", tau_openmpi_policy(P, m, F) * 1e6)
        _row(f"fig7,m={m:.0f},recursive_halving",
             tau_recursive_halving(P, m, F) * 1e6)


def fig8_large_msgs():
    P = 127
    for m in [1 << 18, 1 << 20, 1 << 22, 1 << 24, 1 << 26]:
        m = float(m)
        r = optimal_r_search(P, m, F)
        _row(f"fig8,m={m:.0f},proposed(r={r})",
             tau_intermediate(P, m, r, F) * 1e6)
        _row(f"fig8,m={m:.0f},ring", tau_ring(P, m, F) * 1e6)
        _row(f"fig8,m={m:.0f},recursive_halving",
             tau_recursive_halving(P, m, F) * 1e6)


def fig9_medium_msgs():
    P = 127
    for m in [16384, 32768, 65536, 131072]:
        m = float(m)
        r = optimal_r_search(P, m, F)
        _row(f"fig9,m={m:.0f},proposed(r={r})",
             tau_intermediate(P, m, r, F) * 1e6)
        _row(f"fig9,m={m:.0f},recursive_halving",
             tau_recursive_halving(P, m, F) * 1e6)


def fig10_r_sweep():
    P = 127
    for m in [425.0, 8192.0, 131072.0]:
        for r in range(n_steps_log(P) + 1):
            _row(f"fig10,m={m:.0f},r={r}",
                 tau_intermediate(P, m, r, F) * 1e6)


def fig11_vs_P_small():
    m = 425.0
    for P in [8, 16, 17, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129, 200]:
        r = optimal_r_search(P, m, F)
        _row(f"fig11,P={P},proposed(r={r})",
             tau_intermediate(P, m, r, F) * 1e6)
        _row(f"fig11,P={P},recursive_doubling",
             tau_recursive_doubling(P, m, F) * 1e6)


def fig12_vs_P_9kb():
    m = 9.0 * 1024
    for P in [16, 32, 33, 64, 100, 127, 128, 200, 256, 300]:
        r = optimal_r_search(P, m, F)
        _row(f"fig12,P={P},proposed(r={r})",
             tau_intermediate(P, m, r, F) * 1e6)
        _row(f"fig12,P={P},ring", tau_ring(P, m, F) * 1e6)
        _row(f"fig12,P={P},recursive_halving",
             tau_recursive_halving(P, m, F) * 1e6)


def sched_table():
    """Exact compiled-schedule accounting vs the paper's closed forms."""
    for P in [7, 8, 12, 127]:
        for r in range(max_r(P) + 1):
            s = schedule_summary(build_generalized(P, r))
            _row(f"sched,P={P},r={r},steps={s['steps']},"
                 f"sent={s['units_sent']},reduced={s['units_reduced']}",
                 schedule_cost(build_generalized(P, r), 425.0, F) * 1e6)
        rg = schedule_summary(build_ring(P))
        _row(f"sched,P={P},ring,steps={rg['steps']},sent={rg['units_sent']}",
             schedule_cost(build_ring(P), 425.0, F) * 1e6)
        from repro.core.schedule import build_bruck_all_gather
        bk = schedule_summary(build_bruck_all_gather(P))
        _row(f"sched,P={P},bruck_allgather,steps={bk['steps']},"
             f"sent={bk['units_sent']}",
             schedule_cost(build_bruck_all_gather(P), 425.0, F) * 1e6)


def _spawn_8dev(script: str, extra_args=(), timeout=1800):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run([sys.executable, script, *extra_args], env=env,
                          capture_output=True, text=True, timeout=timeout)


def wallclock_8dev():
    """Real wall-clock of the JAX ppermute executor on 8 host devices."""
    script = os.path.join(os.path.dirname(__file__), "wallclock_worker.py")
    res = _spawn_8dev(script, timeout=900)
    if res.returncode != 0:
        log.error("worker_failed", worker="wallclock",
                  stderr=res.stderr[-200:])
        return
    for line in res.stdout.strip().splitlines():
        if line.startswith("wall,"):
            data(line)


def _worker_bench(script_name: str, prefix: str, extra, timeout=1800) -> None:
    """Spawn an 8-host-device benchmark worker, echo its ``prefix,``
    rows, and fail loudly on a non-zero exit."""
    script = os.path.join(os.path.dirname(__file__), script_name)
    res = _spawn_8dev(script, extra, timeout=timeout)
    if res.returncode != 0:
        log.error("worker_failed", worker=script_name,
                  stderr=res.stderr[-2000:])
        raise SystemExit(1)
    # echo the worker's protocol rows; forward its (REPRO_LOG-filtered)
    # stderr diagnostics untouched
    if res.stderr:
        sys.stderr.write(res.stderr)
    for line in res.stdout.strip().splitlines():
        if line.startswith(prefix + ","):
            data(line)


def executor_bench(smoke: bool = False,
                   out: str = "results/executor.json",
                   ops=(), trace: bool = False) -> None:
    """Old per-row replay vs ExecPlan vs pipelined ExecPlan wallclock on
    8 simulated CPU devices (the perf trajectory's BENCH datapoint);
    writes ``results/executor.json``.  ``--op {sum,max,a2a}``
    (repeatable) restricts the benchmark families: ``max`` rows run the
    executors under the max monoid, ``a2a`` rows time the
    schedule-driven all-to-all against ``lax.all_to_all``.  ``--trace``
    additionally runs the instrumented per-tick replay over the bench
    grid and writes ``trace_executor_*.json`` /
    ``metrics_executor_*.json`` / ``model_error_*.md`` next to
    ``--out``."""
    extra = ["--out", out] + (["--smoke"] if smoke else [])
    for op in ops:
        extra += ["--op", op]
    if trace:
        extra += ["--trace"]
    _worker_bench("executor_worker.py", "executor", extra)


def overlap_bench(smoke: bool = False,
                  out: str = "results/overlap.json") -> None:
    """Backward-overlapped gradient sync benchmark on 8 simulated CPU
    devices: three full train steps differing only in
    ``ParallelConfig.overlap_dispatch`` (skip = compute baseline, post =
    serialized bucketed sync, backward = custom_vjp in-backward
    dispatch), reduced to the gated ``speedup_overlap`` (floor) and
    ``exposed_ratio`` (lower-is-better) rows plus the informational
    roofline model overlay; writes ``results/overlap.json``."""
    extra = ["--out", out] + (["--smoke"] if smoke else [])
    _worker_bench("overlap_worker.py", "overlap", extra, timeout=3600)


def tune_bench(smoke: bool = False, out: str = "results/tuning.json",
               cache: str = None) -> None:
    """Measured autotuning: time the (kind x r x n_buckets x size) grid on
    8 simulated CPU devices, record it into the persistent tuning cache
    (``REPRO_TUNING_CACHE`` / the user cache dir), and write a summary to
    ``results/tuning.json``.  After this, ``choose(..., tune=True)`` (or
    ``REPRO_TUNING=1``) answers from measurements instead of the model."""
    extra = ["--out", out] + (["--smoke"] if smoke else [])
    if cache:
        extra += ["--cache", cache]
    _worker_bench("tune_worker.py", "tune", extra, timeout=3600)


def serve_bench(smoke: bool = False, out: str = "results/serving.json",
                trace: bool = False) -> None:
    """Continuous-batching serving benchmark on a dp=2 x tp=2 mesh of 8
    simulated CPU devices: one deterministic request mix served at each
    offered QPS level, reporting p50/p99 TTFT/latency and tokens/sec
    plus the dimensionless ratios vs a same-host solo (one-request-at-a-
    time) baseline that check_regression.py gates
    (``tokens_per_s_ratio`` floor, ``p99_ttft_ratio`` /
    ``p99_latency_ratio`` ceilings).  Writes ``results/serving.json``;
    ``--trace`` saves the engine.tick Chrome trace + metrics snapshot
    next to it."""
    extra = ["--out", out] + (["--smoke"] if smoke else []) \
        + (["--trace"] if trace else [])
    _worker_bench("serve_worker.py", "serve", extra)


def chaos_bench(smoke: bool = False, out: str = "results/chaos.json",
                trace: bool = False) -> None:
    """Deterministic fault scenarios on the real coordinator/worker
    process mesh (kill -> recover at P-1, torn checkpoint fallback,
    delay -> skew telemetry); writes ``results/chaos.json`` whose exact
    ``recovery_steps`` rows are gated by check_regression.py
    (lower-is-better).  No forced host devices needed: the runtime mesh
    is OS processes over TCP."""
    script = os.path.join(os.path.dirname(__file__), "chaos_worker.py")
    extra = ["--out", out] + (["--smoke"] if smoke else []) \
        + (["--trace"] if trace else [])
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run([sys.executable, script, *extra], env=env,
                         capture_output=True, text=True, timeout=1800)
    if res.returncode != 0:
        log.error("worker_failed", worker="chaos_worker.py",
                  stderr=res.stderr[-2000:])
        raise SystemExit(1)
    if res.stderr:
        sys.stderr.write(res.stderr)
    for line in res.stdout.strip().splitlines():
        if line.startswith("chaos,"):
            data(line)


def figures() -> None:
    data("name,us_per_call,derived")
    fig1_ratio_heatmap()
    fig7_small_msgs()
    fig8_large_msgs()
    fig9_medium_msgs()
    fig10_r_sweep()
    fig11_vs_P_small()
    fig12_vs_P_9kb()
    sched_table()
    if os.environ.get("SKIP_WALLCLOCK") != "1":
        wallclock_8dev()


def _opt(argv, flag, default):
    return argv[argv.index(flag) + 1] if flag in argv else default


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    mode = next((a for a in argv if not a.startswith("-")), "figures")
    if mode == "figures":
        figures()
    elif mode == "executor":
        if "--overlap" in argv:
            overlap_bench(smoke="--smoke" in argv,
                          out=_opt(argv, "--out", "results/overlap.json"))
            return
        ops = tuple(argv[i + 1] for i, a in enumerate(argv)
                    if a == "--op" and i + 1 < len(argv))
        executor_bench(smoke="--smoke" in argv,
                       out=_opt(argv, "--out", "results/executor.json"),
                       ops=ops, trace="--trace" in argv)
    elif mode == "tune":
        tune_bench(smoke="--smoke" in argv,
                   out=_opt(argv, "--out", "results/tuning.json"),
                   cache=_opt(argv, "--cache", None))
    elif mode == "chaos":
        chaos_bench(smoke="--smoke" in argv,
                    out=_opt(argv, "--out", "results/chaos.json"),
                    trace="--trace" in argv)
    elif mode == "serve":
        serve_bench(smoke="--smoke" in argv,
                    out=_opt(argv, "--out", "results/serving.json"),
                    trace="--trace" in argv)
    else:
        raise SystemExit(
            f"unknown mode {mode!r} "
            "(figures | executor | tune | chaos | serve)")


if __name__ == "__main__":
    main()
