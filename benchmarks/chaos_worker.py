"""Chaos benchmark worker: deterministic fault scenarios on the real
multi-process coordinator/worker mesh (see repro.runtime).

Each scenario is a REPRO_FAULTS-style spec run end to end: spawn P
worker processes over TCP, inject the fault, and record what the
recovery actually cost.  Because the faults fire at exact (kind, rank,
step) coordinates and training is deterministic, the resulting
``recovery_steps`` (steps of work re-executed = at_step - restored_step)
is an exact, hardware-independent quantity -- the chaos analog of the
executor benchmark's dimensionless speedup ratios -- and is gated by
``check_regression.py --keys recovery_steps,recovered`` (recovery_steps
is *lower*-is-better).

Rows: ``chaos,<label>,recovery_steps=..,new_P=..,wall_s=..``.
Writes ``--out`` (default results/chaos.json); ``--trace`` additionally
saves the coordinator's per-step Chrome trace (coord.step /
coord.recover / coord.checkpoint spans with skew counters) next to it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import trace as obs_trace  # noqa: E402
from repro.obs.log import data, get_logger  # noqa: E402
from repro.runtime.coordinator import Coordinator, CoordinatorConfig  # noqa: E402

log = get_logger("benchmarks.chaos")

# (label, smoke?, config kwargs) -- every scenario is deterministic:
# same spec, same recovery arc, every run, every host.
SCENARIOS = (
    ("kill_p4", True, dict(
        P=4, n_steps=8, ckpt_every=2, faults="kill:rank=2,step=5")),
    ("kill_before_ckpt_p3", False, dict(
        P=3, n_steps=3, ckpt_every=50, faults="kill:rank=0,step=1")),
    ("torn_ckpt_p3", True, dict(
        P=3, n_steps=8, ckpt_every=2,
        faults="ckpt_torn:step=4;kill:rank=1,step=5")),
    ("delay_skew_p3", False, dict(
        P=3, n_steps=4, ckpt_every=50,
        faults="delay:rank=1,step=2,us=40000")),
)


def run_scenario(label: str, spec: dict, ckpt_root: str) -> dict:
    spec = dict(spec)
    n_steps = spec.pop("n_steps")
    cfg = CoordinatorConfig(ckpt_dir=os.path.join(ckpt_root, label),
                            dim=8, batch=4, lr=0.2, step_timeout_s=60.0,
                            **spec)
    t0 = time.perf_counter()
    with Coordinator(cfg) as c:
        recs = c.run(n_steps)
    wall_s = time.perf_counter() - t0
    row = {
        "label": label,
        "P": cfg.P,
        "n_steps": n_steps,
        "faults": cfg.faults,
        "wall_s": round(wall_s, 3),
        "final_loss": recs[-1]["loss"],
        "max_skew_us": round(max(r["skew_us"] for r in recs), 1),
        "steps_completed": len(c.final_losses()),
    }
    if c.recoveries:
        rec = c.recoveries[0]
        row.update({
            # exact + deterministic: gated lower-is-better
            "recovery_steps": float(rec.recovery_steps),
            "recovered": 1.0 if len(c.final_losses()) == n_steps else 0.0,
            "new_P": rec.new_P,
            "restored_step": rec.restored_step,
        })
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/chaos.json")
    ap.add_argument("--smoke", action="store_true",
                    help="run only the smoke subset (CI PR gate)")
    ap.add_argument("--trace", action="store_true",
                    help="save the coordinator Chrome trace next to --out")
    ap.add_argument("--ckpt-root", default=None,
                    help="checkpoint scratch dir (default: a tmp dir)")
    args = ap.parse_args(argv)

    ckpt_root = args.ckpt_root
    if ckpt_root is None:
        import tempfile
        ckpt_root = tempfile.mkdtemp(prefix="repro_chaos_")
    if args.trace:
        obs_trace.enable(clear=True)

    rows = []
    for label, in_smoke, spec in SCENARIOS:
        if args.smoke and not in_smoke:
            continue
        row = run_scenario(label, spec, ckpt_root)
        rows.append(row)
        parts = [f"recovery_steps={row.get('recovery_steps', '-')}",
                 f"new_P={row.get('new_P', '-')}",
                 f"wall_s={row['wall_s']}"]
        data(f"chaos,{label}," + ",".join(parts))
        if "recovery_steps" in row and not row["recovered"]:
            log.error("chaos_incomplete", label=label,
                      steps=row["steps_completed"], want=row["n_steps"])
            return 1

    mode = "smoke" if args.smoke else "full"
    payload = {"benchmark": "chaos", "mode": mode, "results": rows}
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)
    if args.trace:
        tracer = obs_trace.get_tracer()
        trace_path = tracer.save(
            os.path.join(out_dir, f"trace_chaos_{mode}.json"),
            process_name=f"chaos-bench-{mode}")
        obs_trace.disable()
        payload["trace_path"] = os.path.basename(trace_path)
        data(f"chaos,trace,{os.path.basename(trace_path)},"
             f"{tracer.n_events}")
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    data(f"chaos,WROTE,{args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
