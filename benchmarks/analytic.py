"""Analytic per-device FLOP / HBM-byte / collective-byte model.

Why analytic: XLA's ``cost_analysis()`` on the dry-run artifact counts each
``while`` body **once**, so any scanned program (layer scan, CE chunk scan,
chunked attention) under-reports by the trip counts.  The collective parse
has the same issue.  This module computes the exact totals from the model
configuration -- the same arithmetic the compiled program executes, loop
trip counts included -- and the roofline table reports both (analytic as
primary, cost_analysis as the per-trace cross-check).

Conventions (per device, per step):
  * dense matmul (m,k)x(k,n): 2mkn FLOPs
  * train multiplier: forward 1x + backward 2x + block-remat re-forward 1x
    = 4x forward FLOPs (chunked attention adds one more forward of itself:
    its remat sits inside the block remat)
  * attention scores+pv: 4 * B * H * Sq * Skv_eff * hd (x2 for fp32
    accumulate not counted -- FLOPs are dtype-agnostic)
  * HBM bytes: parameter reads + activation traffic approximated as
    2 bytes * (reads + writes) of every major tensor; this is a lower
    bound (no XLA spills)
  * collective bytes: what actually crosses links, with standard ring
    factors: all-gather / reduce-scatter of N bytes moves N*(P-1)/P per
    device; allreduce 2N*(P-1)/P; ppermute N.
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.schedule import (build_generalized,  # noqa: E402
                                 build_reduce_scatter)
from repro.models.config import ModelConfig, ShapeConfig  # noqa: E402

BF16 = 2
F32 = 4

# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link


@dataclass
class CellModel:
    flops: float              # per device per step
    hbm_bytes: float
    coll_bytes: float         # per device, link-crossing bytes
    model_flops: float        # 6*N_active*tokens_global / chips
    detail: Dict[str, float]

    def terms(self, chips_unused=None):
        return {
            "compute_s": self.flops / PEAK_FLOPS,
            "memory_s": self.hbm_bytes / HBM_BW,
            "collective_s": self.coll_bytes / LINK_BW,
        }

    @property
    def dominant(self):
        t = self.terms()
        return max(t, key=t.get)


def _ring_bytes(n, p):
    """Per-device bytes on the wire for a ring collective of an n-byte
    tensor over p ranks."""
    return n * (p - 1) / p if p > 1 else 0.0


def _attn_eff_kv(S, window, causal=True):
    if window is not None and window < S:
        return window
    return S / 2 if causal else S


def block_fwd_flops(cfg: ModelConfig, kind: str, B, S, tp, *, moe=True,
                    decode_kv=None):
    """Forward FLOPs of one block on one device (B = local batch)."""
    d = cfg.d_model
    fl = 0.0
    if kind in ("attn", "local_attn"):
        repl = cfg.n_heads % tp != 0
        hl = cfg.n_heads if repl else cfg.n_heads // tp
        kvl = cfg.n_kv_heads if repl else max(cfg.n_kv_heads // tp, 1)
        hd = cfg.hd
        fl += 2 * B * S * d * (hl * hd)            # q
        fl += 2 * B * S * d * (kvl * hd) * 2       # k, v
        kv_eff = decode_kv if decode_kv is not None else \
            _attn_eff_kv(S, cfg.window if (kind == "local_attn" or
                                           cfg.window) else None, cfg.causal)
        fl += 4 * B * hl * S * kv_eff * hd         # scores + pv
        fl += 2 * B * S * (hl * hd) * d            # out proj
        if moe and cfg.moe is not None:
            m = cfg.moe
            tokens = B * S
            cap_tokens = tokens * m.top_k * m.capacity_factor
            fl += 2 * tokens * d * m.n_experts     # router
            per_tok = 3 * 2 * d * (m.d_expert // tp)
            fl += cap_tokens * per_tok
            if m.n_shared:
                fl += tokens * 3 * 2 * d * (m.d_shared // tp)
        elif cfg.d_ff:
            n_mats = 3 if cfg.act in ("swiglu", "geglu") else 2
            fl += n_mats * 2 * B * S * d * (cfg.d_ff // tp)
    elif kind == "rglru":
        w = (cfg.rnn_width or d) // tp
        fl += 4 * 2 * B * S * d * w                # gate, x, rg, ig projs
        fl += 2 * B * S * w * cfg.conv_width       # conv
        fl += 10 * B * S * w                       # scan elementwise
        fl += 2 * B * S * w * d                    # out proj
        if cfg.d_ff:
            n_mats = 3 if cfg.act in ("swiglu", "geglu") else 2
            fl += n_mats * 2 * B * S * d * (cfg.d_ff // tp)
    elif kind == "mlstm":
        wfull = int(d * cfg.mlstm_proj_factor)
        wl = wfull // tp
        H = cfg.n_heads
        dk = wfull // H
        dv = wl // H
        fl += 2 * B * S * d * wfull * 2            # q, k (replicated width)
        fl += 2 * B * S * d * wl * 2               # v, gate
        fl += 2 * B * S * d * H * 2                # i, f
        fl += B * S * H * (4 * dv * dk + 4 * dk)   # state update + readout
        fl += 2 * B * S * wl * d                   # out proj
    elif kind == "slstm":
        # replicated across TP (documented inefficiency)
        fl += 4 * 2 * B * S * d * d
        hd = d // cfg.n_heads
        fl += 4 * 2 * B * S * d * hd               # recurrent R mats
        fl += 2 * B * S * d * d                    # out proj
    fl += 2 * 8 * B * S * d / tp                   # norms etc (minor)
    return fl


def train_cell(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
               param_mode: str = "fsdp", pods: int = 1) -> CellModel:
    B = shape.global_batch // dp                   # local batch
    S = shape.seq_len
    d = cfg.d_model
    detail: Dict[str, float] = {}

    fwd = sum(block_fwd_flops(cfg, k, B, S, tp) for k in cfg.blocks)
    # lm head + embed
    fwd += 2 * B * S * d * (cfg.vocab // tp)
    flops = 4.0 * fwd                              # fwd + remat + bwd(2x)
    # chunked attention remat: one extra attention forward
    attn_extra = sum(4 * B * (cfg.n_heads // tp if cfg.n_heads % tp == 0
                              else cfg.n_heads) * S *
                     _attn_eff_kv(S, cfg.window, cfg.causal) * cfg.hd
                     for k in cfg.blocks if k in ("attn", "local_attn"))
    flops += attn_extra
    detail["fwd_flops"] = fwd

    # optimizer flops ~ 10 * local params (negligible, included)
    n_params = cfg.param_count()
    local_params = n_params / tp / (dp if param_mode == "fsdp" else 1)
    flops += 10 * local_params

    # ---- HBM bytes (lower bound) -----------------------------------
    act = B * S * d / tp * BF16                    # one residual tensor
    hbm = 0.0
    hbm += len(cfg.blocks) * 14 * act              # per block r/w traffic
    hbm += 3 * (n_params / tp / (dp if param_mode == "fsdp" else 1)) * F32 \
        * 3                                        # params+m+v read/write
    hbm += 2 * (n_params / tp) * BF16 * 2          # gathered use fwd+bwd
    detail["act_bytes"] = act * len(cfg.blocks) * 14

    # ---- collective bytes -------------------------------------------
    coll = 0.0
    # TP sequence-parallel boundary: per block ag + rs of (B,S,d) bf16,
    # x2 (fwd) x2 (bwd transpose) [+1 remat re-gather]
    n_boundary = 0
    for k in cfg.blocks:
        full_value = (k == "slstm"
                      or (k in ("attn", "local_attn")
                          and cfg.n_heads % tp != 0))
        per_block = 1 if cfg.parallel_residual else (
            2 if (k in ("attn", "local_attn", "rglru")
                  and (cfg.d_ff or cfg.moe)) else 1)
        # gather always happens; scatter skipped for full-value blocks
        n_boundary += per_block * (2 if not full_value else 1)
    tensor = B * S * d * BF16
    coll += _ring_bytes(tensor, tp) * n_boundary * 3      # fwd + remat + bwd
    detail["tp_coll"] = _ring_bytes(tensor, tp) * n_boundary * 3
    # CE: gathers hidden chunks (total B*S*d) + per-chunk scalar psums
    coll += _ring_bytes(tensor, tp) * 3
    # embed scatter
    coll += _ring_bytes(tensor, tp)

    P_dp = dp
    if param_mode == "fsdp":
        # per block: ag params (bf16 use) fwd + remat, rs grads (f32)
        pbytes = (n_params - cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
                  ) / tp * F32
        coll += 2 * _ring_bytes(pbytes * 0.5, P_dp)       # gather bf16 x2
        coll += _ring_bytes(pbytes, P_dp)                 # grad rs f32
        detail["fsdp_coll"] = 3 * _ring_bytes(pbytes, P_dp)
        # replicated-over-dp leaves (norms etc) via generalized allreduce
        small = 0.05 * pbytes / 50                 # rough
        coll += 2 * _ring_bytes(small, P_dp)
    else:
        # gradient sync through the paper's schedule
        sched = build_generalized(P_dp, 0) if param_mode == "dp" else \
            build_reduce_scatter(P_dp)
        gbytes = n_params / tp * F32
        u = gbytes / P_dp
        coll += sched.units_sent * u
        if param_mode == "zero1":
            coll += build_generalized(P_dp, 0).units_sent * u / 2  # ag params
        detail["grad_coll"] = sched.units_sent * u

    model_flops = 6 * _active_params(cfg) * shape.global_batch * S \
        / (dp * tp)
    return CellModel(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                     model_flops=model_flops, detail=detail)


def _active_params(cfg: ModelConfig) -> float:
    n = cfg.param_count()
    if cfg.moe is None:
        return n
    m = cfg.moe
    routed = m.n_experts * 3 * cfg.d_model * m.d_expert * \
        (len([k for k in cfg.blocks if k in ("attn", "local_attn")])
         - m.first_dense)
    active = n - routed * (1 - m.top_k / m.n_experts)
    return active


def serve_cell(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
               pods: int = 1) -> CellModel:
    """decode (S_new=1 against a cache) or prefill (S_new=seq_len)."""
    decode = shape.kind == "decode"
    B = max(shape.global_batch // dp, 1)
    S_new = 1 if decode else shape.seq_len
    kv_len = shape.seq_len
    d = cfg.d_model
    eff_kv = min(kv_len, cfg.window) if (cfg.window and decode) else kv_len

    fwd = sum(block_fwd_flops(cfg, k, B, S_new, tp,
                              decode_kv=eff_kv if decode else None)
              for k in cfg.blocks)
    fwd += 2 * B * S_new * d * (cfg.vocab // tp)
    n_params = cfg.param_count()

    # HBM: every param read once + cache traffic
    hbm = n_params / tp * BF16
    cache_bytes = 0.0
    for k in cfg.blocks:
        if k in ("attn", "local_attn"):
            kvl = max(cfg.n_kv_heads // tp, 1) if cfg.n_heads % tp == 0 \
                else cfg.n_kv_heads
            cache_bytes += 2 * B * kvl * eff_kv * cfg.hd * BF16
    hbm += cache_bytes + 6 * B * S_new * d / tp * BF16 * len(cfg.blocks)

    tensor = B * S_new * d * BF16
    coll = 0.0
    for k in cfg.blocks:
        full_value = (k == "slstm" or (k in ("attn", "local_attn")
                                       and cfg.n_heads % tp != 0))
        # decode path: psum costs ~2x ring allreduce
        per = 2 if (cfg.d_ff or cfg.moe) and k in (
            "attn", "local_attn", "rglru") else 1
        if not full_value:
            coll += 2 * _ring_bytes(tensor, tp) * per
    coll += 2 * _ring_bytes(B * S_new * cfg.vocab / tp * F32, tp)  # logit gather

    # per-device useful flops: B is already dp-local, divide by tp
    model_flops = 2 * _active_params(cfg) * B * S_new / tp
    return CellModel(flops=fwd, hbm_bytes=hbm, coll_bytes=coll,
                     model_flops=model_flops,
                     detail={"cache_bytes": cache_bytes})


# ---------------------------------------------------------------------------
#  flat vs hierarchical collective comparison (CLI: `analytic.py
#  hierarchical`) -- modeled allreduce time across message sizes on the
#  multi-pod topology preset, written as the usual results/*.json rows.
# ---------------------------------------------------------------------------

def hierarchical_report(out_path: str = "results/hierarchical.json",
                        pods: int = 2, chips_per_pod: int = 256):
    from repro.topology import choose_collective, v5e_multipod
    from repro.topology.hierarchical import (best_flat_plan,
                                             best_hierarchical_plan)
    topo = v5e_multipod(pods, chips_per_pod)
    rows = []
    for mexp in range(10, 31, 2):
        m = 1 << mexp
        flat = best_flat_plan(topo, m)
        hier = best_hierarchical_plan(topo, m)
        plan = choose_collective(topo, m)
        rows.append({
            "topology": topo.describe(),
            "bytes": m,
            "flat_s": flat.cost,
            "hierarchical_s": hier.cost,
            "hierarchical_r": hier.r,
            "speedup": flat.cost / hier.cost if hier.cost > 0 else 1.0,
            "chosen": plan.kind,
            "chosen_r": plan.r,
        })
    if os.path.dirname(out_path):
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    for row in rows:
        print(f"hier,m={row['bytes']},flat={row['flat_s'] * 1e6:.1f}us,"
              f"hier(r={row['hierarchical_r']})="
              f"{row['hierarchical_s'] * 1e6:.1f}us,"
              f"speedup={row['speedup']:.2f},chosen={row['chosen']}")
    return rows


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "hierarchical"
    if mode == "hierarchical":
        hierarchical_report()
    else:
        raise SystemExit(f"unknown mode {mode!r} (modes: hierarchical)")
