"""Wall-clock microbenchmark of the JAX collective executors.

Runs on 8 forced host devices (launched by benchmarks.run with XLA_FLAGS
set).  CPU collective timings do not transfer to ICI, but the *relative*
cost of schedule variants (step count vs volume) and parity with the XLA
native psum are meaningful smoke-level signals.

Prints ``wall,<name>,<us_per_call>,1`` rows.
"""
import os
import sys
import time

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.allreduce import allreduce_flat
from repro.core.schedule import build_generalized, build_ring, max_r
from repro.obs.log import data


def bench(fn, x, iters=30):
    out = fn(x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    for m_elems, label in [(256, "1KB"), (262_144, "1MB"),
                           (8_388_608, "32MB")]:
        x = rng.standard_normal((n, m_elems)).astype(np.float32)
        for r in range(max_r(n) + 1):
            sched = build_generalized(n, r)
            f = jax.jit(shard_map(
                lambda v, s=sched: allreduce_flat(v[0], "data", s)[None],
                mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None)))
            us = bench(f, x)
            data(f"wall,gen_allreduce_{label}_r{r},{us:.1f},1")
        sched = build_ring(n)
        f = jax.jit(shard_map(
            lambda v, s=sched: allreduce_flat(v[0], "data", s)[None],
            mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
        data(f"wall,ring_{label},{bench(f, x):.1f},1")
        g = jax.jit(shard_map(
            lambda v: jax.lax.psum(v[0], "data")[None],
            mesh=mesh, in_specs=P("data", None), out_specs=P("data", None)))
        data(f"wall,xla_psum_{label},{bench(g, x):.1f},1")


if __name__ == "__main__":
    main()
