"""Serving benchmark worker: load-generate against the continuous-
batching engine on a dp=2 x tp=2 mesh of 8 forced host devices.

One deterministic request mix (prompt lengths, max_new_tokens) is served
at each offered arrival rate: requests are submitted on an open-loop
schedule (request i arrives at ``i / qps``; ``qps=inf`` enqueues the
whole mix at once) and the engine is stepped until the mix drains.
Reported per QPS level: p50/p99 TTFT, p50/p99 request latency and
generated tokens/sec.

Absolute microseconds are not comparable across hosts, so the gated
keys are dimensionless ratios against a *solo* baseline measured in the
same process right before the sweep -- the same mix served one request
at a time (no batching, no queueing):

  tokens_per_s_ratio   throughput gain of continuous batching (floor)
  p99_ttft_ratio       p99 TTFT / solo mean request latency (ceiling)
  p99_latency_ratio    p99 latency / solo mean request latency (ceiling)

TP decode collectives run on ExecPlan schedules picked by
``autotune.choose()`` (``decode_collectives="plan"``); the payload
records the trace-time picks.  Rows: ``serve,qps=<q>,tokens_per_s=..``.
Writes ``--out`` (default results/serving.json); ``--trace`` saves the
engine.tick Chrome trace + a metrics snapshot next to it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.launch.mesh import make_mesh, parallel_config_for  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import init_params  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.obs.log import data, get_logger  # noqa: E402
from repro.obs.metrics import get_metrics  # noqa: E402
from repro.serve.engine import Engine, Request  # noqa: E402

log = get_logger("benchmarks.serve")

CFG = ModelConfig(name="bench", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=160, vocab=256,
                  head_dim=16, act="swiglu")
MAX_LEN = 64
CHUNK = 16
# offered arrival rates (requests/sec); "inf" = the whole mix at once.
# smoke runs the subset marked True -- its labels must stay a subset of
# the full grid so the committed baseline always overlaps in CI.
QPS_GRID = ((1.0, False), (4.0, True), (16.0, False), (float("inf"), True))


def _mix(n_requests: int, max_new: int):
    rng = np.random.default_rng(7)
    return [Request(prompt=rng.integers(0, CFG.vocab,
                                        int(rng.integers(4, 25)))
                    .astype(np.int32), max_new_tokens=max_new)
            for _ in range(n_requests)]


def _engine(pc, mesh, params, batch_slots, bundle=None):
    return Engine(CFG, pc, mesh, params, batch_slots=batch_slots,
                  max_len=MAX_LEN, prefill_chunk=CHUNK, block_size=8,
                  bundle=bundle)


def _percentiles(vals):
    a = np.asarray(vals, np.float64)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 99)))


def run_solo(pc, mesh, params, mix, bundle) -> dict:
    """Baseline: the same mix, one request at a time, no batching."""
    eng = _engine(pc, mesh, params, 1, bundle)
    t0 = time.perf_counter()
    for r in mix:
        req = Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)
        eng.submit(req)
        eng.run()
        assert req.done
    wall_s = time.perf_counter() - t0
    tokens = sum(r.max_new_tokens for r in mix)
    return {
        "wall_s": wall_s,
        "tokens_per_s": tokens / wall_s,
        "mean_latency_us": wall_s * 1e6 / len(mix),
    }


def run_level(pc, mesh, params, mix, qps, batch_slots, bundle) -> dict:
    """Serve the mix at one offered arrival rate."""
    eng = _engine(pc, mesh, params, batch_slots, bundle)
    reqs = [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)
            for r in mix]
    period = 0.0 if qps == float("inf") else 1.0 / qps
    t0 = time.perf_counter()
    nxt = 0
    while nxt < len(reqs) or eng.queue or \
            any(s is not None for s in eng.slots):
        now = time.perf_counter() - t0
        while nxt < len(reqs) and now >= nxt * period:
            eng.submit(reqs[nxt])
            nxt += 1
        eng.step()
        if nxt < len(reqs) and not eng.queue and \
                all(s is None for s in eng.slots):
            # idle between arrivals: wait for the next one
            time.sleep(max(0.0, nxt * period - (time.perf_counter() - t0)))
    wall_s = time.perf_counter() - t0
    st = eng.stats()
    assert all(r.done for r in reqs)
    assert st["tokens"] == sum(r.max_new_tokens for r in reqs), st
    for m in eng.kv:
        m.check()
    ttft_p50, ttft_p99 = _percentiles([r.ttft_us for r in reqs])
    lat_p50, lat_p99 = _percentiles([r.latency_us for r in reqs])
    label = "qps=inf" if qps == float("inf") else f"qps={qps:g}"
    return {
        "label": label,
        "bench": "serve",
        "qps": None if qps == float("inf") else qps,
        "n_requests": len(reqs),
        "batch_slots": batch_slots,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(st["tokens"] / wall_s, 2),
        "p50_ttft_us": round(ttft_p50, 1),
        "p99_ttft_us": round(ttft_p99, 1),
        "p50_latency_us": round(lat_p50, 1),
        "p99_latency_us": round(lat_p99, 1),
        "ticks": st["ticks"],
        "prefill_ticks": st["prefill_ticks"],
        "peak_blocks_used": max(k["peak_blocks_used"] for k in st["kv"]),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="run only the smoke QPS subset (CI PR gate)")
    ap.add_argument("--trace", action="store_true",
                    help="save the engine.tick Chrome trace next to --out")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args(argv)

    # the mix is the SAME in smoke and full runs -- smoke only trims the
    # QPS grid -- so a smoke row in CI and the committed full-run row
    # with the same label measure the identical workload
    mix = _mix(args.requests, args.max_new)

    mesh = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    pc = parallel_config_for(mesh, param_mode="dp")
    params, _ = init_params(CFG, pc, jax.random.PRNGKey(0))

    # warm up every compiled (B, S) shape outside the timed runs
    warm = _engine(pc, mesh, params, args.batch_slots)
    bundle = warm.bundle
    warm.generate([Request(prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens)
                   for r in mix[:4]])
    solo_warm = _engine(pc, mesh, params, 1, bundle)
    solo_warm.generate([Request(prompt=mix[0].prompt, max_new_tokens=2)])

    if args.trace:
        obs_trace.enable(clear=True)
    metrics = get_metrics()

    solo = run_solo(pc, mesh, params, mix, bundle)
    data(f"serve,solo,tokens_per_s={solo['tokens_per_s']:.2f},"
         f"mean_latency_us={solo['mean_latency_us']:.1f}")

    rows = []
    for qps, in_smoke in QPS_GRID:
        if args.smoke and not in_smoke:
            continue
        row = run_level(pc, mesh, params, mix, qps, args.batch_slots,
                        bundle)
        row["solo_tokens_per_s"] = round(solo["tokens_per_s"], 2)
        row["solo_mean_latency_us"] = round(solo["mean_latency_us"], 1)
        # dimensionless, host-normalized: the gated keys.  At a finite
        # offered rate, wall clock is arrival-schedule-bound (fixed
        # seconds) while the solo baseline is host-bound, so the
        # throughput ratio is only meaningful on the saturated
        # (qps=inf) row; the latency ratios compare host-bound
        # quantities on both sides and gate at every level.
        if qps == float("inf"):
            row["tokens_per_s_ratio"] = round(
                row["tokens_per_s"] / solo["tokens_per_s"], 3)
        row["p99_ttft_ratio"] = round(
            row["p99_ttft_us"] / solo["mean_latency_us"], 3)
        row["p99_latency_ratio"] = round(
            row["p99_latency_us"] / solo["mean_latency_us"], 3)
        rows.append(row)
        metrics.histogram("serve_tokens_per_s").record(row["tokens_per_s"])
        data(f"serve,{row['label']},tokens_per_s={row['tokens_per_s']},"
             f"tps_ratio={row.get('tokens_per_s_ratio', '-')},"
             f"p99_ttft_ratio={row['p99_ttft_ratio']},"
             f"p99_latency_ratio={row['p99_latency_ratio']}")

    # trace-time decode collective picks (engines share one choice log)
    choices = warm.decode_choices
    picks = [{"op": op, "nbytes": nb, "kind": c.kind, "r": c.r,
              "n_buckets": c.n_buckets, "source": c.source}
             for op, nb, c in choices]
    if not picks:
        log.error("no_decode_choices")
        return 1

    mode = "smoke" if args.smoke else "full"
    payload = {"benchmark": "serving", "mode": mode,
               "model": CFG.name, "mesh": "dp=2,tp=2",
               "decode_choices": picks, "results": rows}
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)
    if args.trace:
        tracer = obs_trace.get_tracer()
        trace_path = tracer.save(
            os.path.join(out_dir, f"trace_serving_{mode}.json"),
            process_name=f"serve-bench-{mode}")
        obs_trace.disable()
        payload["trace_path"] = os.path.basename(trace_path)
        metrics_path = metrics.save(
            os.path.join(out_dir, f"metrics_serving_{mode}.json"),
            extra={"benchmark": "serving", "mode": mode})
        data(f"serve,trace,{os.path.basename(trace_path)},"
             f"{tracer.n_events}")
        data(f"serve,WROTE,{metrics_path}")
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    data(f"serve,WROTE,{args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
