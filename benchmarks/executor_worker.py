"""Executor wallclock benchmark: per-row replay vs ExecPlan vs pipelined.

Runs on 8 forced host devices (launched by ``benchmarks/run.py executor``
with XLA_FLAGS set).  Three executors replay the *same* autotuned
schedule for each message size:

* ``legacy``    -- the pre-ExecPlan per-row replay (Python list of (u,)
  rows, ``jnp.stack``/unstack round-trip per step, per-row output loop),
  preserved verbatim below as the benchmark baseline after its deletion
  from the library;
* ``execplan``  -- the vectorized single-buffer replay (n_buckets=1);
* ``pipelined`` -- the same plan with the autotuned multi-bucket
  software pipeline.

CPU wallclock does not transfer to ICI, but all three executors pay the
same ppermute rendezvous and move the same bytes, so the *relative* cost
isolates exactly what the lowering removed: per-row op dispatch, the
stack/unstack copies, and the double final gather.

Beyond the sum rows, the benchmark covers the generalized collective
family (select families with ``--op``, repeatable; default all):

* ``<label>@max`` rows run the same three executors under the max
  monoid (``combine="max"``) -- gating that non-sum combines keep the
  lowering's speedup;
* ``<label>@a2a`` rows time the schedule-driven all-to-all (direct and
  Bruck plans) against in-process ``lax.all_to_all``.  The *gated*
  quantity is ``speedup_bruck_vs_direct`` (both sides our own stable
  ExecPlan replays); the ``speedup_direct`` / ``speedup_bruck``
  vs-XLA ratios are informational only -- XLA CPU's all_to_all
  wallclock is bimodal across processes on this host (order-of-
  magnitude swings between identical runs), so a ratio against it
  cannot hold a 35% gate.

Prints ``executor,<label>,<variant>,<us_per_call>`` rows and writes a
JSON summary (the repo's first BENCH datapoint) to the path given by
``--out``.
"""
import argparse
import json
import os
import sys
import time

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.allreduce import all_to_all_flat, allreduce_flat
from repro.core.autotune import choose, schedule_for
from repro.core.cost_model import (HOST_CPU, choose_a2a,
                                   pipelined_schedule_cost, schedule_cost)
from repro.core.monoid import MONOIDS
from repro.core.schedule import Schedule, build_ring
from repro.obs import trace as obs_trace
from repro.obs.log import data, get_logger
from repro.obs.metrics import get_metrics

log = get_logger("benchmarks.executor")


# ---------------------------------------------------------------------------
#  the pre-ExecPlan executor, verbatim (baseline only -- do not reuse)
# ---------------------------------------------------------------------------

def _perm_for(sched: Schedule, shift: int):
    g = sched.group
    return [(d, g.apply(shift, d)) for d in range(sched.P)]


def _initial_row_table(sched: Schedule) -> np.ndarray:
    P_ = sched.P
    R = len(sched.initial_slots)
    tbl = np.zeros((R, P_), dtype=np.int32)
    for k in range(R):
        for d in range(P_):
            tbl[k, d] = sched.chunk_of_initial_row(k, d)
    return tbl


def _final_row_table(sched: Schedule) -> np.ndarray:
    P_ = sched.P
    tbl = np.full((P_, P_), -1, dtype=np.int32)
    for k in range(len(sched.final_slots)):
        for d in range(P_):
            tbl[sched.final_chunk_index(k, d), d] = k
    return tbl


def _run_steps(rows, sched: Schedule, axis_name, combine=jnp.add):
    for st in sched.steps:
        if st.n_tx:
            tx = jnp.stack([rows[i] for i in st.tx_rows])
            rx = lax.ppermute(tx, axis_name, perm=_perm_for(sched, st.shift))
        new_rows = []
        for op in st.out:
            if op.kind == "keep":
                new_rows.append(rows[op.res])
            elif op.kind == "recv":
                new_rows.append(rx[op.arr])
            else:
                new_rows.append(combine(rows[op.res], rx[op.arr]))
        rows = new_rows
    return rows


def legacy_allreduce_flat(x, axis_name, sched: Schedule, combine=jnp.add):
    P_ = sched.P
    m = x.shape[0]
    u = -(-m // P_)
    pad = u * P_ - m
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    chunks = x.reshape(P_, u)
    d = lax.axis_index(axis_name)
    init_tbl = jnp.asarray(_initial_row_table(sched))
    rows_idx = jnp.take(init_tbl, d, axis=1)
    stacked = jnp.take(chunks, rows_idx, axis=0)
    rows = [stacked[i] for i in range(stacked.shape[0])]
    rows = _run_steps(rows, sched, axis_name, combine)
    fin_tbl = jnp.asarray(_final_row_table(sched))
    order = jnp.take(fin_tbl, d, axis=1)
    out = jnp.take(jnp.stack(rows), order, axis=0)
    return out.reshape(-1)[:m]


# ---------------------------------------------------------------------------
#  instrumented replay mode (--trace)
# ---------------------------------------------------------------------------

def trace_overhead_ratio(fn, x, iters=20):
    """Relative cost of the *disabled* tracing hook on one jitted call:
    time the call bare, then wrapped in a module-level span with the
    global tracer off (the exact dispatch-path pattern the library
    uses), and return hooked/plain - 1.  Gated < 2%."""
    assert not obs_trace.get_tracer().enabled
    jax.block_until_ready(fn(x))
    best_plain = best_hooked = float("inf")
    for _ in range(4):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(x)
        jax.block_until_ready(out)
        best_plain = min(best_plain, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(iters):
            with obs_trace.span("hook", cat="bench"):
                out = fn(x)
        jax.block_until_ready(out)
        best_hooked = min(best_hooked, time.perf_counter() - t0)
    return best_hooked / best_plain - 1.0


def trace_mode(args, mesh, n, sizes, overhead_probe):
    """Instrumented per-tick replay of every (kind, n_buckets) combo the
    benchmark grid exercises, exported as a Perfetto-loadable Chrome
    trace plus a metrics snapshot embedding the predicted-vs-measured
    model-error table (see repro.obs.validate)."""
    from repro.core.schedule import build_generalized
    from repro.obs.instrument import traced_allreduce
    from repro.obs.validate import (fit_ratio, model_error_table,
                                    report_markdown)

    rng = np.random.default_rng(1)
    metrics = get_metrics()
    tracer = obs_trace.enable(clear=True)
    reports = []
    for label, nbytes in sizes:
        m = nbytes // 4
        vecs = [rng.standard_normal(m).astype(np.float32)
                for _ in range(n)]
        ch = choose(n, nbytes, HOST_CPU, itemsize=4)
        nb = max(2, ch.n_buckets)
        # every (kind, n_buckets) combination the bench grid runs at
        # this size: the chosen generalized schedule and the ring
        # baseline, each unpipelined and at the bench's bucket count
        combos = [("generalized", ch.r if ch.kind == "generalized" else 0),
                  ("ring", 0)]
        for kind, r in combos:
            sched = build_generalized(n, r) if kind == "generalized" \
                else build_ring(n)
            for b in (1, nb):
                rep = traced_allreduce(sched, vecs, n_buckets=b,
                                       mesh=mesh, reps=3, tracer=tracer)
                if not rep.verified:
                    log.error("trace_replay_mismatch", size=label,
                              kind=kind, r=r, n_buckets=b,
                              max_abs_err=rep.max_abs_err)
                    raise SystemExit(1)
                reports.append(rep)
                metrics.counter("replays").inc()
                metrics.counter("replay_ticks").inc(len(rep.ticks))
                metrics.histogram("replay_total_us").record(rep.total_us)
                for t in rep.ticks:
                    metrics.histogram("tick_total_us").record(t.total_us)
                data(f"executor,trace,{label},{kind},r={r},b={b},"
                     f"{rep.total_us:.1f}")
    rows = model_error_table([r.to_dict() for r in reports], HOST_CPU)
    gm = fit_ratio(rows)
    mode = "smoke" if args.smoke else "full"
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    trace_path = tracer.save(
        os.path.join(out_dir, f"trace_executor_{mode}.json"),
        process_name=f"executor-bench-{mode}")
    obs_trace.disable()
    snap_extra = {
        "model_error": rows,
        "model_error_fabric": HOST_CPU.name,
        "model_error_geomean_ratio": gm,
        "trace_off_overhead": overhead_probe,
        "trace_path": os.path.basename(trace_path),
    }
    metrics_path = metrics.save(
        os.path.join(out_dir, f"metrics_executor_{mode}.json"),
        extra=snap_extra)
    report_path = os.path.join(out_dir, f"model_error_{mode}.md")
    with open(report_path, "w") as f:
        f.write(report_markdown(
            rows, title=f"Predicted vs measured ({mode} grid, P={n})",
            fabric_name=HOST_CPU.name))
    data(f"executor,WROTE,{trace_path}")
    data(f"executor,WROTE,{metrics_path}")
    data(f"executor,WROTE,{report_path}")
    log.info("trace_mode_done", replays=len(reports),
             geomean_ratio=round(gm, 3) if gm else None,
             trace_events=tracer.n_events)
    return {"trace_path": os.path.basename(trace_path),
            "metrics_path": os.path.basename(metrics_path),
            "report_path": os.path.basename(report_path),
            "n_replays": len(reports),
            "model_error_geomean_ratio": gm,
            "trace_off_overhead": overhead_probe}


# ---------------------------------------------------------------------------
#  harness
# ---------------------------------------------------------------------------

def bench_interleaved(variants, x, iters, reps=4):
    """Time all variants round-robin so machine-load drift hits every
    executor equally; returns {name: best_us_per_call}."""
    for fn in variants.values():
        jax.block_until_ready(fn(x))        # warm-up / compile
    best = {name: float("inf") for name in variants}
    for _ in range(reps):
        for name, fn in variants.items():
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(x)
            jax.block_until_ready(out)
            best[name] = min(best[name],
                             (time.perf_counter() - t0) / iters * 1e6)
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--op", action="append", default=None,
                    choices=["sum", "max", "a2a"],
                    help="benchmark family to run (repeatable; default all)")
    ap.add_argument("--trace", action="store_true",
                    help="also run the instrumented per-tick replay and "
                         "write a Chrome trace + metrics snapshot + "
                         "model-error report next to --out")
    args = ap.parse_args()
    ops = args.op or ["sum", "max", "a2a"]

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    # "+36B" sizes are ragged (element count coprime with the 8 devices):
    # the ExecPlan executor runs its native exact split there while the
    # legacy baseline zero-pads, so these rows gate the ragged path.
    if args.smoke:
        sizes = [("64KiB", 64 << 10), ("256KiB", 256 << 10),
                 ("256KiB+36B", (256 << 10) + 36)]
        max_sizes = [("256KiB", 256 << 10)]
        a2a_sizes = [("256KiB", 256 << 10)]
        iters = 3
    else:
        sizes = [("256KiB", 256 << 10), ("256KiB+36B", (256 << 10) + 36),
                 ("4MiB", 4 << 20), ("4MiB+36B", (4 << 20) + 36),
                 ("64MiB", 64 << 20)]
        max_sizes = [("256KiB", 256 << 10), ("4MiB+36B", (4 << 20) + 36)]
        a2a_sizes = [("256KiB", 256 << 10), ("4MiB", 4 << 20)]
        iters = 5

    def jit_collective(fn):
        return jax.jit(shard_map(
            lambda v: fn(v[0])[None], mesh=mesh,
            in_specs=P("data", None), out_specs=P("data", None)))

    results = []

    def reduce_rows(bench_sizes, op):
        suffix = "" if op == "sum" else f"@{op}"
        for label, nbytes in bench_sizes:
            m = nbytes // 4
            x = rng.standard_normal((n, m)).astype(np.float32)
            ch = choose(n, nbytes, HOST_CPU, itemsize=4,
                        monoid=MONOIDS[op])
            sched = schedule_for(ch, n)
            nb = max(2, ch.n_buckets)  # exercise the pipeline even if the
            # model's optimum degenerates to one bucket at this size
            legacy_comb = jnp.add if op == "sum" else jnp.maximum
            variants = {
                "legacy": jit_collective(
                    lambda v: legacy_allreduce_flat(v, "data", sched,
                                                    legacy_comb)),
                "execplan": jit_collective(
                    lambda v: allreduce_flat(v, "data", sched, n_buckets=1,
                                             combine=op)),
                "pipelined": jit_collective(
                    lambda v: allreduce_flat(v, "data", sched, n_buckets=nb,
                                             combine=op)),
                "xla_psum": jit_collective(
                    (lambda v: lax.psum(v, "data")) if op == "sum"
                    else (lambda v: lax.pmax(v, "data"))),
            }
            # all variants must agree before any timing counts
            ref = np.asarray(variants["legacy"](x))[0]
            for name in ("execplan", "pipelined"):
                np.testing.assert_allclose(np.asarray(variants[name](x))[0],
                                           ref, rtol=1e-6, atol=1e-6)
            row = {"label": label + suffix, "bytes": nbytes,
                   "ragged": m % n != 0, "op": op,
                   "schedule": {"kind": ch.kind, "r": ch.r},
                   "n_buckets": nb, "model_n_buckets": ch.n_buckets}
            timed = bench_interleaved(variants, x, iters)
            for name, us in timed.items():
                row[f"{name}_us"] = round(us, 1)
                data(f"executor,{label}{suffix},{name},{us:.1f}")
            row["speedup_execplan"] = round(row["legacy_us"]
                                            / row["execplan_us"], 3)
            row["speedup_pipelined"] = round(row["legacy_us"]
                                             / row["pipelined_us"], 3)
            # what the extended cost model predicts pipelining buys on a
            # fabric where comm and combine genuinely overlap
            row["model_speedup_pipelined"] = round(
                schedule_cost(sched, nbytes, HOST_CPU, MONOIDS[op])
                / pipelined_schedule_cost(sched, nbytes, HOST_CPU, nb,
                                          MONOIDS[op]), 3)
            results.append(row)

    def a2a_rows(bench_sizes):
        for label, nbytes in bench_sizes:
            m = nbytes // 4
            assert m % n == 0, "a2a sizes must divide the device count"
            x = rng.standard_normal((n, m)).astype(np.float32)
            variants = {
                "xla_a2a": jit_collective(
                    lambda v: lax.all_to_all(
                        v.reshape(n, -1), "data", 0, 0).reshape(-1)),
                "direct": jit_collective(
                    lambda v: all_to_all_flat(v, "data", kind="direct")),
                "bruck": jit_collective(
                    lambda v: all_to_all_flat(v, "data", kind="bruck")),
            }
            ref = np.asarray(variants["xla_a2a"](x))[0]
            for name in ("direct", "bruck"):
                np.testing.assert_allclose(np.asarray(variants[name](x))[0],
                                           ref, rtol=0, atol=0)
            row = {"label": f"{label}@a2a", "bytes": nbytes,
                   "ragged": False, "op": "a2a", "collective": "a2a",
                   "model_kind": choose_a2a(n, float(nbytes), HOST_CPU)}
            timed = bench_interleaved(variants, x, iters)
            for name, us in timed.items():
                row[f"{name}_us"] = round(us, 1)
                data(f"executor,{label}@a2a,{name},{us:.1f}")
            # informational: XLA CPU a2a wallclock is bimodal across
            # processes here, so these two are not gate-stable
            row["speedup_direct"] = round(row["xla_a2a_us"]
                                          / row["direct_us"], 3)
            row["speedup_bruck"] = round(row["xla_a2a_us"]
                                         / row["bruck_us"], 3)
            # gated: both sides are our own interleaved ExecPlan replays
            row["speedup_bruck_vs_direct"] = round(row["direct_us"]
                                                   / row["bruck_us"], 3)
            results.append(row)

    if "sum" in ops:
        reduce_rows(sizes, "sum")
    if "max" in ops:
        reduce_rows(max_sizes, "max")
    if "a2a" in ops:
        a2a_rows(a2a_sizes)

    trace_summary = None
    if args.trace:
        # probe the disabled-hook overhead on a real jitted collective
        # (must run before trace_mode enables the global tracer)
        label0, nbytes0 = sizes[0]
        x0 = rng.standard_normal((n, nbytes0 // 4)).astype(np.float32)
        ch0 = choose(n, nbytes0, HOST_CPU, itemsize=4)
        sched0 = schedule_for(ch0, n)
        nb0 = max(2, ch0.n_buckets)
        probe_fn = jit_collective(
            lambda v: allreduce_flat(v, "data", sched0, n_buckets=nb0))
        overhead = round(trace_overhead_ratio(probe_fn, x0), 4)
        data(f"executor,trace_off_overhead,{label0},{overhead:.4f}")
        trace_summary = trace_mode(args, mesh, n, sizes, overhead)

    payload = {"P": n, "platform": jax.default_backend(),
               "mode": "smoke" if args.smoke else "full",
               "autotune_fabric": HOST_CPU.name,
               "notes": ("XLA CPU executes collectives synchronously (no "
                         "comm/combine overlap) and this host is "
                         "memory-bandwidth saturated, so measured wallclock "
                         "converges across executors at large sizes; the "
                         "pipelining win shows in model_speedup_pipelined "
                         "and on asynchronous fabrics. xla_psum bounds "
                         "what a native fused collective achieves here. "
                         "@max rows run the same executors under the max "
                         "monoid; @a2a rows compare the schedule-driven "
                         "all-to-all plans against lax.all_to_all."),
               "results": results}
    if trace_summary is not None:
        payload["trace"] = trace_summary
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    data(f"executor,WROTE,{args.out}")


if __name__ == "__main__":
    main()
