"""Measured-tuning worker: populate the tuning cache on 8 host devices.

Launched by ``benchmarks/run.py tune`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the candidate
grid is timed on the same virtual-device fabric the multi-device tests
use.  All measuring logic lives in :mod:`repro.tuning.measure`; this is
only the subprocess entry point.
"""

import argparse
import os
import sys

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.tuning.measure import run_tuning  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="summary JSON path")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument(
        "--cache",
        default=None,
        help="tuning cache path (default: REPRO_TUNING_CACHE or the "
        "user cache dir)",
    )
    args = ap.parse_args()
    run_tuning(smoke=args.smoke, out=args.out, cache_path=args.cache)


if __name__ == "__main__":
    main()
